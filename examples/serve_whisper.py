"""END-TO-END DRIVER (the paper's kind is inference): serve the FULL
whisper-tiny configuration with batched requests through the Q8_0 offload
path, reporting per-request latency and PDP/EDP — the deployment the paper
targets, on the TPU-native stack.

  PYTHONPATH=src python examples/serve_whisper.py [--requests 4] [--dense]
                                                  [--stream]

Flow per the paper's Fig 1: mel frames -> encoder (once per utterance) ->
per-layer cross-K/V projection (dec.cross.kv) -> autoregressive greedy
decode against the self-attention KV cache. Every GEMM routes through the
offload dispatcher: main segments on the (interpret-mode) Pallas kernels,
residuals on the host path, with coverage-based fallback.

``--stream`` serves the same utterances through the continuous-batching
scheduler (DESIGN.md §11) instead: requests are submitted STAGGERED —
half up front, the rest arriving while earlier utterances are mid-decode
— admitted into freed slots of the fixed-shape KV pool between jitted
steps, and each token prints the moment its request produces it.
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core import energy
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.serve.engine import ServeEngine
from repro.tuning import Autotuner


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--frames", type=int, default=192,
                    help="mel frames per utterance (1500 = full 30s window)")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--dense", action="store_true",
                    help="FP16/bf16 baseline instead of Q8_0")
    ap.add_argument("--stream", action="store_true",
                    help="continuous-batching scheduler with staggered "
                         "submission + per-token streaming (DESIGN.md §11)")
    ap.add_argument("--slots", type=int, default=2,
                    help="slot-pool width for --stream")
    args = ap.parse_args(argv)

    cfg = get_config("whisper-tiny")
    print(f"whisper-tiny: {cfg.n_params()/1e6:.1f}M params, "
          f"{cfg.num_encoder_layers}+{cfg.num_layers} layers, "
          f"d={cfg.d_model}, vocab={cfg.vocab_size}")

    t0 = time.time()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)
    print(f"init {time.time()-t0:.1f}s")

    quant = "none" if args.dense else "q8_0"
    # Autotuned dispatch (DESIGN.md §9): ServeEngine pre-tunes the whisper
    # GEMM shapes at construction and persists winners for later runs.
    tuner = Autotuner(cache_path=os.path.join("experiments", "tuning",
                                              "whisper_tiny.json"),
                      mode="analytic")
    offload = OffloadEngine(vmem_budget_kb=8 * 1024, burst=128,
                            prefer_pallas=False,  # XLA path of same math
                            tuner=tuner)
    engine = ServeEngine(cfg, params, max_len=args.max_new + 8,
                         quant=quant, offload=offload, eos_id=-1)

    rng = np.random.default_rng(0)
    mel = rng.standard_normal(
        (args.requests, args.frames, cfg.n_mels)).astype(np.float32)

    if args.stream:
        # Continuous batching (DESIGN.md §11): half the utterances are
        # queued up front; the rest are submitted between decode steps —
        # they land in slots freed by earlier evictions while the batch
        # keeps stepping, and every token streams as soon as it exists.
        sched = engine.scheduler(n_slots=args.slots, n_frames=args.frames)
        half = max(1, args.requests // 2)
        rids = [sched.submit(mel[i:i + 1], max_new=args.max_new)
                for i in range(half)]
        late = list(range(half, args.requests))
        print(f"\nstreaming {args.requests} utterances through "
              f"{args.slots} slots ({half} queued, {len(late)} arriving "
              f"mid-decode, {quant} path)...")

        def on_token(ev):
            print(f"  [stream] utt{ev.rid} step {ev.step}: token "
                  f"{ev.token}{'  <eos/budget>' if ev.done else ''}")

        while sched.n_queued or sched.n_active or late:
            sched.admit()
            for ev in sched.decode_step():
                on_token(ev)
            if late:                      # staggered arrival mid-decode
                i = late.pop(0)
                rids.append(sched.submit(mel[i:i + 1],
                                         max_new=args.max_new))
                print(f"  [arrive] utt{rids[-1]} submitted mid-decode")
        got = sched.finished
        results = [got[r] for r in rids]
        print(f"zero retraces after warmup: "
              f"{sched.step_traces} step trace(s) total")
    else:
        print(f"\ntranscribing {args.requests} utterances "
              f"({args.frames} frames each, {quant} path)...")
        results = engine.transcribe(mel, max_new=args.max_new)
    for i, r in enumerate(results):
        print(f"  utt{i}: {r.steps} tokens | prefill {r.prefill_s:.2f}s "
              f"decode {r.decode_s:.2f}s | PDP {r.pdp_j():.1f} J "
              f"(v5e TDP model)")

    rep = engine.energy_report(results)
    st = offload.stats
    print(f"\nbatch: {rep['requests']} reqs, {rep['total_s']:.2f}s total, "
          f"PDP {rep['pdp_j']:.1f} J, EDP {rep['edp_js']:.1f} J*s")
    print(f"offload: {st.offloaded_calls} offloaded / {st.fallback_calls} "
          f"fallback calls ({st.offload_rate():.1%} — paper: 93.8% coverage "
          f"at 32KB); flop offload rate {st.offload_flop_rate():.1%}")
    print(f"by kernel class: { {k: v for k, v in sorted(st.by_kernel.items())[:8]} }")


if __name__ == "__main__":
    main()
