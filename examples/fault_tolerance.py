"""Fault-tolerance demo: inject a node failure mid-training and watch the
supervision loop restart from the latest atomic checkpoint; then compare
against an uninterrupted run — losses on the replayed steps are identical
(bit-exact restore + stateless data cursor).

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil

from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.train.fault import RestartPolicy, run_with_restarts
from repro.train.trainer import Trainer

CKPT = "/tmp/repro_ft_demo"


def make_run(steps=12):
    return RunConfig(
        model=get_smoke_config("phi3-mini-3.8b"),
        shape=ShapeConfig("t", 32, 4, "train"),
        optimizer=OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=50),
        steps=steps, checkpoint_every=3, checkpoint_dir=CKPT)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)

    crashed = {"done": False}

    def bomb(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            print(f"  !!! injecting node failure at step {step}")
            raise RuntimeError("simulated preemption")

    histories = []

    def make_attempt(attempt):
        def run():
            print(f"--- attempt {attempt} "
                  f"(resumes from latest checkpoint if any)")
            tr = Trainer(make_run(), vocab_cap=64, fault_hook=bomb)
            tr.train()
            histories.append(tr.history)
            return tr
        return run

    tr = run_with_restarts(make_attempt,
                           RestartPolicy(max_restarts=2, backoff_s=0.01))
    print("\nsteps executed per attempt:",
          [[h["step"] for h in hist] for hist in histories])

    # gold uninterrupted run for comparison
    shutil.rmtree(CKPT, ignore_errors=True)
    gold = Trainer(make_run(), vocab_cap=64)
    gold.train()
    gold_by_step = {h["step"]: h["loss"] for h in gold.history}
    resumed_by_step = {h["step"]: h["loss"] for h in histories[-1]}
    print("\nstep | resumed loss | uninterrupted loss")
    agree = True
    for s in sorted(resumed_by_step):
        a, b = resumed_by_step[s], gold_by_step[s]
        agree &= abs(a - b) < 1e-5 * max(abs(b), 1)
        print(f"{s:4d} | {a:.6f} | {b:.6f}")
    print("\nbit-exact resume:", "YES" if agree else "NO")


if __name__ == "__main__":
    main()
