"""Quickstart: the paper's technique in 60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Quantize a weight matrix to GGML Q8_0 (blocks of 32 + fp16 scale).
2. Run the mixed-execution dot product: burst-aligned main segment on the
   Pallas TPU kernel (interpret mode on CPU), residual on the host path.
3. Ask the offload dispatcher whether the invocation fits the local-memory
   budget (the paper's LMM-coverage test) and account PDP.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.core.offload import OffloadEngine
from repro.core.qformats import quantize_q8_0, reconstruction_error
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)

    # Whisper-tiny's FFN down-projection shape: W (384, 1536), x (tokens, 1536)
    w = jax.random.normal(kw, (384, 1536)) * 0.02
    x = jax.random.normal(kx, (8, 1536))

    # 1) Q8_0 quantization (paper §3.2 / §4.2)
    wq = quantize_q8_0(w)
    err = reconstruction_error(w, wq)
    print(f"Q8_0: {wq.qs.shape[0]}x{wq.k} int8 + {wq.scales.size} fp16 "
          f"scales | MAE {err['mae']:.2e} (paper: 1.39e-4) | "
          f"{wq.nbytes()} bytes vs {w.size*2} fp16 bytes")

    # 2) mixed execution: aligned main on the kernel, residual on host
    y = ops.matmul(x, wq, burst=128, prefer_pallas=True, interpret=True)
    y_ref = x @ w.T
    print(f"mixed-exec matmul: out {y.shape}, max|err| vs dense "
          f"{float(jnp.max(jnp.abs(y - y_ref))):.2e}")

    # 3) offload dispatch + PDP accounting (paper Eq. 1-2)
    eng = OffloadEngine(vmem_budget_kb=32, burst=128, prefer_pallas=True,
                        interpret=True)
    y2 = eng.linear(x, wq, name="ffn.down")
    print(f"dispatcher: offloaded={eng.stats.offloaded_calls} "
          f"fallback={eng.stats.fallback_calls} "
          f"(budget test: activation {x.size*2}B vs 32KB)")
    pdp = energy.pdp_mixed(t_active_s=0.8, t_main_s=1.0,
                           p_accel_w=energy.P_IMAX_LANE_Q8_W * 2)
    print(f"PDP for a 1s step, 0.8s accelerator-active: {pdp:.3f} J "
          f"(Eq. 2; host remainder at {energy.P_ARM_A72_W} W)")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-5)
    print("ok")


if __name__ == "__main__":
    main()
