"""Train a ~100M-param dense LM for a few hundred steps on CPU, with
checkpoint/restart and straggler monitoring — the training-side example.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]

The model is a real 12-layer GQA transformer (~100M params at d=768); data
is the deterministic learnable synthetic stream, so the loss curve is a
genuine convergence signal. Interrupt and re-run: it resumes from the last
atomic checkpoint at the exact cursor.
"""
import argparse

from repro.configs.base import (
    ModelConfig, OptimizerConfig, RunConfig, ShapeConfig)
from repro.train.trainer import Trainer


def make_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=2048, vocab_size=8192,
        norm="rmsnorm", act="swiglu",
        dtype="float32", param_dtype="float32",
        remat="none", scan_layers=False,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8_ef"])
    args = ap.parse_args(argv)

    cfg = make_100m()
    print(f"model: {cfg.n_params()/1e6:.0f}M params")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", args.seq, args.batch, "train"),
        optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20,
                                  total_steps=args.steps,
                                  grad_compress=args.grad_compress),
        steps=args.steps, checkpoint_every=50,
        checkpoint_dir=args.ckpt_dir)

    trainer = Trainer(run, vocab_cap=cfg.vocab_size,
                      install_signal_handler=True)
    trainer._init_or_restore()
    if trainer._start_step:
        print(f"resuming from step {trainer._start_step}")
    metrics = trainer.train()
    losses = [h["loss"] for h in trainer.history]
    if losses:
        k = max(len(losses) // 10, 1)
        curve = [f"{sum(losses[i:i+k])/len(losses[i:i+k]):.3f}"
                 for i in range(0, len(losses), k)]
        print("loss curve (deciles):", " -> ".join(curve))
        print(f"final: {metrics}")
        if trainer.monitor.events:
            print(f"stragglers flagged: {trainer.monitor.events}")


if __name__ == "__main__":
    main()
