"""Mixed execution (paper §3.2): burst-aligned main + residual split."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.mixed_exec import (
    mixed_matmul, mixed_matmul_q8, residual_fraction, split_aligned,
    split_point)
from repro.core.qformats import quantize_q8_0
from repro.kernels import ref


@given(st.integers(0, 10_000), st.integers(1, 512))
def test_split_invariants(length, burst):
    main, res = split_aligned(length, burst)
    assert main + res == length
    assert main % burst == 0
    assert 0 <= res < burst
    assert split_point(length, burst) == main


def test_paper_zero_residual_claim():
    """Whisper-tiny's static dims (384, 1536, 64) are exact multiples of
    the paper's burst 16 (and our 128-lane analog divides 384? no — 384 =
    3x128; 1536 = 12x128; 64 is sub-lane and residual-handled)."""
    for dim in (384, 1536, 64):
        assert dim % 16 == 0           # the paper's claim verbatim
    for dim in (384, 1536):
        assert dim % 128 == 0          # TPU lane analog
    assert residual_fraction(64, 128) == 1.0  # dk=64 runs on the host path


@given(st.integers(1, 8), st.integers(1, 300), st.integers(1, 128),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_mixed_matmul_matches_monolith(m, k, burst, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(keys[0], (m, k))
    w = jax.random.normal(keys[1], (16, k))
    got = mixed_matmul(x, w, burst, ref.matmul_f32_ref)
    want = x @ w.T
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 6), st.integers(0, 2**31 - 1),
       st.sampled_from([32, 64, 96, 128, 160]))
@settings(max_examples=20, deadline=None)
def test_mixed_matmul_q8(nblocks, seed, burst):
    k = nblocks * 32 + 17            # force a ragged tail
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(keys[0], (4, k))
    w = jax.random.normal(keys[1], (8, k)) * 0.1

    # quantize main (aligned) part; residual stays dense fp32 on host —
    # build the QTensor over the aligned prefix only, as the engine does
    k_main = (k // 32) * 32
    wq = quantize_q8_0(w[:, :k_main])

    def main_fn(xm, wqm):
        return ref.q8_matmul_ref(xm, wqm)

    got_main = mixed_matmul_q8(x[:, :k_main], wq, burst, main_fn)
    want_main = ref.q8_matmul_ref(x[:, :k_main], wq)
    np.testing.assert_allclose(got_main, want_main, rtol=1e-4, atol=1e-4)


def test_residual_fraction_monotone_in_burst():
    """Bigger bursts strand at least as much residual work (paper's
    three-way trade-off, §3.2) for any fixed length."""
    for length in (100, 383, 1000):
        prev = -1.0
        for burst in (8, 16, 32, 64):
            frac = residual_fraction(length, burst)
            assert frac >= 0.0
        # burst > length -> everything is residual
        assert residual_fraction(length, length + 1) == 1.0
        assert residual_fraction(length, length) == 0.0
