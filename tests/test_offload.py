"""Offload dispatcher: per-invocation decisions, stats, numerical parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import OffloadEngine, OffloadStats
from repro.core.qformats import quantize_q8_0


def test_dispatch_decision_by_budget():
    eng = OffloadEngine(vmem_budget_kb=1)      # 1 KB budget
    assert eng.should_offload(m=8, k=32, n=8)          # 512 B activation
    assert not eng.should_offload(m=1024, k=1024, n=8)  # 2 MB > 1 KB


def test_linear_parity_and_stats():
    eng = OffloadEngine(vmem_budget_kb=8 * 1024, burst=32,
                        prefer_pallas=True, interpret=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 96))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 96)) * 0.1
    y = eng.linear(x, w, name="test")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T),
                               rtol=2e-2, atol=2e-2)
    assert eng.stats.offloaded_calls == 1
    assert eng.stats.by_kernel["test"] == 1


def test_linear_q8_parity():
    eng = OffloadEngine(burst=32, prefer_pallas=True, interpret=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 64)) * 0.1
    wq = quantize_q8_0(w)
    y = eng.linear(x, wq, name="q8")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T),
                               rtol=2e-2, atol=2e-2)


def test_fallback_accounting():
    eng = OffloadEngine(vmem_budget_kb=1, burst=32, prefer_pallas=False)
    x = jnp.ones((512, 512))
    w = jnp.ones((16, 512))
    eng.linear(x, w)
    assert eng.stats.fallback_calls == 1
    assert eng.stats.offloaded_calls == 0
    assert eng.stats.offload_rate() == 0.0


def test_stats_flop_rates():
    s = OffloadStats(offloaded_calls=3, fallback_calls=1,
                     offloaded_flops=300, fallback_flops=100)
    assert s.offload_rate() == 0.75
    assert s.offload_flop_rate() == 0.75
