"""Property suite for the multi-entry paged window scatter/gather
(DESIGN.md §15.2/§17.4, via the tests/_hyp.py optional-hypothesis shim):
writing a W-token verify window through a block table and gathering it
back must be bit-identical to ``_cache_update`` on the contiguous
layout — for ANY in-contract (page_size, W, length) combination,
including windows that straddle page boundaries and W > page_size.
Pinned deterministic examples cover the named edge cases so the
contract holds even when hypothesis is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.models.attention import (_cache_update, paged_window_gather,
                                    paged_window_update)

HKV, HD = 2, 3


def _arena(b, n_log, ps, seed):
    """A private-page arena: B rows x n_log logical pages, each row's
    table pointing at distinct physical pages (page 0 is the trash
    page), pre-filled with a deterministic pattern."""
    rng = np.random.default_rng(seed)
    n_phys = 1 + b * n_log
    pages = rng.standard_normal((n_phys, ps, HKV, HD)).astype(np.float32)
    bt = (1 + np.arange(b * n_log)).reshape(b, n_log).astype(np.int32)
    return jnp.asarray(pages), jnp.asarray(bt)


def _run_pair(ps, n_log, lengths, w, seed):
    """Drive both layouts from the same state and window; return
    (contiguous buffer, gathered paged view) for comparison."""
    b = len(lengths)
    pages, bt = _arena(b, n_log, ps, seed)
    length = jnp.asarray(np.asarray(lengths, np.int32))
    rng = np.random.default_rng(seed + 1)
    val = jnp.asarray(rng.standard_normal((b, w, HKV, HD)).astype(np.float32))

    # contiguous reference: same initial contents via the gather identity
    buf = paged_window_gather(pages, bt)
    ref = _cache_update(buf, val, length)

    got_pages = paged_window_update(pages, bt, length, val)
    got = paged_window_gather(got_pages, bt)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    # untouched physical pages (trash page 0 included) stay bit-identical
    touched = set()
    for row, ln in enumerate(lengths):
        for j in range(w):
            touched.add(int(bt[row, (ln + j) // ps]))
    untouched = sorted(set(range(pages.shape[0])) - touched)
    np.testing.assert_array_equal(np.asarray(pages)[untouched],
                                  np.asarray(got_pages)[untouched])


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=6),      # page_size
       st.integers(min_value=1, max_value=5),      # logical pages per row
       st.integers(min_value=1, max_value=8),      # window width W
       st.integers(min_value=1, max_value=4),      # batch rows
       st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=0, max_value=10 ** 6))
def test_paged_window_matches_contiguous(ps, n_log, w, b, lseed, seed):
    """Property: paged scatter+gather == contiguous ``_cache_update``
    for any in-contract geometry (``length + W <= capacity``), any
    per-row lengths, including boundary-straddling and W > page_size."""
    cap = n_log * ps
    if w > cap:
        w = cap
    rng = np.random.default_rng(lseed)
    lengths = rng.integers(0, cap - w + 1, size=b).tolist()
    _run_pair(ps, n_log, lengths, w, seed)


@pytest.mark.parametrize("ps,n_log,lengths,w", [
    (4, 3, [3, 0], 3),     # window straddles a page boundary (3..5)
    (2, 5, [1, 4], 5),     # W > page_size: window spans 3+ pages
    (4, 2, [4, 0], 4),     # window starts exactly on a boundary
    (1, 6, [2, 5], 1),     # degenerate page_size=1, plain W=1 step
    (5, 2, [5, 3], 5),     # fills the second page end-to-end
])
def test_paged_window_pinned_examples(ps, n_log, lengths, w):
    """The named edge cases, pinned: these run even without hypothesis
    (the shim skip-marks the property test when it is absent)."""
    _run_pair(ps, n_log, lengths, w, seed=7)


def test_paged_window_rows_independent():
    """Rows with private pages never interfere: writing row 0's window
    leaves row 1's gathered view bit-identical."""
    pages, bt = _arena(2, 3, 4, seed=11)
    length = jnp.asarray(np.asarray([2, 6], np.int32))
    val = jnp.asarray(np.zeros((2, 3, HKV, HD), np.float32))
    before = np.asarray(paged_window_gather(pages, bt))
    out = paged_window_update(pages, bt, length,
                              val.at[1].set(np.nan))  # row1 writes NaN
    after = np.asarray(paged_window_gather(out, bt))
    # row 0's window is zeros, the rest of row 0 untouched
    np.testing.assert_array_equal(after[0, 2:5], np.zeros((3, HKV, HD)))
    np.testing.assert_array_equal(after[0, :2], before[0, :2])
    np.testing.assert_array_equal(after[0, 5:], before[0, 5:])
    # row 1's NaNs landed only in row 1's window
    assert np.isnan(after[1, 6:9]).all()
    np.testing.assert_array_equal(after[1, :6], before[1, :6])
