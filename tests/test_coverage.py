"""LMM coverage model (paper Tables 2/6): CDF structure + invariants.

Property-based variants needing ``hypothesis`` (requirements-dev.txt) live
in test_coverage_properties.py so this module collects everywhere."""
import pytest

from repro.configs.registry import get_config
from repro.core.coverage import (
    AGG_UNITS, LMM_SIZES_KB, MulMat, coverage, coverage_cdf,
    enumerate_lm, enumerate_whisper, fallback_time_fraction, fits)


@pytest.fixture(scope="module")
def whisper_mulmats():
    return enumerate_whisper(get_config("whisper-tiny"))


def test_invocation_scale_matches_paper(whisper_mulmats):
    """§5.4: tiny has ~477k dot-product invocations for the jfk.wav run.
    Our enumerator counts row-dot-products; same order of magnitude."""
    dots = sum(m.dots for m in whisper_mulmats)
    assert 1e5 < dots < 1e8


def test_table2_structure(whisper_mulmats):
    """Optimized coverage: high (>80%) at 32 KB, 100% by 256 KB.
    Baseline (padded): far lower at small sizes — the 67x claim's shape."""
    cdf = dict((s, (b, o)) for s, b, o in coverage_cdf(whisper_mulmats))
    assert cdf[32][1] > 0.80                 # optimized 32KB covers most
    assert cdf[256][1] == pytest.approx(1.0)
    assert cdf[32][0] < cdf[32][1]           # padding strictly hurts
    assert cdf[8][1] > 0.3                   # small dot products fit early


def test_coverage_monotone_in_budget(whisper_mulmats):
    prev_b = prev_o = -1.0
    for s, b, o in coverage_cdf(whisper_mulmats):
        assert b >= prev_b and o >= prev_o
        prev_b, prev_o = b, o


def test_base_small_need_64kb():
    """Table 6: tiny saturates at 32 KB; base/small only at 64 KB."""
    tiny = enumerate_whisper(get_config("whisper-tiny"))
    base = enumerate_whisper(get_config("whisper-base"))
    small = enumerate_whisper(get_config("whisper-small"))
    cov = lambda ms, kb: coverage(ms, kb)
    assert cov(tiny, 32) > 0.8
    assert cov(base, 32) < cov(tiny, 32)     # the paper's coverage drop
    assert cov(base, 64) > 0.9               # 64 KB restores >94% (paper)
    assert cov(small, 64) > 0.9
    assert cov(small, 32) < 0.8


def test_fits_monotone_spot_checks():
    """Deterministic spot-check of the property in
    test_coverage_properties.py: fits(8KB) implies fits(256KB)."""
    for m, k, units in [(1, 1, 1), (1500, 384, 46), (2000, 2000, 1),
                        (7, 31, 64)]:
        mm = MulMat("x", m=m, k=k, n=8)
        assert fits(mm, 256, agg_units=units) or not fits(mm, 8,
                                                          agg_units=units)


def test_fallback_latency_model_monotone():
    ms = enumerate_whisper(get_config("whisper-small"))
    ts = [fallback_time_fraction(ms, kb) for kb in LMM_SIZES_KB]
    for a, b in zip(ts, ts[1:]):
        assert b <= a + 1e-12   # more LMM never slower (Fig 11 trend)


def test_lm_enumerator_counts():
    cfg = get_config("phi3-mini-3.8b")
    ms = enumerate_lm(cfg, seq=128, new_tokens=4, batch=2)
    assert any(m.name == "vocab" for m in ms)
    assert any(m.name.startswith("dec.") for m in ms)
    total_flops = sum(m.flops for m in ms)
    assert total_flops > 0
    cfg_moe = get_config("olmoe-1b-7b")
    ms2 = enumerate_lm(cfg_moe, seq=128)
    assert any(m.name == "moe.expert" for m in ms2)
