"""Paged KV-cache pool + scheduler (DESIGN.md §15): the paged scheduler
emits token streams identical to one-at-a-time decode through prefix
sharing, oversubscription, and preempt-and-recompute; plan keys carry the
page geometry so paged and contiguous programs never collide; eviction
returns pages before the next admit pass (EOS-reuse regression); and the
fixed-shape arenas keep the engine's decode step at zero retraces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.paging import PagedKVPool

N_FRAMES = 8


@pytest.fixture(scope="module")
def whisper_setup():
    cfg = get_smoke_config("whisper-tiny")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    return cfg, params


@pytest.fixture(scope="module")
def ref_engine(whisper_setup):
    """Reference engine for one-at-a-time token streams — kept separate
    from the engines under test so their step-trace counters stay
    untouched by ref transcribes."""
    cfg, params = whisper_setup
    return ServeEngine(cfg, params, max_len=32, quant="none", eos_id=-1)


def _mels(cfg, n, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.standard_normal((1, N_FRAMES, cfg.n_mels)).astype(np.float32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Pool construction contracts
# ---------------------------------------------------------------------------
def test_pool_rejects_bad_geometry(whisper_setup):
    cfg, params = whisper_setup
    with pytest.raises(ValueError, match="power of two"):
        PagedKVPool(cfg, params, 2, 16, n_frames=N_FRAMES, page_size=3)
    with pytest.raises(ValueError, match="divide n_frames"):
        PagedKVPool(cfg, params, 2, 16, n_frames=N_FRAMES,
                    cross_page_size=3)
    with pytest.raises(ValueError, match="n_frames"):
        PagedKVPool(cfg, params, 2, 16)                # audio needs frames


def test_pool_rejects_lm_families(whisper_setup):
    cfg = get_smoke_config("qwen2.5-14b")
    with pytest.raises(NotImplementedError):
        PagedKVPool(cfg, None, 2, 16, n_frames=N_FRAMES)


def test_pool_defaults_cover_full_occupancy(whisper_setup):
    """Default geometry = no oversubscription: every slot can hold
    max_len self pages and a private cross block, plus the trash page."""
    cfg, params = whisper_setup
    pool = PagedKVPool(cfg, params, 3, 16, n_frames=N_FRAMES, page_size=4)
    assert pool.max_pages == 4
    assert pool.n_pages == 1 + 3 * 4
    assert pool.n_cross_per_req == 1                   # one page per utterance
    assert pool.n_cross_pages == 1 + 3
    assert pool.plan_geometry == (4, 13, N_FRAMES, 4)
    # used bytes counts real allocations, not slot capacity
    assert pool.used_kv_bytes() == 0
    pool.alloc_self_page(pool.acquire())
    assert pool.used_kv_bytes() == pool.page_bytes


# ---------------------------------------------------------------------------
# Parity: paged scheduler vs one-at-a-time decode
# ---------------------------------------------------------------------------
def test_paged_matches_one_at_a_time_with_sharing(whisper_setup, ref_engine):
    """The §15 contract: paged continuous decode (with duplicate
    utterances landing on shared cross pages) emits, per request, exactly
    the token stream a batch-1 transcribe produces — at one step trace."""
    cfg, params = whisper_setup
    m = _mels(cfg, 3)
    # staggered budgets keep each duplicate resident WITH its partner
    # (sharing is by live refcount — a retired digest is a miss again)
    trace = [(m[0], 6), (m[1], 6), (m[0], 3), (m[1], 3), (m[2], 3)]
    refs = [ref_engine.transcribe(mel, max_new=mn)[0].tokens
            for mel, mn in trace]
    eng = ServeEngine(cfg, params, max_len=32, quant="none", eos_id=-1)
    sched = eng.paged_scheduler(n_slots=3, n_frames=N_FRAMES, page_size=4)
    rids = [sched.submit(mel, max_new=mn) for mel, mn in trace]
    res = sched.run()
    for rid, ref in zip(rids, refs):
        assert res[rid].tokens == ref
    assert sched.shared_hits == 2
    assert sched.preemptions == 0                      # default geometry
    assert sched.step_traces == 1                      # zero retraces
    # finished requests dropped their replay payloads
    assert not sched._payloads


def test_preemption_replays_token_exactly(whisper_setup, ref_engine):
    """Oversubscription contract (§15.5): a self arena too small for the
    concurrent budgets forces preempt-and-recompute, and every stream is
    STILL token-exact — greedy replay is deterministic. PDP attribution
    survives: per-request energies sum to the batch total."""
    cfg, params = whisper_setup
    mels = _mels(cfg, 3)
    off = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=32, quant="q8_0", offload=off,
                      eos_id=-1)
    # refs on the SAME quant (q8_0 shifts numerics vs the dense ref
    # engine); this traces the batch-1 step once, counted below
    refs = [eng.transcribe(m, max_new=6)[0].tokens for m in mels]
    traces0 = eng._step_traces
    # 4 allocatable self pages for 3 slots x ceil(7/4)=2 pages -> starved
    sched = eng.paged_scheduler(n_slots=3, n_frames=N_FRAMES, page_size=4,
                                n_pages=5)
    rids = [sched.submit(m, max_new=6) for m in mels]
    res = sched.run()
    for rid, ref in zip(rids, refs):
        assert res[rid].tokens == ref
        assert res[rid].steps == 6
    assert sched.preemptions > 0
    # one new trace (the paged pool-width step); replay uses decode_jit
    assert eng._step_traces == traces0 + 1
    att = sched.attribution()
    assert sum(att["per_request_pdp_j"].values()) == \
        pytest.approx(att["batch_pdp_j"], rel=1e-9)


def test_shared_hit_skips_prefill_and_its_ledger_commit(whisper_setup):
    """A prefix-share admission runs no encoder: one prefill ledger
    commit for two identical utterances, and no plan work attributed to
    the hit (the PDP invariant would break otherwise)."""
    cfg, params = whisper_setup
    off = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0", offload=off,
                      eos_id=-1)
    mel = _mels(cfg, 1)[0]
    sched = eng.paged_scheduler(n_slots=2, n_frames=N_FRAMES, page_size=4)
    r0 = sched.submit(mel, max_new=3)
    r1 = sched.submit(mel.copy(), max_new=3)           # same bytes, new array
    n_steps = 0
    while sched.n_queued or sched.n_active:
        sched.admit()
        if sched.decode_step():
            n_steps += 1
    assert sched.shared_hits == 1
    # 1 prefill commit (not 2) + one commit per executed batch step
    assert off.ledger.commits == 1 + n_steps
    assert sched.finished[r0].tokens == sched.finished[r1].tokens
    # last release retired the shared digest with its pages
    assert not sched.pool._shared


def test_plan_keys_carry_page_geometry(whisper_setup):
    """§15.5: the paged step's plan key embeds the page geometry, so
    paged and contiguous programs at the SAME (batch, frames) point hold
    disjoint PlanCache entries — no cross-mode plan reuse."""
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0",
                      offload=OffloadEngine(prefer_pallas=False), eos_id=-1)
    k_contig = eng._key("step", 2, N_FRAMES)
    k_paged = eng._key("step", 2, N_FRAMES, pages=(4, 9, N_FRAMES, 3))
    assert k_contig != k_paged
    k_other = eng._key("step", 2, N_FRAMES, pages=(8, 9, N_FRAMES, 3))
    assert k_paged != k_other                          # geometry-sensitive
    mel = _mels(cfg, 1)[0]
    sched_c = eng.scheduler(n_slots=2, n_frames=N_FRAMES)
    sched_c.submit(mel, max_new=2)
    sched_c.run()
    n_plans = len(eng._plans)
    sched_p = eng.paged_scheduler(n_slots=2, n_frames=N_FRAMES, page_size=4)
    sched_p.submit(mel, max_new=2)
    sched_p.run()
    # the paged step recorded its own plan; batch-1 prefill was shared
    assert len(eng._plans) == n_plans + 1


# ---------------------------------------------------------------------------
# EOS-reuse regression (ISSUE 7 satellite): freed pages admit the queue
# head in the SAME scheduler pass
# ---------------------------------------------------------------------------
def test_eviction_frees_pages_for_immediate_admission(whisper_setup,
                                                      ref_engine):
    """With a full arena and a queued request, the admit pass right after
    an EOS eviction admits it — pages return to the allocators before
    release() returns, not at some later sweep."""
    cfg, params = whisper_setup
    mel = _mels(cfg, 1)[0]
    first = ref_engine.transcribe(mel, max_new=3)[0].tokens[0]
    eng = ServeEngine(cfg, params, max_len=16, quant="none",
                      eos_id=int(first))
    # one slot's worth of pages: 1 trash + 1 self, 1 trash + 1 cross
    sched = eng.paged_scheduler(n_slots=2, n_frames=N_FRAMES, page_size=4,
                                n_pages=2, n_cross_pages=2)
    r0 = sched.submit(mel, max_new=8)
    r1 = sched.submit(_mels(cfg, 2)[1], max_new=8)     # distinct utterance
    assert sched.admit() == [r0]                       # arena full: r1 waits
    assert sched.n_queued == 1
    assert not sched.pool.can_alloc(1, sched.pool.n_cross_per_req)
    events = sched.decode_step()                       # r0 hits EOS, evicted
    assert any(ev.rid == r0 and ev.done for ev in events)
    assert sched.admit() == [r1]                       # freed pages reused NOW
    assert sched.finished[r0].tokens == [int(first)]


def test_arena_too_small_raises_instead_of_livelock(whisper_setup):
    """A request that cannot fit even with every active slot preempted is
    a configuration error, not an infinite admission stall."""
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=16, quant="none", eos_id=-1)
    # cross arena: 1 trash + 1 page, but cross_page_size=4 -> 2 pages/req
    sched = eng.paged_scheduler(n_slots=2, n_frames=N_FRAMES, page_size=4,
                                cross_page_size=4, n_cross_pages=2)
    sched.submit(_mels(cfg, 1)[0], max_new=2)
    with pytest.raises(RuntimeError, match="arena too small"):
        sched.run()


# ---------------------------------------------------------------------------
# Zero retraces across paged schedules
# ---------------------------------------------------------------------------
def test_zero_retraces_across_paged_schedules(whisper_setup):
    """Admissions, share hits, evictions, and preemptions are host table
    edits + pre-traced splices: the engine's step_fn traces exactly once
    per page geometry across any schedule."""
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=32, quant="none", eos_id=-1)
    mels = _mels(cfg, 4)
    sched = eng.paged_scheduler(n_slots=2, n_frames=N_FRAMES, page_size=4,
                                n_pages=5)             # tight: preempts
    sched.submit(mels[0], max_new=2)
    sched.run()                                        # warmup: one trace
    traces0 = eng._step_traces
    assert traces0 == 1
    for m in mels[1:3]:
        sched.submit(m, max_new=5)
    sched.run()
    for m in (mels[3], mels[3]):                       # second wave, share hit
        sched.submit(m, max_new=3)                     # (co-resident duplicate)
    sched.run()
    assert eng._step_traces == traces0                 # ZERO retraces
    assert sched.shared_hits >= 1


def test_paged_insert_roundtrips_prefill_state(whisper_setup):
    """Splicing a batch-1 prefill into the arenas and gathering it back
    through the block table reproduces the contiguous cache bytes — the
    §15.2 layout equivalence behind token parity."""
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=16, quant="none", eos_id=-1)
    pool = PagedKVPool(cfg, params, n_slots=2, max_len=16,
                       n_frames=N_FRAMES, page_size=4)
    mel = jnp.asarray(_mels(cfg, 1)[0])
    _, req = eng._prefill_jit(eng._serve_params, mel)
    slot = pool.acquire()
    pool.alloc_cross_pages(slot, "d0")
    pool.alloc_self_page(slot)
    pool.sync()
    pool.insert(slot, req)
    ls = pool.state.layer_states
    # cross pages hold the encoder KV, bit-for-bit
    got_k = np.asarray(ls.cross_k[:, pool._ct[slot]]).reshape(
        cfg.num_layers, N_FRAMES, cfg.num_kv_heads, cfg.head_dim)
    want_k = np.asarray(req.layer_states.cross_kv[0][:, 0]).astype(
        got_k.dtype)
    np.testing.assert_array_equal(got_k, want_k)
    # per-slot length/step counters match the request's
    assert int(ls.length[0, slot]) == \
        int(req.layer_states.self_kv.length[0])
    assert int(pool.state.step[slot]) == int(req.step)
