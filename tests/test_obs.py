"""Observability subsystem (DESIGN.md §16): tracer span/phase semantics
under arbitrary lifecycle interleavings, the exact ledger-delta
attribution invariant (§16.2), histogram/percentile soundness, the
structural no-allocation guarantee of disabled telemetry, and the
Perfetto/Prometheus export contract (validated with the same
tools/check_trace.py CI runs)."""
import importlib.util
import json
import os

import jax
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro import obs
from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler

N_FRAMES = 8


def _load_check_trace():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "tools", "check_trace.py")
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def whisper_setup():
    cfg = get_smoke_config("whisper-tiny")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    return cfg, params


def _mels(cfg, n, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.standard_normal((1, N_FRAMES, cfg.n_mels)).astype(np.float32)
            for _ in range(n)]


class _VClock:
    """Deterministic strictly-increasing clock for tracer tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-6
        return self.t


# ---------------------------------------------------------------------------
# Tracer: stack spans + lifecycle phases
# ---------------------------------------------------------------------------
def test_stack_spans_nest_and_close():
    tr = obs.Tracer(clock=_VClock())
    with tr.span("outer", cat="host"):
        with tr.span("inner", cat="host", args={"k": 1}):
            pass
    assert tr.all_closed()
    assert tr.check_nesting() == []
    # journal order is close order: inner closes first
    assert [s.name for s in tr.spans] == ["inner", "outer"]
    inner, outer = tr.spans
    assert inner.args == {"k": 1}
    assert outer.ts_us <= inner.ts_us
    assert outer.ts_us + outer.dur_us >= inner.ts_us + inner.dur_us


def test_span_closes_on_exception():
    tr = obs.Tracer(clock=_VClock())
    with pytest.raises(ValueError):
        with tr.span("doomed"):
            raise ValueError("boom")
    assert tr.all_closed()
    assert [s.name for s in tr.spans] == ["doomed"]


def test_phase_lifecycle_and_rid_closure():
    tr = obs.Tracer(clock=_VClock())
    tr.begin(0, "queued")
    tr.begin(0, "decode")
    tr.end(0, "queued")
    assert 0 not in tr.rids_closed          # decode still open
    tr.end(0, "decode", steps=4)
    assert tr.rids_closed == {0} == tr.rids_opened
    assert tr.all_closed()
    decode = [s for s in tr.spans if s.name == "decode"][0]
    assert decode.args["steps"] == 4
    assert decode.track == obs.request_track(0)


def test_phase_double_begin_and_unopened_end_raise():
    tr = obs.Tracer(clock=_VClock())
    tr.begin(1, "queued")
    with pytest.raises(RuntimeError):
        tr.begin(1, "queued")
    with pytest.raises(RuntimeError):
        tr.end(1, "decode")
    assert tr.open_phases() == [(1, "queued")]
    assert not tr.all_closed()


def test_instant_events_pick_request_track():
    tr = obs.Tracer(clock=_VClock())
    tr.instant("submit", rid=3)
    tr.instant("plan_build")
    a, b = tr.events
    assert (a.track, b.track) == (obs.request_track(3), obs.ENGINE_TRACK)
    assert a.instant and b.instant


# Legal per-rid lifecycle transitions, mirroring the schedulers: queued
# -> admit (decode opens) -> finish, or preempt (back to queued) and
# around again. The property: ANY interleaving of these ops across rids
# leaves a tracer whose phases all close and whose spans nest.
_ADMIT, _PREEMPT, _FINISH = 0, 1, 2


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2)),
                max_size=60))
def test_phase_closure_under_any_interleaving(ops):
    tr = obs.Tracer(clock=_VClock())
    state = {}                              # rid -> "queued" | "decode"
    for rid, op in ops:
        if rid not in state:
            tr.instant("submit", rid=rid)
            tr.begin(rid, "queued")
            state[rid] = "queued"
        if op == _ADMIT and state[rid] == "queued":
            tr.end(rid, "queued")
            tr.begin(rid, "decode")
            state[rid] = "decode"
        elif op == _PREEMPT and state[rid] == "decode":
            tr.instant("preempt", rid=rid)
            tr.end(rid, "decode")
            tr.begin(rid, "queued")
            state[rid] = "queued"
        elif op == _FINISH and state[rid] == "decode":
            tr.end(rid, "decode")
            del state[rid]
    # drain the stragglers the way the scheduler drains its queue
    for rid, phase in sorted(state.items()):
        if phase == "queued":
            tr.end(rid, "queued")
            tr.begin(rid, "decode")
        tr.end(rid, "decode")
    assert tr.all_closed()
    assert tr.rids_closed == tr.rids_opened
    assert tr.check_nesting() == []
    # the export of a fully-closed tracer has no dangling "B" events
    evs = obs.export.trace_events(tr)["traceEvents"]
    assert not [e for e in evs if e["ph"] == "B"]


# ---------------------------------------------------------------------------
# Metrics: histogram + percentile
# ---------------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(min_value=0, max_value=1e6,
                          allow_nan=False, allow_infinity=False),
                max_size=200))
def test_histogram_bucket_sum_invariant(values):
    h = obs.Histogram("h", buckets=obs.LATENCY_BUCKETS_S)
    for v in values:
        h.observe(v)
    assert sum(h.bucket_counts) == h.count == len(values)
    snap = h.snapshot()
    assert sum(c for _, c in snap["buckets"]) == snap["count"]


@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=100),
       st.floats(min_value=0, max_value=100))
def test_percentile_matches_numpy(values, q):
    assert obs.percentile(values, q) == \
        pytest.approx(float(np.percentile(values, q)), rel=1e-9, abs=1e-9)


def test_histogram_bucket_sum_deterministic():
    h = obs.Histogram("h", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0, 1e9):   # incl. two +Inf-bucket hits
        h.observe(v)
    assert sum(h.bucket_counts) == h.count == 5
    assert h.bucket_counts == [1, 1, 1, 2]


def test_tracked_histogram_percentiles_exact():
    h = obs.Histogram("h", track_values=True)
    xs = [0.001 * (i + 1) for i in range(20)]
    for v in xs:
        h.observe(v)
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(float(np.percentile(xs, q)))


def test_prometheus_exposition_cumulative_buckets():
    r = obs.MetricsRegistry()
    h = r.histogram("repro_t_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    r.counter("repro_n_total").inc(2, kind="a")
    text = r.render_prometheus()
    lines = text.splitlines()
    bucket_lines = [l for l in lines if l.startswith("repro_t_seconds_bucket")]
    cums = [int(l.rsplit(" ", 1)[1]) for l in bucket_lines]
    assert cums == sorted(cums) and cums[-1] == 3   # cumulative, ends at count
    assert 'le="+Inf"' in bucket_lines[-1]
    assert 'repro_n_total{kind="a"} 2' in lines


# ---------------------------------------------------------------------------
# Telemetry: ledger spans (§16.2)
# ---------------------------------------------------------------------------
def test_ledger_spans_do_not_nest():
    tele = obs.Telemetry(clock=_VClock())
    with pytest.raises(RuntimeError):
        with tele.span("a", ledger=True):
            with tele.span("b", ledger=True):
                pass
    tele2 = obs.Telemetry(clock=_VClock())
    h = tele2.ledger_open()
    with pytest.raises(RuntimeError):
        tele2.ledger_open()
    tele2.ledger_close(h, "a")
    with tele2.span("c", ledger=True):      # guard released after close
        pass


def test_ledger_open_close_matches_with_form():
    """The hot-path pair and the with-form record the same span shape and
    claim the same delta (here: zero, no ledger bound)."""
    tele = obs.Telemetry(clock=_VClock())
    with tele.span("step", cat="step", ledger=True, args={"active": 2}):
        pass
    h = tele.ledger_open()
    tele.ledger_close(h, "step", cat="step", args={"active": 2})
    a, b = tele.tracer.spans
    assert a.name == b.name == "step"
    assert a.args == b.args == {"active": 2, "flops": 0, "calls": 0}
    assert tele.ledger_consistent()["exact"]


# ---------------------------------------------------------------------------
# Disabled telemetry allocates nothing (structural)
# ---------------------------------------------------------------------------
def test_disabled_telemetry_allocates_no_obs_objects(whisper_setup,
                                                     monkeypatch):
    """telemetry=None serving must never construct a Telemetry, Tracer,
    or Span — every instrumentation site is one ``is not None`` test.
    Proven structurally: constructors are patched to raise, then a full
    drain runs."""
    cfg, params = whisper_setup

    def _bomb(*a, **k):
        raise AssertionError("obs object constructed on the disabled path")

    import repro.obs.trace as trace_mod
    monkeypatch.setattr(obs.Telemetry, "__init__", _bomb)
    monkeypatch.setattr(trace_mod.Tracer, "__init__", _bomb)
    monkeypatch.setattr(trace_mod.Span, "__init__", _bomb)
    eng = ServeEngine(cfg, params, max_len=16, quant="none", eos_id=-1)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    for m in _mels(cfg, 3):
        sched.submit(m, max_new=3)
    res = sched.run()
    assert len(res) == 3
    assert all(len(r.tokens) == 3 for r in res.values())


# ---------------------------------------------------------------------------
# End-to-end: instrumented drains hold the §16.2 invariants
# ---------------------------------------------------------------------------
def test_continuous_drain_exact_attribution(whisper_setup, tmp_path):
    cfg, params = whisper_setup
    tele = obs.Telemetry()
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0",
                      offload=OffloadEngine(interpret=True,
                                            prefer_pallas=False),
                      eos_id=-1, telemetry=tele)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    rids = [sched.submit(m, max_new=4) for m in _mels(cfg, 4)]
    res = sched.run()
    assert set(res) == set(rids)

    cons = tele.ledger_consistent()
    assert cons["exact"], cons              # integer equality, not approx
    assert cons["claimed_flops"] > 0 and cons["claimed_calls"] > 0
    assert tele.tracer.all_closed()
    assert tele.tracer.check_nesting() == []
    assert tele.tracer.rids_closed == set(rids)

    # run() flushed the buffered step metrics into the registry
    m = tele.metrics
    assert m.counter("repro_tokens_total").value() == 16
    assert m.counter("repro_requests_submitted_total").value() == 4
    assert m.counter("repro_requests_finished_total").value() == 4
    assert m.histogram("repro_ttft_seconds").count == 4
    assert m.histogram("repro_step_seconds").count == \
        sum(1 for s in tele.tracer.spans if s.name == "decode_step")

    # exports: trace passes the CI validator, snapshot is JSON-safe
    trace_path = tmp_path / "t.json"
    tele.write_trace(str(trace_path))
    with open(trace_path) as f:
        assert _load_check_trace().validate(json.load(f)) == []
    json.dumps(tele.snapshot(), default=str)
    text = tele.write_metrics(str(tmp_path / "m.prom"))
    assert os.path.exists(text)


def test_paged_drain_with_preemption_and_sharing(whisper_setup):
    """The §16.2 invariants survive the paged scheduler's hard paths:
    prefix-shared admissions, CoW splits, preempt-and-replay."""
    cfg, params = whisper_setup
    tele = obs.Telemetry()
    eng = ServeEngine(cfg, params, max_len=32, quant="q8_0",
                      offload=OffloadEngine(interpret=True,
                                            prefer_pallas=False),
                      eos_id=-1, telemetry=tele)
    shared = _mels(cfg, 1)[0]
    # starved self arena (test_paging.py geometry) -> preemptions
    sched = eng.paged_scheduler(n_slots=3, n_frames=N_FRAMES, page_size=4,
                                n_pages=5)
    rids = [sched.submit(shared, max_new=6) for _ in range(3)]
    res = sched.run()
    assert set(res) == set(rids)
    assert sched.preemptions > 0

    cons = tele.ledger_consistent()
    assert cons["exact"], cons
    assert tele.tracer.all_closed()
    assert tele.tracer.check_nesting() == []
    names = {e.name for e in tele.tracer.events}
    assert "preempt" in names and "replay" in names
    assert "prefix_hit" in names            # identical mels share pages
    m = tele.metrics
    assert m.counter("repro_preemptions_total").value() == sched.preemptions
    assert m.counter("repro_replays_total").value() > 0
    # replay re-decode is claimed by the replay ledger span, so the
    # per-request "decode" phases may open/close more than once per rid
    assert tele.tracer.rids_closed == set(rids)


def test_attribution_reports_lifecycle_timings(whisper_setup):
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=16, quant="none", eos_id=-1)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    rids = [sched.submit(m, max_new=3) for m in _mels(cfg, 3)]
    while sched.n_queued or sched.n_active:
        sched.admit()
        sched.decode_step()
    att = sched.attribution()
    assert set(att["per_request_queue_wait_s"]) == set(rids)
    assert set(att["per_request_ttft_s"]) == set(rids)
    assert all(v >= 0 for v in att["per_request_queue_wait_s"].values())
    # TTFT includes queue wait + prefill, so it dominates the wait
    assert all(att["per_request_ttft_s"][r] >=
               att["per_request_queue_wait_s"][r] for r in rids)
