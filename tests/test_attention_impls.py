"""Flash (k-blocked online softmax) vs chunked-baseline attention equality,
fwd and bwd — the §Perf optimization must be a pure re-scheduling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import model as M
from repro.models.attention import _chunked_attention, _flash_attention


@pytest.mark.parametrize("b,sq,sk,hq,hkv,d,causal", [
    (2, 64, 64, 4, 2, 16, True),
    (1, 128, 128, 8, 8, 32, True),
    (2, 32, 96, 4, 1, 16, False),     # cross-attention shape
    (2, 1, 64, 4, 2, 16, True),       # single-query
    (2, 48, 48, 4, 4, 16, True),      # ragged vs k_chunk
])
def test_flash_matches_chunked(b, sq, sk, hq, hkv, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(sq + sk), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), jnp.float32)
    a = _chunked_attention(q, k, v, causal, chunk=32)
    f = _flash_attention(q, k, v, causal, chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                               rtol=1e-5, atol=1e-5)


def test_flash_gradients_match():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.float32)
    g1 = jax.grad(lambda q: jnp.sum(
        _chunked_attention(q, k, v, True, 32) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(
        _flash_attention(q, k, v, True, 32, 32) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-4)


def test_model_level_flash_equivalence():
    """Whole-model logits identical under attn_impl switch."""
    cfg = get_smoke_config("phi3-mini-3.8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = M.forward(params, cfg, batch)
    cfg_f = dataclasses.replace(cfg, attn_impl="flash")
    l2, _ = M.forward(params, cfg_f, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)
