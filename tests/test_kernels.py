"""Per-kernel validation: Pallas kernels (interpret=True on CPU) swept over
shapes/dtypes and asserted allclose against the pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qformats import quantize_q8_0
from repro.kernels import ref
from repro.kernels.bf16_matmul import bf16_matmul
from repro.kernels.q8_matmul import q8_matmul, vmem_claim_bytes
from repro.kernels.q8_matvec import q8_matvec
from repro.kernels import ops


def _w(key, n, k, scale=0.05):
    return jax.random.normal(key, (n, k)) * scale


# ---------------------------------------------------------------------------
# q8_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (8, 64, 64, 8, 64, 32),
    (16, 128, 256, 16, 64, 64),
    (32, 256, 128, 16, 128, 128),
    (128, 256, 512, 64, 128, 256),     # default-ish MXU tiling
    (8, 512, 96, 8, 256, 32),          # skinny K with whole blocks
])
def test_q8_matmul_vs_ref(m, n, k, bm, bn, bk):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * n + k))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    wq = quantize_q8_0(_w(kw, n, k))
    got = q8_matmul(x, wq.flat_qs(), wq.scales, block_m=bm, block_n=bn,
                    block_k=bk, interpret=True)
    want = ref.q8_matmul_ref(x, wq)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_q8_matmul_dtypes(xdtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 0.5).astype(xdtype)
    wq = quantize_q8_0(_w(jax.random.PRNGKey(1), 64, 64))
    got = q8_matmul(x, wq.flat_qs(), wq.scales, block_m=8, block_n=64,
                    block_k=32, interpret=True)
    want = ref.q8_matmul_ref(x.astype(jnp.float32), wq)
    tol = 2e-2 if xdtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    assert got.dtype == jnp.float32


def test_q8_matmul_rejects_partial_blocks():
    x = jnp.ones((16, 64))
    wq = quantize_q8_0(jnp.ones((64, 64)))
    with pytest.raises(ValueError):
        q8_matmul(x, wq.flat_qs(), wq.scales, block_m=8, block_n=64,
                  block_k=48, interpret=True)   # 48 % 32 != 0
    with pytest.raises(ValueError):
        q8_matmul(x[:10], wq.flat_qs(), wq.scales, block_m=8, block_n=64,
                  block_k=32, interpret=True)   # M=10 % 8 != 0


def test_vmem_claim_model():
    """The BlockSpec working set (LMM-sizing analog) is monotone in every
    block dim and matches the documented formula."""
    base = vmem_claim_bytes(128, 256, 256)
    assert vmem_claim_bytes(256, 256, 256) > base
    assert vmem_claim_bytes(128, 512, 256) > base
    assert vmem_claim_bytes(128, 256, 512) > base
    db_x = 2 * 128 * 256 * 2
    db_q = 2 * 256 * 256
    db_s = 2 * 256 * 8 * 4
    acc = 128 * 256 * 4 * 2
    assert base == db_x + db_q + db_s + acc


# ---------------------------------------------------------------------------
# q8_matvec (decode path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,n,k,bn", [
    (8, 128, 64, 64),
    (8, 512, 384, 512),      # whisper d_model
    (16, 1536, 384, 512),    # whisper d_ff x d_model
])
def test_q8_matvec_vs_ref(b, n, k, bn):
    kx, kw = jax.random.split(jax.random.PRNGKey(b + n))
    x = jax.random.normal(kx, (b, k), jnp.float32)
    wq = quantize_q8_0(_w(kw, n, k))
    got = q8_matvec(x, wq.flat_qs(), wq.scales, block_n=bn, interpret=True)
    np.testing.assert_allclose(got, ref.q8_matvec_ref(x, wq),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# bf16_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,n,k", [(8, 64, 64), (32, 128, 384), (64, 256, 512)])
def test_bf16_matmul_vs_ref(m, n, k):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + n + k))
    x = (jax.random.normal(kx, (m, k)) * 0.3).astype(jnp.bfloat16)
    w = (_w(kw, n, k) * 5).astype(jnp.bfloat16)
    got = bf16_matmul(x, w, block_m=8, block_n=64, block_k=64, interpret=True)
    want = ref.matmul_bf16_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    assert got.dtype == jnp.float32


# ---------------------------------------------------------------------------
# ops.matmul — the dispatcher the model zoo calls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape_lead", [(), (3,), (2, 5)])
@pytest.mark.parametrize("kk", [64, 96, 130, 383])   # incl. ragged K
def test_ops_matmul_q8_mixed_exec(shape_lead, kk):
    """The public entry point handles leading batch dims and ragged K via
    the paper's main/residual split — allclose to the monolithic oracle."""
    kx, kw = jax.random.split(jax.random.PRNGKey(kk))
    x = jax.random.normal(kx, (*shape_lead, 4, kk), jnp.float32)
    w = _w(kw, 32, kk)
    k_main = (kk // 32) * 32
    wq_full = quantize_q8_0(w[:, :k_main]) if k_main else None
    got = ops.matmul(x, w, burst=32, prefer_pallas=True, interpret=True)
    want = jnp.einsum("...k,nk->...n", x, w)
    # dense path runs the paper's 16-bit kernel (bf16 operands, f32 accum):
    # tolerance is bf16 ulp-scale, not f32
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_ops_matmul_q8_weights():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 384), jnp.float32)
    wq = quantize_q8_0(_w(jax.random.PRNGKey(1), 1536, 384))
    got = ops.matmul(x, wq, burst=128, prefer_pallas=True, interpret=True)
    want = ref.q8_matmul_ref(x, wq)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ops_matmul_pallas_vs_xla_path_agree():
    """prefer_pallas True (interpret) and False (XLA dequant) must agree —
    they share the dequant definition."""
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 256), jnp.float32)
    wq = quantize_q8_0(_w(jax.random.PRNGKey(3), 128, 256))
    a = ops.matmul(x, wq, burst=64, prefer_pallas=True, interpret=True)
    b = ops.matmul(x, wq, burst=64, prefer_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
