"""Round-boundary speculative scheduling (DESIGN.md §17.4): queued
requests admit into freed wave rows at round boundaries, the paged
verify window reads/writes through block tables, and preemption under
speculation replays token-exactly. Every scheduler here is gated
against TWO references — the run-to-completion ``SpecScheduler`` wave
and plain greedy on the verifier — because speculative decoding's whole
contract is that scheduling may change throughput but never tokens."""
import jax
import numpy as np
import pytest

from repro import obs
from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.speculative import (PagedSpecScheduler,
                                     SpecContinuousScheduler, SpecScheduler,
                                     SpeculativeEngine)

N_FRAMES = 16
K = 3


@pytest.fixture(scope="module")
def ladder():
    tiny = get_smoke_config("whisper-tiny")
    base = get_smoke_config("whisper-base")
    tp = M.init_params(jax.random.PRNGKey(0), tiny)
    bp = M.init_params(jax.random.PRNGKey(1), base)
    return tiny, tp, base, bp


@pytest.fixture(scope="module")
def workload(ladder):
    """Six batch-1 utterances with randomized lengths (seeded): varied
    ``max_new`` is what makes rows finish at different rounds, so
    round-boundary admission actually exercises freed-row reuse."""
    tiny = ladder[0]
    rng = np.random.default_rng(42)
    mels = [np.asarray(jax.random.normal(jax.random.PRNGKey(10 + i),
                                         (1, N_FRAMES, tiny.n_mels)),
                       np.float32) for i in range(6)]
    max_news = rng.integers(3, 10, size=6).tolist()
    return mels, max_news


@pytest.fixture(scope="module")
def greedy_ref(ladder, workload):
    """Plain greedy on the verifier, one request at a time — the
    token-exactness ground truth."""
    _, _, base, bp = ladder
    mels, max_news = workload
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    return {i: v.transcribe(m, sot_id=1, max_new=n)[0].tokens
            for i, (m, n) in enumerate(zip(mels, max_news))}


@pytest.fixture(scope="module")
def wave_ref(ladder, workload, greedy_ref):
    """The run-to-completion SpecScheduler output — the §17.4 parity
    reference the round-boundary schedulers are gated against."""
    tiny, tp, base, bp = ladder
    mels, max_news = workload
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    spec = v.speculative(tiny, tp, k=K)
    sch = SpecScheduler(spec, n_slots=2)
    rids = {sch.submit(m, max_new=n): i
            for i, (m, n) in enumerate(zip(mels, max_news))}
    out = {rids[r]: res.tokens for r, res in sch.run().items()}
    assert out == greedy_ref            # the wave reference is itself exact
    return out


def _spec(ladder, **engine_kw):
    tiny, tp, base, bp = ladder
    v = ServeEngine(base, bp, max_len=64, eos_id=-1, **engine_kw)
    return v, v.speculative(tiny, tp, k=K)


def _drive_with_midflight(sch, workload):
    """Submit half the workload, run one round, submit the rest mid-
    flight, drain. Returns ({req index: tokens}, n admitted after the
    first round) so callers can assert round-boundary admission really
    re-used freed rows."""
    mels, max_news = workload
    rids = {}
    for i in range(3):
        rids[sch.submit(mels[i], max_new=max_news[i])] = i
    sch.admit()
    sch.decode_step()
    for i in range(3, 6):
        rids[sch.submit(mels[i], max_new=max_news[i])] = i
    n_before = len(sch._active)
    out = sch.run()
    return {rids[r]: res.tokens for r, res in out.items()}, n_before


def _assert_attribution_sums(sch):
    att = sch.attribution()
    s = sum(att["per_request_pdp_j"].values())
    assert abs(s - att["batch_pdp_j"]) <= 1e-9 * max(1.0, att["batch_pdp_j"])


# ---------------------------------------------------------------------------
# round-boundary admission on the contiguous pool
# ---------------------------------------------------------------------------
def test_continuous_spec_admission_parity(ladder, workload, greedy_ref,
                                          wave_ref):
    v, spec = _spec(ladder, quant="none")
    sch = spec.continuous(n_slots=2, n_frames=N_FRAMES)
    got, _ = _drive_with_midflight(sch, workload)
    assert got == greedy_ref
    assert got == wave_ref
    # the whole drain compiled exactly one verify and one draft step
    assert (v._verify_traces, spec.draft._step_traces) == (1, 1)
    assert spec.rounds > 0 and spec.accepted <= spec.drafted
    _assert_attribution_sums(sch)


def test_spec_submit_rejects_overflowing_request(ladder):
    """The admission guard is static: a request whose window writes
    could reach past max_len is rejected at submit, not at round N."""
    _, spec = _spec(ladder, quant="none")
    sch = spec.continuous(n_slots=2, n_frames=N_FRAMES)
    mel = np.zeros((1, N_FRAMES, ladder[0].n_mels), np.float32)
    with pytest.raises(ValueError, match="max_len"):
        sch.submit(mel, max_new=64)


# ---------------------------------------------------------------------------
# the paged pool: window scatter through block tables, trim, preemption
# ---------------------------------------------------------------------------
def test_paged_spec_admission_parity(ladder, workload, greedy_ref, wave_ref):
    """Roomy arena: mid-flight admission into freed rows, token parity
    with BOTH references, the pages x role x k plan key, and a drained
    allocator afterwards."""
    v, spec = _spec(ladder, quant="none")
    sch = spec.paged(n_slots=2, n_frames=N_FRAMES, page_size=4,
                     n_pages=1 + 2 * 16, cross_page_size=N_FRAMES,
                     n_cross_pages=3)
    got, active_after_midflight = _drive_with_midflight(sch, workload)
    assert got == greedy_ref
    assert got == wave_ref
    assert active_after_midflight > 0   # rows were live across admission
    assert (v._verify_traces, spec.draft._step_traces) == (1, 1)
    # every page went back to the arena when the last request drained
    alloc = sch.pool.self_alloc
    assert alloc.n_allocated == 0
    assert alloc.n_free == alloc.n_allocatable
    _assert_attribution_sums(sch)


def test_paged_spec_preemption_replay(ladder, workload, greedy_ref):
    """Tight arena: the pre-round capacity pass hits PagesExhausted
    mid-round, preempts a victim, and the preempted request's replay is
    token-exact; pages the rejected suffixes crossed into are released
    (free + allocated == allocatable after the drain); the whole run
    still compiles exactly one verify/draft step program."""
    mels, max_news = workload
    v, spec = _spec(ladder, quant="none")
    sch = spec.paged(n_slots=3, n_frames=N_FRAMES, page_size=4,
                     n_pages=1 + 6, cross_page_size=N_FRAMES,
                     n_cross_pages=4)
    rids = {sch.submit(m, max_new=n): i
            for i, (m, n) in enumerate(zip(mels, max_news))}
    out = sch.run()
    got = {rids[r]: res.tokens for r, res in out.items()}
    assert sch.preemptions > 0
    assert got == greedy_ref
    assert (v._verify_traces, spec.draft._step_traces) == (1, 1)
    alloc = sch.pool.self_alloc
    assert alloc.n_allocated == 0
    assert alloc.n_free == alloc.n_allocatable
    _assert_attribution_sums(sch)


def test_paged_spec_q8_offload_by_role(ladder, workload):
    """q8_0 + offload through the paged speculative path: tokens still
    match plain greedy on the SAME quantized verifier, and the shared
    ledger's by_role split sums exactly to the flop totals."""
    tiny, tp, base, bp = ladder
    mels, max_news = workload
    off = OffloadEngine(interpret=True, prefer_pallas=False)
    v = ServeEngine(base, bp, max_len=64, quant="q8_0", offload=off,
                    eos_id=-1)
    ref = {i: v.transcribe(m, sot_id=1, max_new=n)[0].tokens
           for i, (m, n) in enumerate(zip(mels[:3], max_news[:3]))}
    spec = v.speculative(tiny, tp, k=K)
    sch = spec.paged(n_slots=2, n_frames=N_FRAMES, page_size=4,
                     n_pages=1 + 2 * 16, cross_page_size=N_FRAMES,
                     n_cross_pages=3)
    rids = {sch.submit(m, max_new=n): i
            for i, (m, n) in enumerate(zip(mels[:3], max_news[:3]))}
    got = {rids[r]: res.tokens for r, res in sch.run().items()}
    assert got == ref
    # the verify plan keys paged x role x k disjointly (DESIGN.md §17.4)
    key = sch._verify_plan.key
    assert any(q[0] == "pages" for q in key if isinstance(q, tuple))
    assert ("role", "verify") in key and ("k", K) in key
    assert key != sch._draft_step_plan.key
    s = off.stats
    assert s.by_role.get("draft", 0) > 0 and s.by_role.get("verify", 0) > 0
    total = s.offloaded_flops + s.fallback_flops + s.residual_flops
    assert sum(s.by_role.values()) == total
    _assert_attribution_sums(sch)


# ---------------------------------------------------------------------------
# telemetry: the §16 instants and counters fire on the new paths
# ---------------------------------------------------------------------------
def test_spec_scheduling_telemetry(ladder, workload):
    tiny, tp, base, bp = ladder
    mels, max_news = workload
    tele = obs.Telemetry()
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1,
                    telemetry=tele)
    spec = v.speculative(tiny, tp, k=K)
    sch = spec.paged(n_slots=2, n_frames=N_FRAMES, page_size=4,
                     n_pages=1 + 2 * 16, cross_page_size=N_FRAMES,
                     n_cross_pages=3)
    rids = [sch.submit(m, max_new=n)
            for m, n in zip(mels[:4], max_news[:4])]
    res = sch.run()
    assert set(res) == set(rids)
    names = {e.name for e in tele.tracer.events}
    assert "spec_admit" in names
    assert "spec_round" in {s.name for s in tele.tracer.spans}
    m = tele.metrics
    assert m.counter("repro_spec_admissions_total").value() == len(rids)
    assert m.counter("repro_spec_rounds_total").value() == spec.rounds
    assert tele.tracer.all_closed()
    assert tele.tracer.check_nesting() == []
    assert tele.tracer.rids_closed == set(rids)
