"""Property suite for the paged KV-cache allocator + copy-on-write
(DESIGN.md §15.1-§15.2, via the tests/_hyp.py optional-hypothesis shim):
under ANY interleaving of alloc/retain/release, no page is handed out
while its refcount is live, free + allocated always equals the
allocatable arena size, a release to refcount 0 returns the page to the
free list, and ``ensure_private`` (CoW) never mutates a shared page —
only the writer's table repoints."""
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.configs.registry import get_smoke_config
from repro.models.model import ServeState
from repro.serve.paging import PageAllocator, PagedKVPool, PagesExhausted

N_FRAMES = 8


# ---------------------------------------------------------------------------
# PageAllocator invariants
# ---------------------------------------------------------------------------
def _check_invariants(alloc: PageAllocator, model: dict) -> None:
    """The §15.1 allocator contract against a dict refcount model."""
    # free + allocated == allocatable arena size, always
    assert alloc.n_free + alloc.n_allocated == alloc.n_allocatable
    # the allocator's refcounts match the model's exactly
    for p in range(alloc.n_pages):
        assert alloc.refcount[p] == model.get(p, 0)
    # no live page sits on any free list; every dead one does
    free = {p for lst in alloc._free for p in lst}
    live = {p for p, rc in model.items() if rc > 0}
    assert not (free & live)
    dead = set(range(alloc.reserve, alloc.n_pages)) - live
    assert free == dead
    assert alloc.n_free == len(free)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2),      # reserved (trash) pages
       st.integers(min_value=1, max_value=24),     # allocatable pages
       st.integers(min_value=1, max_value=4),      # shard hint
       st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                          st.integers(min_value=0, max_value=10 ** 6)),
                max_size=80))
def test_allocator_invariants_under_any_op_sequence(reserve, extra, n_shards,
                                                    ops):
    """Property: any alloc/retain/release interleaving preserves every
    §15.1 invariant, and alloc NEVER double-allocates a live page."""
    alloc = PageAllocator(reserve + extra, n_shards, reserve=reserve)
    model: dict = {}
    _check_invariants(alloc, model)
    for kind, pick in ops:
        live = sorted(p for p, rc in model.items() if rc > 0)
        if kind == 0:                                  # alloc
            if alloc.n_free == 0:
                with pytest.raises(PagesExhausted):
                    alloc.alloc(prefer=pick)
            else:
                page = alloc.alloc(prefer=pick)
                # never a reserved page, never a live page
                assert page >= reserve
                assert model.get(page, 0) == 0
                model[page] = 1
        elif kind == 1 and live:                       # retain
            page = live[pick % len(live)]
            alloc.retain(page)
            model[page] += 1
        elif kind == 2 and live:                       # release
            page = live[pick % len(live)]
            freed = alloc.release(page)
            model[page] -= 1
            # release to refcount 0 returns the page to the free list...
            assert freed == (model[page] == 0)
            if freed:
                # ...immediately: the very next alloc can hand it back
                assert page in alloc._free[alloc.page_shard(page)]
        _check_invariants(alloc, model)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=16), st.integers(min_value=1,
                                                           max_value=4))
def test_allocator_drains_to_exactly_the_allocatable_set(n, n_shards):
    """Draining the arena yields each non-reserved page exactly once;
    refilling restores full capacity."""
    alloc = PageAllocator(n + 1, n_shards, reserve=1)
    pages = [alloc.alloc() for _ in range(n)]
    assert sorted(pages) == list(range(1, n + 1))      # all, once, no trash
    with pytest.raises(PagesExhausted):
        alloc.alloc()
    for p in pages:
        assert alloc.release(p)
    assert alloc.n_free == n


def test_allocator_rejects_dead_page_ops():
    alloc = PageAllocator(4, reserve=1)
    with pytest.raises(ValueError):
        alloc.retain(2)                                # never allocated
    with pytest.raises(ValueError):
        alloc.release(2)
    p = alloc.alloc()
    alloc.release(p)
    with pytest.raises(ValueError):
        alloc.release(p)                               # already freed
    with pytest.raises(ValueError):
        PageAllocator(1, reserve=1)                    # nothing allocatable


def test_allocator_prefers_requested_shard():
    alloc = PageAllocator(8, n_shards=4, reserve=0)    # shards of 2 pages
    assert alloc.page_shard(alloc.alloc(prefer=2)) == 2
    assert alloc.page_shard(alloc.alloc(prefer=2)) == 2
    # preferred shard dry -> falls over to the fullest shard, not an error
    assert alloc.page_shard(alloc.alloc(prefer=2)) != 2


# ---------------------------------------------------------------------------
# Copy-on-write never mutates the shared page (DESIGN.md §15.2)
# ---------------------------------------------------------------------------
def _patterned_pool(n_slots=2, page_size=4, n_pages=6):
    """A tiny paged pool whose self arena holds a distinct value at every
    element, so any stray write is detectable bit-for-bit."""
    cfg = get_smoke_config("whisper-tiny")
    pool = PagedKVPool(cfg, None, n_slots=n_slots, max_len=16,
                       n_frames=N_FRAMES, page_size=page_size,
                       n_pages=n_pages)
    ls = pool.state.layer_states
    k = jnp.arange(ls.self_k.size, dtype=jnp.float32).reshape(
        ls.self_k.shape).astype(ls.self_k.dtype)
    pool.state = ServeState(ls._replace(self_k=k, self_v=k + 1.0),
                            pool.state.step)
    return pool


def _page(pool, p):
    ls = pool.state.layer_states
    return (np.asarray(ls.self_k[:, p]), np.asarray(ls.self_v[:, p]))


def test_cow_split_copies_and_never_mutates_shared_page():
    pool = _patterned_pool()
    src = pool.alloc_self_page(0)
    aliased = pool.alias_self_page(1, 0, 0)
    assert aliased == src and pool.self_alloc.refcount[src] == 2
    before_k, before_v = _page(pool, src)

    fresh = pool.ensure_private(1, 0)
    assert fresh != src
    # the shared page is bit-identical to before the split
    after_k, after_v = _page(pool, src)
    np.testing.assert_array_equal(after_k, before_k)
    np.testing.assert_array_equal(after_v, before_v)
    # the private copy carries the same bytes, under the writer's table
    fk, fv = _page(pool, fresh)
    np.testing.assert_array_equal(fk, before_k)
    np.testing.assert_array_equal(fv, before_v)
    assert pool._bt[1, 0] == fresh and pool._bt[0, 0] == src
    # refcounts reflect the split; already-private pages are a no-op
    assert pool.self_alloc.refcount[src] == 1
    assert pool.self_alloc.refcount[fresh] == 1
    assert pool.ensure_private(1, 0) == fresh
    assert pool.ensure_private(0, 0) == src


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=2, max_value=4),          # slots sharing the page
       st.integers(min_value=0, max_value=3))          # which slot writes
def test_cow_property_any_sharer_splits_without_mutation(n_sharers, writer):
    """Property: with ANY number of slots aliasing one physical page, a
    CoW split by ANY of them leaves the shared page bytes untouched and
    every other sharer's table still pointing at it."""
    writer = writer % n_sharers
    pool = _patterned_pool(n_slots=4, n_pages=10)
    src = pool.alloc_self_page(0)
    for s in range(1, n_sharers):
        pool.alias_self_page(s, 0, 0)
    assert pool.self_alloc.refcount[src] == n_sharers
    before_k, before_v = _page(pool, src)

    fresh = pool.ensure_private(writer, 0)
    if n_sharers == 1:
        assert fresh == src                            # nothing shared
        return
    assert fresh != src
    after_k, after_v = _page(pool, src)
    np.testing.assert_array_equal(after_k, before_k)
    np.testing.assert_array_equal(after_v, before_v)
    assert pool.self_alloc.refcount[src] == n_sharers - 1
    for s in range(n_sharers):
        want = fresh if s == writer else src
        assert pool._bt[s, 0] == want


def test_release_returns_cross_refs_and_unpublishes_digest():
    """Slot release drops every page reference it holds and retires the
    shared digest at refcount 0 — the §15.2 half of the EOS-reuse
    guarantee (scheduler half in tests/test_paging.py)."""
    pool = _patterned_pool(n_pages=8)
    pool.alloc_cross_pages(0, "digest-a")
    pool.attach_shared(1, "digest-a")
    pool.alloc_self_page(0)
    pool.alloc_self_page(1)
    slot0, slot1 = pool.acquire(), pool.acquire()
    free_before = (pool.self_alloc.n_free, pool.cross_alloc.n_free)
    pool.release(slot0)
    assert pool.has_shared("digest-a")                 # slot1 still refs it
    pool.release(slot1)
    assert not pool.has_shared("digest-a")
    assert pool.self_alloc.n_free == free_before[0] + 2
    assert pool.cross_alloc.n_free == \
        free_before[1] + pool.n_cross_per_req
    # freed slots' table rows point at the trash page
    assert not pool._bt[:2].any() and not pool._ct[:2].any()
