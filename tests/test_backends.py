"""Backend registry (DESIGN.md §12): capability resolution, forcing,
xla_ref-vs-pallas_tpu numerical parity, plan pinning round-trips, and the
single-probe platform-detection invariant."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import (
    RESIDUAL, REGISTRY, KernelRequest, executor, pin_for_prefer)
from repro.backends.registry import FORCE_ENV, BackendRegistry
from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.core.plan import plan_linear, record_plan
from repro.core.qformats import quantize_q8_0
from repro.kernels import ref
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.tuning import kernel_for

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _isolate_forcing(monkeypatch):
    """These tests exercise pin/force semantics themselves — a
    REPRO_BACKEND set by the environment (the CI xla_ref matrix leg) must
    not leak in underneath them."""
    monkeypatch.delenv(FORCE_ENV, raising=False)


def _req(kernel="q8_matmul", m=32, n=64, k=64, dtype="q8_0", **kw):
    return KernelRequest(kernel=kernel, m=m, n=n, k=k, dtype=dtype, **kw)


# ---------------------------------------------------------------------------
# Capability resolution
# ---------------------------------------------------------------------------
def test_builtin_registration_order():
    """Registration order IS resolution priority (DESIGN.md §12.2)."""
    assert REGISTRY.names() == ("pallas_tpu", "host_residual", "xla_ref")


def test_main_segment_resolves_platform_default():
    """Off-TPU, an unpinned main segment lands on xla_ref — the old
    pallas-on-TPU/XLA-elsewhere rule restated as capability resolution."""
    b = REGISTRY.resolve(_req())
    assert b.name == ("pallas_tpu" if jax.default_backend() == "tpu"
                      else "xla_ref")


def test_residual_always_resolves_host():
    assert REGISTRY.resolve(_req(k=17, dtype="bf16",
                                 segment=RESIDUAL)).name == "host_residual"


def test_pin_overrides_capability_order():
    assert REGISTRY.resolve(_req(), pin="pallas_tpu").name == "pallas_tpu"
    assert REGISTRY.resolve(_req(), pin="xla_ref").name == "xla_ref"


def test_unsupported_pin_falls_through():
    """pallas_tpu declines residual segments; the pin falls through to
    capability order rather than erroring."""
    req = _req(k=17, dtype="bf16", segment=RESIDUAL)
    assert REGISTRY.resolve(req, pin="pallas_tpu").name == "host_residual"


def test_prefer_pallas_translation():
    assert pin_for_prefer(True) == "pallas_tpu"
    assert pin_for_prefer(False) == "xla_ref"
    assert pin_for_prefer(None) is None


def test_unknown_backend_raises():
    with pytest.raises(KeyError):
        REGISTRY.get("cgla_sim")
    with pytest.raises(KeyError):
        with REGISTRY.force("cgla_sim"):
            pass


def test_force_context_beats_pin():
    with REGISTRY.force("xla_ref"):
        assert REGISTRY.resolve(_req(), pin="pallas_tpu").name == "xla_ref"
    # restored on exit
    assert REGISTRY.resolve(_req(), pin="pallas_tpu").name == "pallas_tpu"


def test_force_env_var(monkeypatch):
    monkeypatch.setenv(FORCE_ENV, "xla_ref")
    assert REGISTRY.resolve(_req(), pin="pallas_tpu").name == "xla_ref"
    monkeypatch.setenv(FORCE_ENV, "")          # empty means unset
    assert REGISTRY.resolve(_req(), pin="pallas_tpu").name == "pallas_tpu"


def test_forcing_never_redirects_residual(monkeypatch):
    """The residual host arm is structural mixed-execution semantics —
    REPRO_BACKEND must not silently change its f32 numerics."""
    monkeypatch.setenv(FORCE_ENV, "xla_ref")
    req = _req(k=17, dtype="bf16", segment=RESIDUAL)
    assert REGISTRY.resolve(req).name == "host_residual"


def test_forcing_never_redirects_structural_main(monkeypatch):
    """forceable=False marks a capacity-based fallback: the pin holds and
    REPRO_BACKEND cannot push it onto the accelerator."""
    monkeypatch.setenv(FORCE_ENV, "pallas_tpu")
    req = _req(forceable=False)
    assert REGISTRY.resolve(req, pin="xla_ref").name == "xla_ref"
    assert REGISTRY.resolve(_req(), pin="xla_ref").name == "pallas_tpu"


def test_fallback_plan_entries_exempt_from_forcing(monkeypatch):
    """An offload=False entry keeps the reference path — and really runs
    it — even under REPRO_BACKEND=pallas_tpu, so ledger fallback
    accounting matches what executed."""
    monkeypatch.setenv(FORCE_ENV, "pallas_tpu")
    eng = OffloadEngine(vmem_budget_kb=1, burst=32)     # nothing fits
    e = eng.plan_entry(512, 512, 16, quantized=False)
    assert not e.offload and e.backend == "xla_ref"
    # prove execution honors the structural pin: pallas must not be built
    calls = []
    pallas = REGISTRY.get("pallas_tpu")
    monkeypatch.setattr(pallas, "build",
                        lambda req: calls.append(req) or (lambda x, w: x))
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 512)) * 0.1
    y = eng.linear(x, w, name="fallback")
    assert not calls
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T),
                               rtol=2e-2, atol=2e-2)
    assert eng.stats.fallback_calls == 1
    assert eng.stats.by_backend == {"xla_ref": 1}


def test_register_new_backend_round_trip():
    class Fake:
        name = "cgla_sim"
        def supports(self, req):
            return True
        def auto(self, req):
            return False                        # never volunteers
        def build(self, req):
            return lambda x, w: jnp.zeros((x.shape[0], req.n), jnp.float32)
        def cost_hints(self, req):
            return {"flops": req.flops}

    reg = BackendRegistry()
    reg.register(Fake())
    assert reg.names() == ("cgla_sim",)
    assert reg.resolve(_req(), pin="cgla_sim").name == "cgla_sim"
    out = reg.dispatch(_req(n=8), pin="cgla_sim")(jnp.ones((4, 64)), None)
    assert out.shape == (4, 8)


def test_cost_hints_present():
    req = _req()
    for name in REGISTRY.names():
        hints = REGISTRY.get(name).cost_hints(req)
        assert hints["flops"] == req.flops
        assert "unit" in hints


# ---------------------------------------------------------------------------
# Numerical parity: xla_ref vs pallas_tpu (interpret off-TPU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,n,k,burst", [
    (8, 512, 384, 128),       # q8_matvec decode path (whisper d_model)
    (4, 1536, 384, 64),       # q8_matvec, skinny M
    (32, 256, 160, 32),       # q8_matmul prefill path
    (64, 384, 1536, 256),     # q8_matmul, whisper ffn.down
])
def test_parity_q8(m, n, k, burst):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + n + k))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    wq = quantize_q8_0(jax.random.normal(kw, (n, k)) * 0.1)
    with REGISTRY.force("pallas_tpu"):
        a = executor.matmul(x, wq, burst=burst, interpret=True)
    with REGISTRY.force("xla_ref"):
        b = executor.matmul(x, wq, burst=burst)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(b, ref.q8_matmul_ref(x, wq),
                               rtol=2e-5, atol=2e-5)


def test_parity_q8_matvec_kernel_selected():
    """The decode shapes above really exercise the matvec kernel."""
    assert kernel_for(8, True) == "q8_matvec"
    assert kernel_for(4, True) == "q8_matvec"
    assert kernel_for(32, True) == "q8_matmul"


@pytest.mark.parametrize("m,n,k,burst", [(8, 64, 96, 32), (32, 128, 384, 128)])
def test_parity_dense(m, n, k, burst):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * k))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (n, k)) * 0.1
    with REGISTRY.force("pallas_tpu"):
        a = executor.matmul(x, w, burst=burst, interpret=True)
    with REGISTRY.force("xla_ref"):
        b = executor.matmul(x, w, burst=burst)
    # both run the paper's 16-bit semantics: bf16 operands, f32 accum
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


def test_host_residual_whole_problem_parity():
    """host_residual is pinnable as a whole-problem host baseline (the
    paper's CPU-only row; benchmarks/backend_matrix.py relies on this)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 384), jnp.float32)
    wq = quantize_q8_0(jax.random.normal(jax.random.PRNGKey(1), (64, 384)) * 0.1)
    got = executor.matmul(x, wq, burst=128, backend="host_residual")
    np.testing.assert_allclose(got, ref.q8_matmul_ref(x, wq),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Plan pinning (DESIGN.md §12.3)
# ---------------------------------------------------------------------------
def test_plan_entry_records_backend():
    kw = dict(quantized=True, vmem_budget_kb=8 * 1024, default_burst=256,
              tuner=None)
    assert plan_linear("q", 8, 384, 1536, backend="xla_ref", **kw).backend \
        == "xla_ref"
    assert plan_linear("q", 8, 384, 1536, backend="pallas_tpu", **kw).backend \
        == "pallas_tpu"
    # fallback entries always pin the reference path
    e = plan_linear("big", 1024, 1024, 8, quantized=False, vmem_budget_kb=1,
                    default_burst=32, tuner=None, backend="pallas_tpu")
    assert not e.offload and e.backend == "xla_ref"


def test_plan_entry_zero_main_segment_names_host():
    """k < burst: no main segment exists — the entry must attribute the
    whole linear to the host residual arm that actually runs it, not pin
    a phantom main-segment backend (whisper's enc.frontend, k=n_mels=80,
    hits this at the default burst 256)."""
    e = plan_linear("enc.frontend", 8, 80, 384, quantized=False,
                    vmem_budget_kb=8 * 1024, default_burst=256, tuner=None,
                    backend="pallas_tpu")
    assert e.offload and e.k_main == 0 and e.k_res == 80
    assert e.backend == "host_residual"


def test_plan_entry_backend_honors_forcing(monkeypatch):
    monkeypatch.setenv(FORCE_ENV, "xla_ref")
    e = plan_linear("q", 8, 384, 1536, quantized=True,
                    vmem_budget_kb=8 * 1024, default_burst=256, tuner=None,
                    backend="pallas_tpu")
    assert e.backend == "xla_ref"


@pytest.fixture(scope="module")
def whisper_engine():
    cfg = get_smoke_config("whisper-tiny")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0",
                      offload=OffloadEngine(prefer_pallas=False), eos_id=-1)
    return cfg, eng


def test_plan_backend_roundtrips_through_record_plan(whisper_engine):
    cfg, eng = whisper_engine
    mel = jnp.zeros((1, 8, cfg.n_mels), jnp.float32)
    p1 = record_plan(eng.offload, eng._prefill_fn, eng._serve_params, mel)
    p2 = record_plan(eng.offload, eng._prefill_fn, eng._serve_params, mel)
    assert len(p1) > 0
    assert p1.signature() == p2.signature()     # equality includes .backend
    # engine pins xla_ref; zero-main-segment linears (k < burst, e.g. the
    # k=n_mels frontend) attribute to the host arm that actually runs them
    assert all(e.backend == ("host_residual" if e.k_main == 0 else "xla_ref")
               for e in p1)


def test_plan_backend_roundtrips_through_plancache_zero_retraces(
        whisper_engine):
    """PlanEntry.backend survives the PlanCache round-trip and pinning it
    costs zero retraces in ServeEngine steps (the §10 purity contract)."""
    cfg, eng = whisper_engine
    mel = np.zeros((2, 8, cfg.n_mels), np.float32)
    eng.transcribe(mel, max_new=3)
    traces = eng._step_traces
    hits0 = eng._plans.hits
    for plan in eng._plans.plans.values():
        assert len(plan) > 0
        assert all(e.backend == ("host_residual" if e.k_main == 0
                                 else "xla_ref") for e in plan)
    eng.transcribe(mel, max_new=3)              # steady state
    assert eng._step_traces == traces           # zero retraces
    assert eng._plans.hits > hits0              # plans round-tripped
    by_backend = eng.offload.stats.by_backend
    assert set(by_backend) <= {"xla_ref", "host_residual"}
    # ledger attribution names exactly the backends the plans recorded
    planned = {e.backend for plan in eng._plans.plans.values() for e in plan}
    assert set(by_backend) == planned and sum(by_backend.values()) > 0
    assert eng.energy_report([])["dispatch"]["by_backend"] == \
        dict(eng.offload.stats.by_backend)


# ---------------------------------------------------------------------------
# Single-probe platform detection (the old ops.py duplication)
# ---------------------------------------------------------------------------
def test_platform_probe_is_centralized():
    """``jax.default_backend()`` is probed in exactly one place under src/
    — backends/platform.py (kernels/ops.py and tuning/ used to duplicate
    it)."""
    offenders = []
    for path in glob.glob(os.path.join(ROOT, "src", "**", "*.py"),
                          recursive=True):
        if path.endswith(os.path.join("backends", "platform.py")):
            continue
        with open(path, encoding="utf-8") as f:
            if "default_backend()" in f.read():
                offenders.append(os.path.relpath(path, ROOT))
    assert not offenders, f"platform probes outside the registry: {offenders}"


def test_platform_probe_cached(monkeypatch):
    from repro.backends import platform as plat
    plat.reset_probe_cache()
    assert plat.backend_platform() == jax.default_backend()
    # cached: a spoofed entry is returned as-is until reset
    plat._PROBE["platform"] = "tpu"
    assert plat.on_tpu() and not plat.default_interpret()
    plat.reset_probe_cache()
    assert plat.backend_platform() == jax.default_backend()
