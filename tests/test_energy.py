"""PDP/EDP energy model + burst/LMM experiments vs the paper's figures."""
import pytest
from _hyp import given, settings, st

from repro.core import energy
from repro.core.amdahl import PAPER_SHARE, amdahl_bound, amdahl_speedup
from repro.core.bursts import (
    optimal_burst, paper_burst_sweep, select_tile_burst, tile_sweep_report)
from repro.core.coverage import MulMat


# ---------------------------------------------------------------------------
# Eq. 1-3
# ---------------------------------------------------------------------------
def test_pdp_edp_definitions():
    assert energy.pdp(2.0, 3.0) == 6.0
    assert energy.edp(2.0, 3.0) == 12.0


def test_pdp_mixed_partition():
    # 10 s total, 4 s on the accelerator at 2 W, rest on host at 0.5 W
    v = energy.pdp_mixed(4.0, 10.0, 2.0, 0.5)
    assert v == pytest.approx(4 * 2 + 6 * 0.5)
    with pytest.raises(ValueError):
        energy.pdp_mixed(11.0, 10.0, 2.0)


@given(st.floats(0.01, 100), st.floats(0.01, 100))
@settings(max_examples=30)
def test_edp_scales_quadratically_with_time(t, p):
    assert energy.edp(2 * t, p) == pytest.approx(4 * energy.edp(t, p), rel=1e-6)


# ---------------------------------------------------------------------------
# Burst sweep (Fig 10)
# ---------------------------------------------------------------------------
def test_burst16_is_pdp_and_edp_optimal():
    """The paper's headline co-design result: burst 16 minimizes both PDP
    and EDP among {8, 16, 32} under the measured times + synthesized
    powers."""
    pts = paper_burst_sweep(lanes=2)
    assert optimal_burst(pts, "pdp").burst == 16
    assert optimal_burst(pts, "edp").burst == 16


def test_burst_sweep_matches_paper_magnitudes():
    """§4.4: burst 16 PDP 42.2 J, EDP 1511 J*s; burst 32 is latency-optimal
    but worse on both energy metrics."""
    pts = {p.burst: p for p in paper_burst_sweep(lanes=2)}
    assert pts[16].pdp_j == pytest.approx(42.2, rel=0.15)
    assert pts[16].edp_js == pytest.approx(1511.0, rel=0.15)
    assert pts[32].t_main_s < pts[16].t_main_s < pts[8].t_main_s
    assert pts[32].pdp_j > pts[16].pdp_j
    assert pts[8].pdp_j > pts[16].pdp_j


def test_system_power_matches_paper():
    """§4.4 lists system powers 1.0967/1.5427/2.4287 W for bursts 8/16/32
    (2 lanes + ARM idle)."""
    assert energy.system_power_burst(8) == pytest.approx(1.0967, rel=1e-3)
    assert energy.system_power_burst(16) == pytest.approx(1.5427, rel=1e-3)
    assert energy.system_power_burst(32) == pytest.approx(2.4287, rel=1e-3)


def test_lmm_power_curve():
    """Fig 7: 16->32 KB costs only ~10 mW; growth accelerates after 64 KB."""
    p16 = energy.lmm_power(16)
    p32 = energy.lmm_power(32)
    p256 = energy.lmm_power(256)
    assert p32 - p16 == pytest.approx(0.010, abs=2e-3)
    assert p256 > p32 * 1.4
    assert energy.lmm_power(32, "q8_0") > p32   # integer datapath overhead


# ---------------------------------------------------------------------------
# TPU tile-granularity analog
# ---------------------------------------------------------------------------
def _mulmats():
    return [MulMat("a", 128, 384, 512, count=100),
            MulMat("b", 1, 1500, 384, count=500),
            MulMat("c", 8, 130, 64, count=50)]


def test_tile_sweep_monotone_tradeoffs():
    pts = tile_sweep_report(_mulmats())
    by_burst = {p.burst: p for p in pts}
    # residual stranding never decreases with burst size
    assert by_burst[512].residual_flop_frac >= by_burst[128].residual_flop_frac
    # VMEM claim grows with burst
    assert by_burst[512].vmem_claim_bytes > by_burst[128].vmem_claim_bytes
    # overhead shrinks with burst
    assert by_burst[512].grid_overhead < by_burst[128].grid_overhead


def test_select_tile_burst_returns_candidate():
    assert select_tile_burst(_mulmats()) in (128, 256, 512)


# ---------------------------------------------------------------------------
# Amdahl (Fig 4 / §1)
# ---------------------------------------------------------------------------
def test_amdahl_paper_bounds():
    assert amdahl_bound(PAPER_SHARE["fp16"]) == pytest.approx(10.6, abs=0.1)
    assert amdahl_bound(PAPER_SHARE["q8_0"]) == pytest.approx(7.8, abs=0.1)


@given(st.floats(0.0, 0.999), st.floats(1.0, 1e6))
@settings(max_examples=50)
def test_amdahl_speedup_bounded(f, s):
    v = amdahl_speedup(f, s)
    assert 1.0 <= v <= amdahl_bound(f) + 1e-9


def test_amdahl_validation():
    with pytest.raises(ValueError):
        amdahl_speedup(1.5, 2.0)
    with pytest.raises(ValueError):
        amdahl_speedup(0.5, -1.0)
