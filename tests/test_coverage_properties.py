"""Property-based coverage-model invariants (hypothesis).

Needs the dev extra ``hypothesis`` (requirements-dev.txt); the module skips
cleanly where dev deps are absent — the suite must collect on a bare
runtime install (DESIGN.md §6.3's CI-on-CPU discipline).
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.coverage import MulMat, coverage, fits  # noqa: E402


@given(st.integers(1, 2000), st.integers(1, 2000), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_fits_monotone(m, k, units):
    mm = MulMat("x", m=m, k=k, n=8)
    fit_small = fits(mm, 8, agg_units=units)
    fit_big = fits(mm, 256, agg_units=units)
    assert fit_big or not fit_small   # fits(8KB) implies fits(256KB)


@given(st.lists(st.tuples(st.integers(1, 512), st.integers(1, 512),
                          st.integers(1, 512)), min_size=1, max_size=12),
       st.sampled_from([8, 16, 32, 64, 128, 256]))
@settings(max_examples=30, deadline=None)
def test_coverage_bounded_and_budget_monotone(shapes, kb):
    ms = [MulMat(f"m{i}", m=m, k=k, n=n)
          for i, (m, k, n) in enumerate(shapes)]
    c = coverage(ms, kb)
    assert 0.0 <= c <= 1.0
    assert coverage(ms, 2 * kb) >= c   # more budget never covers less
