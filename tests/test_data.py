"""Data pipeline: determinism, resume, host sharding, learnability hooks."""
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataCursor, make_stream

SHAPE = ShapeConfig("t", 32, 8, "train")


def _stream(arch="phi3-mini-3.8b", **kw):
    return make_stream(get_smoke_config(arch), SHAPE, vocab_cap=97, **kw)


def test_deterministic_replay():
    s1, s2 = _stream(), _stream()
    for step in (0, 1, 7, 1000):
        b1, b2 = s1.batch_at(step), s2.batch_at(step)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))


def test_steps_differ():
    s = _stream()
    a = np.asarray(s.batch_at(0)["tokens"])
    b = np.asarray(s.batch_at(1)["tokens"])
    assert not np.array_equal(a, b)


def test_resume_equals_continuous():
    """batch_at is stateless: resuming at step N gives the same stream a
    continuous run would see — the checkpoint cursor is sufficient state."""
    s = _stream()
    run_a = [np.asarray(s.batch_at(i)["tokens"]) for i in range(5)]
    s2 = _stream()   # "restarted process"
    run_b = [np.asarray(s2.batch_at(i)["tokens"]) for i in range(3, 5)]
    np.testing.assert_array_equal(run_a[3], run_b[0])
    np.testing.assert_array_equal(run_a[4], run_b[1])


def test_host_sharding_disjoint_and_complete():
    full = _stream(num_hosts=1, host_id=0).batch_at(0)
    parts = [_stream(num_hosts=4, host_id=h).batch_at(0) for h in range(4)]
    assert all(np.asarray(p["tokens"]).shape[0] == 2 for p in parts)
    # host slices are pairwise distinct streams
    flat = [np.asarray(p["tokens"]) for p in parts]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(flat[i], flat[j])


def test_labels_are_shifted_tokens():
    b = _stream().batch_at(0)
    t = np.asarray(b["tokens"])
    l = np.asarray(b["labels"])
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])
    assert np.all(l[:, -1] == -1)


def test_sequences_are_learnable():
    """Next token is a deterministic function of the current token (affine
    map mod v) — the convergence signal in examples/train_lm.py is real."""
    b = _stream().batch_at(0)
    t = np.asarray(b["tokens"])
    # within one sequence, equal current tokens always produce the same next
    row = t[0]
    seen = {}
    for cur, nxt in zip(row[:-1], row[1:]):
        if cur in seen:
            assert seen[cur] == nxt
        seen[cur] = nxt


def test_whisper_stream_has_mel():
    s = make_stream(get_smoke_config("whisper-tiny"), SHAPE, vocab_cap=97)
    b = s.batch_at(0)
    assert b["mel"].shape == (8, 32, get_smoke_config("whisper-tiny").n_mels)
    # mel determined by tokens (learnable transcription)
    b2 = s.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["mel"]), np.asarray(b2["mel"]))


def test_vlm_stream_has_patches():
    s = make_stream(get_smoke_config("llava-next-mistral-7b"), SHAPE,
                    vocab_cap=97)
    b = s.batch_at(0)
    assert "patches" in b and b["patches"].ndim == 3


def test_cursor():
    c = DataCursor(step=5, seed=1)
    assert c.advance(3).step == 8
    assert c.advance(3).seed == 1


def test_global_batch_must_divide_hosts():
    with pytest.raises(ValueError):
        make_stream(get_smoke_config("phi3-mini-3.8b"), SHAPE, num_hosts=3)
