"""Checkpointing: atomicity, bit-exactness, elasticity, retention."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.configs.registry import get_smoke_config
from repro.train.checkpoint import (
    latest_checkpoint, load_checkpoint, remove_old_checkpoints,
    save_checkpoint)
from repro.train.step import init_train_state


@pytest.fixture()
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


@pytest.fixture(scope="module")
def state():
    cfg = get_smoke_config("qwen2.5-14b")
    return init_train_state(jax.random.PRNGKey(0), cfg,
                            OptimizerConfig(), 64)


def test_save_load_bit_exact(ckpt_dir, state):
    save_checkpoint(ckpt_dir, state, step=3, cursor_step=3)
    path = latest_checkpoint(ckpt_dir)
    assert path.endswith("step_3")
    template = jax.eval_shape(lambda: state)
    restored, manifest = load_checkpoint(path, template)
    assert manifest["cursor"]["step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype   # bf16 survives the byte round-trip


def test_latest_picks_max_step(ckpt_dir, state):
    for s in (1, 10, 2):
        save_checkpoint(ckpt_dir, state, step=s)
    assert latest_checkpoint(ckpt_dir).endswith("step_10")


def test_atomicity_tmp_dirs_ignored(ckpt_dir, state):
    save_checkpoint(ckpt_dir, state, step=1)
    # simulate a crash mid-save: stale tmp dir must not be visible
    os.makedirs(os.path.join(ckpt_dir, ".tmp_step_99"))
    assert latest_checkpoint(ckpt_dir).endswith("step_1")


def test_overwrite_same_step(ckpt_dir, state):
    save_checkpoint(ckpt_dir, state, step=1)
    save_checkpoint(ckpt_dir, state, step=1)   # no crash, replaced
    assert latest_checkpoint(ckpt_dir).endswith("step_1")


def test_shape_mismatch_rejected(ckpt_dir, state):
    save_checkpoint(ckpt_dir, state, step=1)
    cfg2 = get_smoke_config("phi3-mini-3.8b")   # different shapes
    other = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg2,
                                 OptimizerConfig(), 64))
    with pytest.raises((ValueError, KeyError)):
        load_checkpoint(latest_checkpoint(ckpt_dir), other)


def test_retention(ckpt_dir, state):
    for s in range(6):
        save_checkpoint(ckpt_dir, state, step=s)
    remove_old_checkpoints(ckpt_dir, keep=2)
    kept = sorted(os.listdir(ckpt_dir))
    assert kept == ["step_4", "step_5"]


def test_elastic_restore_to_new_placement(ckpt_dir, state):
    """Restore with explicit shardings (single-device here; the 512-device
    dryrun exercises the mesh case) — the elastic path device_puts every
    leaf onto the provided sharding."""
    from jax.sharding import SingleDeviceSharding
    save_checkpoint(ckpt_dir, state, step=1)
    template = jax.eval_shape(lambda: state)
    dev = jax.devices()[0]
    shardings = jax.tree_util.tree_map(
        lambda _: SingleDeviceSharding(dev), template)
    restored, _ = load_checkpoint(latest_checkpoint(ckpt_dir), template,
                                  shardings=shardings)
    for leaf in jax.tree_util.tree_leaves(restored):
        assert leaf.devices() == {dev}
