"""Measured-replay calibration (DESIGN.md §14): fit recovery on synthetic
timings, calibrated ranking vs the closed form, replay determinism and
registry-forcing semantics, and the versioned coefficients store."""
import json
import math

import pytest

from repro.tuning import (
    Autotuner, BackendCoefficients, CalibratedCoefficients, TuningCache,
    TuningKey, TuningRecord, analytic_features, calibrated_cost,
    enumerate_candidates, fit, fit_backend, get_calibration, preferred_cost,
    rank_correlation, replay, set_calibration, sibling_path, trimmed_mean)
from repro.tuning.calibrate import SCHEMA_VERSION
from repro.tuning.replay import ReplaySample


# ---------------------------------------------------------------------------
# fit: recovery of known constants from synthetic samples
# ---------------------------------------------------------------------------
class _Syn:
    """Duck-typed fit sample: analytic features + a synthetic time."""
    def __init__(self, flops, bytes_hbm, steps, time_s, backend="syn"):
        self.flops, self.bytes_hbm, self.steps = flops, bytes_hbm, steps
        self.time_s, self.backend = time_s, backend


def _synthetic(eff_flops, eff_bw, overhead_s, backend=None):
    """Noise-free samples generated FROM the additive model the fit
    assumes — least squares must recover the constants exactly.  Tuple
    rows for ``fit``; duck-typed ``_Syn`` objects for ``fit_backend``."""
    out = []
    for f, b, s in [(1e9, 1e6, 10), (4e9, 2e6, 40), (1e8, 8e6, 5),
                    (2e10, 5e5, 300), (5e8, 4e6, 80), (9e9, 9e6, 17)]:
        t = f / eff_flops + b / eff_bw + s * overhead_s
        out.append(_Syn(f, b, s, t, backend) if backend
                   else (f, b, s, t))
    return out


def test_fit_recovers_synthetic_constants():
    want = (3.2e13, 5.1e11, 2.5e-7)
    got = fit(_synthetic(*want), backend="syn")
    assert got.backend == "syn"
    assert got.n_samples == 6
    for g, w in zip((got.eff_flops, got.eff_bw, got.overhead_s), want):
        assert abs(g - w) / w < 1e-6
    assert got.median_rel_err < 1e-9       # the fit explains its own data


def test_fit_needs_three_samples():
    with pytest.raises(ValueError, match="need >= 3"):
        fit(_synthetic(1e13, 1e11, 1e-7)[:2], backend="syn")


def test_fit_backend_filters():
    mixed = (_synthetic(1e13, 1e11, 1e-7, backend="a")
             + _synthetic(9e13, 9e11, 9e-7, backend="b"))
    ca = fit_backend(mixed, "a")
    assert ca.n_samples == 6
    assert abs(ca.eff_flops - 1e13) / 1e13 < 1e-6


def test_predict_matches_parts():
    c = BackendCoefficients("x", 1e13, 1e11, 1e-7)
    parts = c.predict_parts(1e9, 1e6, 10)
    assert c.predict(1e9, 1e6, 10) == pytest.approx(sum(parts))
    assert parts == pytest.approx((1e9 / 1e13, 1e6 / 1e11, 10 * 1e-7))


# ---------------------------------------------------------------------------
# calibrated ranking == closed form; preference chain
# ---------------------------------------------------------------------------
def _coeffs(backend="xla_ref"):
    return BackendCoefficients(backend, 2e12, 3e10, 5e-7)


def test_calibrated_cost_matches_closed_form():
    co = _coeffs()
    for c in enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                  vmem_budget_bytes=2**21)[:20]:
        rep = calibrated_cost(c, 1504, 384, 1536, coeffs=co)
        f, b, s = analytic_features(c, 1504, 384, 1536)
        assert rep.source == "calibrated"
        assert rep.cost_s == pytest.approx(co.predict(f, b, s), rel=1e-12)


def test_preferred_cost_precedence():
    """explicit calibration > process-global > analytic fallback."""
    cand = enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                vmem_budget_bytes=2**21)[0]
    cal = CalibratedCoefficients()
    cal.put(_coeffs())
    assert preferred_cost(cand, 1504, 384, 1536).source == "analytic"
    assert preferred_cost(cand, 1504, 384, 1536,
                          calibration=cal).source == "calibrated"
    prev = set_calibration(cal)
    try:
        assert get_calibration() is cal
        assert preferred_cost(cand, 1504, 384, 1536).source == "calibrated"
        louder = CalibratedCoefficients()
        louder.put(BackendCoefficients("xla_ref", 1e10, 1e9, 1e-6))
        rep = preferred_cost(cand, 1504, 384, 1536, calibration=louder)
        f, b, s = analytic_features(cand, 1504, 384, 1536)
        assert rep.cost_s == pytest.approx(
            louder.for_backend().predict(f, b, s))   # explicit arg wins
    finally:
        set_calibration(prev)


def test_tuner_ranks_with_calibration():
    cal = CalibratedCoefficients()
    cal.put(_coeffs())
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic",
                    calibration=cal)
    rec = tun.search("q8_matmul", 1504, 384, 1536)
    assert rec.source == "calibrated"
    # the pick is argmin of the same closed form the test computes itself
    co = cal.for_backend()
    best = min(enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                    vmem_budget_bytes=2**21),
               key=lambda c: co.predict(*analytic_features(c, 1504, 384,
                                                           1536)))
    assert (rec.block_m, rec.block_n, rec.block_k) == (
        best.block_m, best.block_n, best.block_k)


def test_tuner_autoloads_sibling_calibration(tmp_path):
    cache_p = str(tmp_path / "tuning.json")
    TuningCache().save(cache_p)
    cal = CalibratedCoefficients()
    cal.put(_coeffs())
    cal.save(sibling_path(cache_p))
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic",
                    cache_path=cache_p)
    assert tun.calibration is not None
    assert tun.search("q8_matmul", 1504, 384, 1536).source == "calibrated"


def test_cache_merge_ranks_calibrated_between():
    """merge preference: measured > calibrated > analytic."""
    key = TuningKey("q8_matmul", 1504, 384, 1536, "q8_0", 2**21)
    a = TuningCache()
    a.put(key, TuningRecord(94, 384, 512, 1e-4, 2**20, "analytic"))
    b = TuningCache()
    b.put(key, TuningRecord(188, 128, 256, 9e-4, 2**19, "calibrated"))
    a.merge(b)
    assert a.entries[key].source == "calibrated"    # beats analytic
    c = TuningCache()
    c.put(key, TuningRecord(32, 128, 128, 5e-3, 2**18, "measured"))
    a.merge(c)
    assert a.entries[key].source == "measured"      # loses to measured


# ---------------------------------------------------------------------------
# rank correlation + trimmed mean
# ---------------------------------------------------------------------------
def test_rank_correlation_bounds():
    assert rank_correlation([1, 2, 3, 4], [10, 20, 30, 40]) == 1.0
    assert rank_correlation([1, 2, 3, 4], [40, 30, 20, 10]) == -1.0
    assert rank_correlation([1.0], [2.0]) == 1.0            # degenerate
    # ties get average ranks; a tie against a strict order stays in (0,1)
    r = rank_correlation([1, 1, 2, 3], [1, 2, 3, 4])
    assert 0.0 < r < 1.0


def test_trimmed_mean_robust_to_outlier():
    assert trimmed_mean([5.0, 1.0, 100.0]) == 5.0          # N=3 -> median
    assert trimmed_mean([1.0, 2.0, 3.0, 4.0, 100.0]) == 3.0
    assert trimmed_mean([7.0]) == 7.0
    assert trimmed_mean([2.0, 4.0]) == 3.0                 # n<3: plain mean
    with pytest.raises(ValueError):
        trimmed_mean([])


# ---------------------------------------------------------------------------
# replay: determinism witness + registry-forcing semantics
# ---------------------------------------------------------------------------
def test_replay_deterministic(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    a = replay("q8_matvec", 8, 128, 64, "q8_0", backend="xla_ref",
               reps=2, warmup=1)
    b = replay("q8_matvec", 8, 128, 64, "q8_0", backend="xla_ref",
               reps=2, warmup=1)
    assert a.backend == b.backend == "xla_ref"
    assert a.checksum == b.checksum          # bit-identical program+operands
    assert math.isfinite(a.checksum)
    assert len(a.times_s) == 2 and all(t > 0 for t in a.times_s)
    assert (a.flops, a.bytes_hbm, a.steps) == (b.flops, b.bytes_hbm, b.steps)
    assert a.flops > 0 and a.bytes_hbm > 0 and a.steps >= 1


def test_replay_seed_changes_operands(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    a = replay("q8_matvec", 8, 128, 64, "q8_0", backend="xla_ref",
               reps=1, warmup=1, seed=0)
    b = replay("q8_matvec", 8, 128, 64, "q8_0", backend="xla_ref",
               reps=1, warmup=1, seed=1)
    assert a.checksum != b.checksum


def test_replay_honors_backend_forcing(monkeypatch):
    """REPRO_BACKEND outranks the replay pin, exactly as in production
    dispatch (DESIGN.md §12.2) — a forced process measures what it runs."""
    monkeypatch.setenv("REPRO_BACKEND", "xla_ref")
    smp = replay("q8_matvec", 8, 128, 64, "q8_0", backend="host_residual",
                 reps=1, warmup=1)
    assert smp.backend == "xla_ref"


def test_replay_records_pinned_tiling(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    smp = replay("q8_matvec", 8, 128, 64, "q8_0", backend="xla_ref",
                 tiling=(8, 64, 64), reps=1, warmup=1)
    assert smp.tiling == (8, 64, 64)


# ---------------------------------------------------------------------------
# the versioned JSON store
# ---------------------------------------------------------------------------
def _store():
    cal = CalibratedCoefficients()
    cal.put(BackendCoefficients("xla_ref", 2.123e12, 3.456e10, 5.7e-7,
                                n_samples=10, median_rel_err=0.07))
    cal.put(BackendCoefficients("pallas_tpu", 9e13, 8e11, 2e-7,
                                n_samples=10, median_rel_err=0.11))
    return cal


def test_store_roundtrip_exact(tmp_path):
    cal = _store()
    p = str(tmp_path / "coeffs.json")
    cal.save(p)
    back = CalibratedCoefficients.load(p)
    assert back.to_dict() == cal.to_dict()   # lossless, bit-for-bit floats
    assert back.for_backend("xla_ref").eff_flops == 2.123e12
    assert len(back) == 2


def test_store_schema_guard(tmp_path):
    p = tmp_path / "future.json"
    p.write_text(json.dumps({"schema": SCHEMA_VERSION + 1,
                             "backends": {}}))
    with pytest.raises(ValueError, match="schema"):
        CalibratedCoefficients.load(str(p))


def test_corrupt_store_degrades_to_none(tmp_path):
    """Calibration is an optimization: a corrupt file warns and yields
    None (analytic fallback), never a construction failure."""
    p = tmp_path / "corrupt.json"
    p.write_text("garbage{{{")
    with pytest.warns(UserWarning, match="unreadable calibration"):
        assert CalibratedCoefficients.load_or_none(str(p)) is None
    assert CalibratedCoefficients.load_or_none(
        str(tmp_path / "absent.json")) is None
    assert CalibratedCoefficients.load_or_none(None) is None


def test_sibling_path_convention(tmp_path):
    assert sibling_path("/a/b/tuning.json") == "/a/b/tuning.calibration.json"
    # Autotuner(cache_path=p) looks exactly there (see autoload test above)


def test_fit_from_replay_samples_is_storable(monkeypatch, tmp_path):
    """End to end at test scale: replay -> fit -> store -> reload ->
    tuner consumes it."""
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    samples = [replay("q8_matvec", 8, n, 64, "q8_0", backend="xla_ref",
                      reps=2, warmup=1)
               for n in (128, 256, 512)]
    co = fit_backend(samples, "xla_ref")
    assert co.eff_flops > 0 and co.eff_bw > 0 and co.overhead_s >= 0
    cal = CalibratedCoefficients()
    cal.put(co)
    p = str(tmp_path / "coeffs.json")
    cal.save(p)
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic",
                    calibration=CalibratedCoefficients.load(p))
    rec = tun.search("q8_matvec", 8, 1536, 384)
    assert rec is not None and rec.source == "calibrated"


def test_replay_sample_time_is_trimmed_mean():
    s = ReplaySample("q8_matvec", 8, 128, 64, "q8_0", "xla_ref", None,
                     (5.0, 1.0, 100.0), 1, 0.0, 1e6, 1e5, 2.0)
    assert s.time_s == 5.0
