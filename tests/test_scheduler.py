"""Continuous-batching scheduler + slot KV-cache pool (DESIGN.md §11):
slot-batched decode emits token streams identical to one-at-a-time
ServeEngine decode under randomized arrival/eviction schedules, the slot
splice ops are pure and exact, and the fixed-shape pool keeps the
engine's decode step at zero retraces after warmup (jit-purity regression
in the style of tests/test_plan.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import SlotKVPool, slot_insert, slot_reset
from repro.serve.scheduler import ContinuousBatchingScheduler

N_FRAMES = 8


@pytest.fixture(scope="module")
def whisper_setup():
    cfg = get_smoke_config("whisper-tiny")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    return cfg, params


@pytest.fixture(scope="module")
def whisper_engine(whisper_setup):
    cfg, params = whisper_setup
    return ServeEngine(cfg, params, max_len=32, quant="none", eos_id=-1)


@pytest.fixture(scope="module")
def lm_engine():
    cfg = get_smoke_config("qwen2.5-14b")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    return ServeEngine(cfg, params, max_len=32, quant="none", eos_id=-1)


def _mels(cfg, n, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.standard_normal((1, N_FRAMES, cfg.n_mels)).astype(np.float32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Slot layout + splice ops
# ---------------------------------------------------------------------------
def test_slot_layout_broadcasts_counters(whisper_setup):
    cfg, params = whisper_setup
    memory = jnp.zeros((3, N_FRAMES, cfg.d_model))
    stt = M.init_serve_state(params, cfg, 3, 16, memory=memory)
    slot = M.slot_layout(stt, 3)
    assert slot.step.shape == (3,)
    assert slot.layer_states.self_kv.length.shape == (cfg.num_layers, 3)
    # data leaves untouched
    assert slot.layer_states.self_kv.k.shape == \
        stt.layer_states.self_kv.k.shape
    # idempotent
    again = M.slot_layout(slot, 3)
    assert again.step.shape == (3,)
    assert again.layer_states.self_kv.length.shape == (cfg.num_layers, 3)


def test_slot_insert_and_reset_are_exact(whisper_setup):
    """insert splices the request's state into exactly one slot row;
    reset zeroes exactly that row — other slots bit-identical."""
    cfg, params = whisper_setup
    pool = SlotKVPool(cfg, params, n_slots=3, max_len=16, n_frames=N_FRAMES)
    mel = jnp.asarray(_mels(cfg, 1)[0])
    eng = ServeEngine(cfg, params, max_len=16, quant="none", eos_id=-1)
    _, req = eng._prefill_jit(eng._serve_params, mel)
    before = pool.state
    after = slot_insert(pool.state, 1, req)
    req_slot = M.slot_layout(req, 1)

    def rows(tree, i):
        return jax.tree_util.tree_map(lambda a: np.asarray(a[:, i]), tree)

    for i in (0, 2):    # untouched slots
        a, b = rows(after.layer_states, i), rows(before.layer_states, i)
        jax.tree_util.tree_map(np.testing.assert_array_equal, a, b)
    ins = rows(after.layer_states, 1)
    src = rows(req_slot.layer_states, 0)
    jax.tree_util.tree_map(np.testing.assert_array_equal, ins, src)

    cleared = slot_reset(after, 1)
    z = rows(cleared.layer_states, 1)
    jax.tree_util.tree_map(lambda a: np.testing.assert_array_equal(
        a, np.zeros_like(a)), z)
    assert int(cleared.step[1]) == 0
    a, b = rows(cleared.layer_states, 0), rows(after.layer_states, 0)
    jax.tree_util.tree_map(np.testing.assert_array_equal, a, b)


def test_pool_acquire_release(whisper_setup):
    cfg, params = whisper_setup
    pool = SlotKVPool(cfg, params, n_slots=2, max_len=16, n_frames=N_FRAMES)
    assert pool.n_free == 2
    a = pool.acquire()
    b = pool.acquire()
    assert {a, b} == {0, 1} and pool.n_free == 0
    with pytest.raises(IndexError):
        pool.acquire()
    pool.release(a)
    assert pool.n_free == 1 and pool.acquire() == a


def test_pool_requires_frames_for_audio(whisper_setup):
    cfg, params = whisper_setup
    with pytest.raises(ValueError):
        SlotKVPool(cfg, params, n_slots=2, max_len=16)


# ---------------------------------------------------------------------------
# Scheduler vs one-at-a-time equivalence
# ---------------------------------------------------------------------------
def test_scheduler_matches_one_at_a_time(whisper_engine):
    """The §11 contract: slot-batched continuous decode emits, per
    request, exactly the token stream a batch-1 ServeEngine.transcribe of
    the same (padded) utterance produces."""
    eng = whisper_engine
    mels = _mels(eng.cfg, 5)
    refs = [eng.transcribe(m, max_new=4)[0].tokens for m in mels]
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    rids = [sched.submit(m, max_new=4) for m in mels[:3]]
    res = sched.run()
    rids += [sched.submit(m, max_new=4) for m in mels[3:]]  # staggered
    res.update(sched.run())
    for i, rid in enumerate(rids):
        assert res[rid].tokens == refs[i]
        assert res[rid].steps == 4


def test_scheduler_matches_one_at_a_time_lm(lm_engine):
    eng = lm_engine
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, eng.cfg.vocab_size, (1, 4)).astype(np.int32)
               for _ in range(4)]
    refs = [eng.generate(p, max_new=3)[0].tokens for p in prompts]
    sched = ContinuousBatchingScheduler(eng, n_slots=2)
    rids = [sched.submit(p, max_new=3) for p in prompts]
    res = sched.run()
    for i, rid in enumerate(rids):
        assert res[rid].tokens == refs[i]


def test_scheduler_pads_short_utterances(whisper_engine):
    """Submitting an unpadded short utterance equals submitting it
    pre-padded to the pool's frame capacity (the fixed-shape contract)."""
    eng = whisper_engine
    short = np.random.default_rng(3).standard_normal(
        (1, 5, eng.cfg.n_mels)).astype(np.float32)
    padded = np.pad(short, ((0, 0), (0, N_FRAMES - 5), (0, 0)))
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    r1 = sched.submit(short, max_new=3)
    r2 = sched.submit(padded, max_new=3)
    res = sched.run()
    assert res[r1].tokens == res[r2].tokens
    too_long = np.zeros((1, N_FRAMES + 1, eng.cfg.n_mels), np.float32)
    with pytest.raises(ValueError):
        sched.submit(too_long)


def test_submit_rejects_stacked_batches(whisper_engine):
    """One request per submit(): a stacked batch would slot_insert
    multiple rows at one slot and corrupt its neighbors' KV state."""
    eng = whisper_engine
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    stacked = np.zeros((2, N_FRAMES, eng.cfg.n_mels), np.float32)
    with pytest.raises(ValueError, match="ONE request"):
        sched.submit(stacked)
    with pytest.raises(ValueError):
        sched.submit(np.zeros((N_FRAMES,), np.float32))   # missing mel axis
    assert sched.n_queued == 0


def test_scheduler_streams_tokens_in_order(whisper_engine):
    eng = whisper_engine
    mels = _mels(eng.cfg, 3)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    rids = [sched.submit(m, max_new=3) for m in mels]
    events = []
    res = sched.run(on_token=lambda ev: events.append(ev))
    for rid in rids:
        stream = [ev.token for ev in events if ev.rid == rid]
        assert stream == res[rid].tokens          # streamed == final
        dones = [ev.done for ev in events if ev.rid == rid]
        assert dones[-1] and not any(dones[:-1])  # done marks the last


_RAND_ENGINE = None


def _rand_engine():
    """One engine shared across hypothesis examples — its jit wrappers
    (and their compiles) are per-instance, so rebuilding per example
    would recompile the decode step every time."""
    global _RAND_ENGINE
    if _RAND_ENGINE is None:
        cfg = get_smoke_config("whisper-tiny")
        params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
        _RAND_ENGINE = ServeEngine(cfg, params, max_len=32, quant="none",
                                   eos_id=-1)
    return _RAND_ENGINE


@settings(max_examples=5, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=4), min_size=1,
                max_size=5),
       st.lists(st.integers(min_value=0, max_value=3), min_size=5,
                max_size=5),
       st.integers(min_value=1, max_value=3))
def test_randomized_arrival_eviction_schedules(max_news, gaps, n_slots):
    """Property: for ANY arrival pattern (requests trickling in between
    decode steps), ANY per-request budget mix, and ANY pool width, every
    request's stream equals its one-at-a-time decode."""
    eng = _rand_engine()
    cfg = eng.cfg
    mels = _mels(cfg, len(max_news), np.random.default_rng(7))
    refs = [eng.transcribe(m, max_new=mn)[0].tokens
            for m, mn in zip(mels, max_news)]
    sched = ContinuousBatchingScheduler(eng, n_slots=n_slots,
                                        n_frames=N_FRAMES)
    rid2i, queued = {}, list(range(len(mels)))
    gi = 0
    while queued or sched.n_queued or sched.n_active:
        if queued:
            n = gaps[gi % len(gaps)] if gi else 1
            if not (sched.n_queued or sched.n_active):
                n = max(n, 1)        # idle scheduler must receive work
            for _ in range(n):
                if queued:
                    i = queued.pop(0)
                    rid2i[sched.submit(mels[i], max_new=max_news[i])] = i
            gi += 1
        sched.admit()
        sched.decode_step()
    for rid, i in rid2i.items():
        assert sched.finished[rid].tokens == refs[i]
        assert sched.finished[rid].steps == max_news[i]


def test_zero_budget_request_matches_one_shot(whisper_engine):
    """max_new=0 finishes immediately with the empty result the one-shot
    path returns — it never occupies a slot."""
    eng = whisper_engine
    mel = _mels(eng.cfg, 1)[0]
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    rid = sched.submit(mel, max_new=0)
    assert sched.n_queued == 0
    res = sched.run()
    ref = eng.transcribe(mel, max_new=0)[0]
    assert res[rid].tokens == ref.tokens == []
    assert res[rid].steps == ref.steps == 0


def test_run_claims_results_exactly_once(whisper_engine):
    """run() hands each result out once and clears it — a long-running
    submit()/run() loop holds no unbounded history."""
    eng = whisper_engine
    mels = _mels(eng.cfg, 2)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    r0 = sched.submit(mels[0], max_new=2)
    first = sched.run()
    assert set(first) == {r0} and not sched.finished
    r1 = sched.submit(mels[1], max_new=2)
    second = sched.run()
    assert set(second) == {r1}                  # r0 not re-delivered
    att = sched.attribution()
    assert att["per_request_pdp_j"] == {}       # all claimed
    assert att["busy_s"] == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# EOS eviction
# ---------------------------------------------------------------------------
def test_scheduler_evicts_on_eos(whisper_setup):
    cfg, params = whisper_setup
    probe = ServeEngine(cfg, params, max_len=32, quant="none", eos_id=-1)
    mel = _mels(cfg, 1)[0]
    first = probe.transcribe(mel, max_new=3)[0].tokens[0]
    eng = ServeEngine(cfg, params, max_len=32, quant="none",
                      eos_id=int(first))
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    rid = sched.submit(mel, max_new=8)
    res = sched.run()
    assert res[rid].steps == 1                     # evicted on first EOS
    assert res[rid].tokens == [int(first)]
    assert sched.pool.n_free == 2                  # slot returned


# ---------------------------------------------------------------------------
# Jit purity / zero retraces (style of tests/test_plan.py)
# ---------------------------------------------------------------------------
def test_zero_retraces_across_schedules(whisper_setup):
    """The tentpole regression: the engine's decode step_fn is traced
    exactly once per pool geometry, no matter the admission/eviction
    schedule — insert/reset only splice values into fixed shapes."""
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=32, quant="none", eos_id=-1)
    mels = _mels(cfg, 6)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    sched.submit(mels[0], max_new=2)
    sched.run()                                     # warmup: one trace
    traces0 = eng._step_traces
    assert traces0 >= 1
    for m in mels[1:4]:
        sched.submit(m, max_new=3)
    sched.run()
    for m in mels[4:]:                              # staggered second wave
        sched.submit(m, max_new=2)
    sched.run()
    assert eng._step_traces == traces0              # ZERO retraces


def test_slot_ops_are_trace_pure(whisper_setup):
    """slot_insert/slot_reset jit and abstractly trace without touching
    any engine accounting (they are pure pytree splices)."""
    cfg, params = whisper_setup
    off = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0", offload=off,
                      eos_id=-1)
    pool = SlotKVPool(cfg, eng._serve_params, n_slots=2, max_len=16,
                      n_frames=N_FRAMES)
    mel = jnp.asarray(_mels(cfg, 1)[0])
    _, req = eng._prefill_jit(eng._serve_params, mel)
    calls0 = off.stats.offloaded_calls + off.stats.fallback_calls
    jax.eval_shape(slot_insert, pool.state, jnp.int32(0), req)
    jax.eval_shape(slot_reset, pool.state, jnp.int32(0))
    out = jax.jit(slot_insert)(pool.state, 1, req)
    assert out.step.shape == (2,)
    assert off.stats.offloaded_calls + off.stats.fallback_calls == calls0


def test_scheduler_shares_plans_with_one_shot_path(whisper_setup):
    """Plan keys are canonical across serving modes (DESIGN.md §11.3): a
    transcribe at the pool's (batch, frames) point and the scheduler's
    slot step resolve to the SAME PlanCache entry — no re-recording."""
    cfg, params = whisper_setup
    off = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0", offload=off,
                      eos_id=-1)
    mel = np.concatenate(_mels(cfg, 2), axis=0)
    eng.transcribe(mel, max_new=2)                  # records ("step",q,2,F)
    n_plans = len(eng._plans)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    sched.submit(mel[:1], max_new=2)
    sched.run()
    # scheduler added at most the batch-1 prefill plan; its slot step hit
    # the existing ("step", q, 2, F) entry
    assert len(eng._plans) == n_plans + 1
    assert eng._plans.hits >= 1


def test_ledger_commits_match_executed_steps(whisper_setup):
    """Per-request attribution stays exact (§11.3): committed step
    executions equal the batch steps the scheduler actually ran, and
    per-request PDP sums to the batch total."""
    cfg, params = whisper_setup
    off = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0", offload=off,
                      eos_id=-1)
    sched = ContinuousBatchingScheduler(eng, n_slots=2, n_frames=N_FRAMES)
    for m in _mels(cfg, 3):
        sched.submit(m, max_new=3)
    n_steps = 0
    while sched.n_queued or sched.n_active:
        sched.admit()
        if sched.decode_step():
            n_steps += 1
    # 3 prefill commits + one commit per executed batch step
    assert off.ledger.commits == 3 + n_steps
    att = sched.attribution()
    assert sum(att["per_request_pdp_j"].values()) == \
        pytest.approx(att["batch_pdp_j"], rel=1e-9)


# ---------------------------------------------------------------------------
# Engine wrappers
# ---------------------------------------------------------------------------
def test_engine_submit_run_wrappers(whisper_engine):
    eng = whisper_engine
    mels = _mels(eng.cfg, 2)
    # n_frames omitted: inferred from the first utterance's frame count
    r0 = eng.submit_audio(mels[0], max_new=3, n_slots=2)
    assert eng._scheduler.n_frames == N_FRAMES
    r1 = eng.submit_audio(mels[1], max_new=3)   # defaults reuse the pool
    got = eng.run()
    refs = [eng.transcribe(m, max_new=3)[0].tokens for m in mels]
    assert got[r0].tokens == refs[0] and got[r1].tokens == refs[1]
