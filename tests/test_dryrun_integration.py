"""Dry-run integration: the real launch path on the real production mesh,
exercised in a subprocess (the 512-device XLA flag must not leak into this
test process). One cheap cell per step-kind keeps it fast; the full 40-cell
matrix runs via ``python -m repro.launch.dryrun --all`` (EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run_cell(tmp, arch, shape, mesh="pod", timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    cp = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", tmp],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert cp.returncode == 0, cp.stdout[-2000:] + cp.stderr[-2000:]
    mesh_dir = "pod_16x16" if mesh == "pod" else "multipod_2x16x16"
    with open(os.path.join(tmp, mesh_dir, f"{arch}__{shape}.json")) as f:
        return json.load(f)


@pytest.mark.slow
def test_whisper_train_cell_pod(tmp_path):
    r = _run_cell(str(tmp_path), "whisper-tiny", "train_4k")
    assert r["status"] == "ok"
    rf = r["roofline"]
    assert rf["flops_per_device"] > 0
    assert rf["bytes_per_device"] > 0
    assert rf["bottleneck"] in ("compute", "memory", "collective")
    assert r["memory"]["temp_bytes"] < 16 * 2**30   # fits v5e HBM
    assert rf["coll_count"] > 0                     # sharded program


@pytest.mark.slow
def test_whisper_decode_cell_multipod(tmp_path):
    r = _run_cell(str(tmp_path), "whisper-tiny", "decode_32k",
                  mesh="multipod")
    assert r["status"] == "ok"
    assert r["roofline"]["chips"] == 512            # pod axis engaged


@pytest.mark.slow
def test_long500k_skips_full_attention_arch(tmp_path):
    r = _run_cell(str(tmp_path), "phi3-mini-3.8b", "long_500k")
    assert r["status"] == "skip"
    assert "full-attention" in r["reason"]
