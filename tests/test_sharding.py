"""Sharding rules on abstract meshes (no devices needed) + ctx constraints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import abstract_mesh

from repro.configs.registry import get_config, get_smoke_config
from repro.launch import input_specs as IS
from repro.models import model as M
from repro.sharding import ctx, rules

POD = abstract_mesh((16, 16), ("data", "model"))
MULTI = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _specs_by_path(params, mesh):
    specs = rules.param_specs(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    out = {}
    for path, spec in flat:
        out["/".join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                     for k in path)] = spec
    return out


@pytest.fixture(scope="module")
def qwen_specs():
    from repro.configs.base import TRAIN_4K
    cfg = get_config("qwen1.5-110b")
    params = IS.abstract_params(cfg, TRAIN_4K)
    return _specs_by_path(params, POD)


def test_attention_weight_rules(qwen_specs):
    s = {k: v for k, v in qwen_specs.items()}
    qkey = next(k for k in s if k.endswith("attn/q/w"))
    okey = next(k for k in s if k.endswith("attn/o/w"))
    assert s[qkey][-2:] == ("model", "data")   # col-parallel + FSDP
    assert s[okey][-2:] == ("data", "model")   # row-parallel + FSDP


def test_ffn_and_head_rules(qwen_specs):
    s = qwen_specs
    up = next(k for k in s if k.endswith("up/w") and "stack" in k)
    down = next(k for k in s if k.endswith("down/w"))
    head = next(k for k in s if k.endswith("lm_head/w"))
    assert s[up][-2:] == ("model", "data")
    assert s[down][-2:] == ("data", "model")
    assert s[head] == P("model", "data")       # 152064 % 16 == 0


def test_indivisible_dims_fall_back():
    """whisper: vocab 51872 (padded) divides 16; heads 6 do not -> the
    head-sharded dims must come out None, never an invalid spec."""
    cfg = get_config("whisper-tiny")
    from repro.configs.base import TRAIN_4K
    params = IS.abstract_params(cfg, TRAIN_4K)
    s = _specs_by_path(params, POD)
    emb = next(k for k in s if k.endswith("embed/table"))
    assert s[emb][0] == "model"                # padded vocab shards
    kproj = next(k for k in s if k.endswith("attn/k/w"))
    # 6 heads * 64 = 384 divides 16 -> out dim still shards; fine
    assert s[kproj][0] in ("model", None)


def test_moe_expert_rules():
    cfg = get_config("arctic-480b")
    from repro.configs.base import TRAIN_4K
    params = IS.abstract_params(cfg, TRAIN_4K)
    s = _specs_by_path(params, POD)
    wup = next(k for k in s if k.endswith("moe/w_up"))
    wdown = next(k for k in s if k.endswith("moe/w_down"))
    # (R, E, d, dff): E -> model (EP), d -> data (FSDP)
    assert s[wup] == P(None, "model", "data")
    assert s[wdown] == P(None, "model", None, "data")


def test_q8_qtensor_inherits_w_rule():
    from repro.core.qformats import quantize_q8_0
    params = {"attn": {"q": {"w": quantize_q8_0(jnp.ones((256, 128)))}}}
    s = rules.param_specs(params, POD)
    assert s["attn"]["q"]["w"].qs[0] == "model"
    assert s["attn"]["q"]["w"].scales[0] == "model"


def test_batch_specs_pod_and_multipod():
    batch = {"tokens": jnp.zeros((256, 64), jnp.int32)}
    s_pod = rules.batch_specs(batch, POD)
    assert s_pod["tokens"] == P("data")
    s_multi = rules.batch_specs(batch, MULTI)
    assert s_multi["tokens"] == P(("pod", "data"))
    # B=1: falls back to sequence sharding
    s1 = rules.batch_specs({"tokens": jnp.zeros((1, 64), jnp.int32)}, POD)
    assert s1["tokens"] == P(None, "data")


def test_cache_specs_kv_divisible_vs_not():
    olmoe = get_smoke_config("olmoe-1b-7b")  # structure only
    # divisible kv heads: (R,B,S,16,hd) with 16%16==0 -> heads on model
    kv = {"k": jnp.zeros((2, 128, 64, 16, 8))}
    s = rules.cache_specs(kv, POD, 16, 8)
    assert s["k"] == P(None, "data", None, "model")
    # kv=8 on 16-way model -> S carries the model axis instead
    kv8 = {"k": jnp.zeros((2, 128, 64, 8, 16))}
    s8 = rules.cache_specs(kv8, POD, 8, 16)
    assert s8["k"] == P(None, "data", "model")
    # B=1 long-context: S takes (data, model)
    kv1 = {"k": jnp.zeros((2, 1, 512, 8, 16))}
    s1 = rules.cache_specs(kv1, POD, 8, 16)
    assert s1["k"] == P(None, None, ("data", "model"))


def test_ctx_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, "batch", None) is x


def test_ctx_divisibility_fallback():
    mesh = abstract_mesh((4, 2), ("data", "model"))
    with ctx.activation_sharding(mesh):
        # dims indivisible by the axes -> no constraint failure, still traces
        def f(x):
            return ctx.constrain(x, "batch", "model")
        out = jax.eval_shape(f, jax.ShapeDtypeStruct((6, 3), jnp.float32))
        assert out.shape == (6, 3)


def test_ctx_rank_mismatch_raises():
    mesh = abstract_mesh((2, 2), ("data", "model"))
    with ctx.activation_sharding(mesh):
        with pytest.raises(ValueError):
            ctx.constrain(jnp.ones((2, 2)), "batch")
