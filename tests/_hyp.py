"""Optional-hypothesis shim: real decorators when the dev extra is
installed (requirements-dev.txt), skip-marked stand-ins otherwise — so
mixed modules keep their deterministic tests collectable on a bare runtime
install while the property-based ones degrade to skips."""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: any strategy call returns a
        placeholder (never executed — the test is skip-marked)."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (requirements-dev.txt)")(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn
