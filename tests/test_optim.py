"""AdamW, schedules, clipping, Q8_0 moments, int8-EF gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.core.qformats import QTensor
from repro.optim.adamw import (
    AdamWState, adamw_init, adamw_update, clip_by_global_norm, global_norm,
    lr_schedule)
from repro.optim.compression import ef_compress_grads, ef_init


def _quadratic_problem(state_dtype="float32"):
    """min ||w - target||^2 — AdamW must converge."""
    target = jnp.asarray(np.linspace(-1, 1, 64).reshape(2, 32), jnp.float32)
    params = {"w": jnp.zeros((2, 32))}
    cfg = OptimizerConfig(lr=5e-2, warmup_steps=0, total_steps=400,
                          weight_decay=0.0, state_dtype=state_dtype)
    opt = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    return float(loss(params))


def test_adamw_converges():
    assert _quadratic_problem() < 1e-2


@pytest.mark.parametrize("state_dtype", ["bfloat16", "q8_0"])
def test_adamw_quantized_moments_converge(state_dtype):
    """8-bit/16-bit moment storage still converges (paper's Q8_0 block
    format applied to optimizer state)."""
    assert _quadratic_problem(state_dtype) < 5e-2


def test_q8_moments_actually_quantized():
    params = {"w": jnp.ones((4, 64))}
    cfg = OptimizerConfig(state_dtype="q8_0")
    opt = adamw_init(params, cfg)
    assert isinstance(opt.mu["w"], QTensor)
    g = {"w": jnp.full((4, 64), 0.5)}
    params2, opt2, _ = adamw_update(g, opt, params, cfg)
    assert isinstance(opt2.mu["w"], QTensor)
    assert opt2.mu["w"].qs.dtype == jnp.int8


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=0.02)
    assert lrs[-1] == pytest.approx(1e-4, rel=0.05)   # decays to 10%
    assert lrs[1] < lrs[2]                            # warming up


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    gn = global_norm(g)
    np.testing.assert_allclose(float(gn), np.sqrt(90 + 160), rtol=1e-6)
    clipped, _ = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit -> untouched
    unclipped, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), 3.0, rtol=1e-6)


def test_weight_decay_skips_1d():
    params = {"w": jnp.ones((2, 32)), "norm": jnp.ones((32,))}
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, weight_decay=1.0)
    opt = adamw_init(params, cfg)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(zero_g, opt, params, cfg)
    assert float(jnp.max(jnp.abs(p2["norm"] - 1.0))) < 1e-7   # no decay
    assert float(jnp.max(p2["w"])) < 1.0                      # decayed


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------
def test_ef_compression_ratio():
    grads = {"w": jnp.ones((64, 128))}
    ef = ef_init(grads)
    _, _, stats = ef_compress_grads(grads, ef)
    # int8 payload + fp16 scales vs f32: ~3.76x reduction
    assert 3.0 < 1.0 / stats["ratio"] < 4.2


def test_ef_error_feedback_carries_residual():
    """Persistent tiny gradients must eventually pass through thanks to the
    error accumulator, even when a single step quantizes them to zero."""
    big = 1.0
    tiny = big / 10_000.0     # << one int8 step of the block scale
    g = {"w": jnp.asarray([[big] + [tiny] * 31])}
    ef = ef_init(g)
    passed = jnp.zeros((1, 32))
    for _ in range(200):
        out, ef, _ = ef_compress_grads(g, ef)
        passed = passed + out["w"]
    # after N steps the cumulative transmitted tiny-coordinate mass must
    # approach N * tiny (error feedback prevents permanent silencing)
    expect = 200 * tiny
    got = float(passed[0, 5])
    assert got == pytest.approx(expect, rel=0.2)


def test_ef_convergence_matches_uncompressed():
    """Training the quadratic with int8-EF compressed grads converges to a
    comparable loss (the convergence contract from DESIGN.md §7)."""
    target = jnp.asarray(np.linspace(-1, 1, 64).reshape(2, 32), jnp.float32)

    def run(compress):
        params = {"w": jnp.zeros((2, 32))}
        cfg = OptimizerConfig(lr=5e-2, warmup_steps=0, weight_decay=0.0)
        opt = adamw_init(params, cfg)
        ef = ef_init(params)

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        for _ in range(250):
            g = jax.grad(loss)(params)
            if compress:
                g, ef, _ = ef_compress_grads(g, ef)
            params, opt, _ = adamw_update(g, opt, params, cfg)
        return float(loss(params))

    plain = run(False)
    comp = run(True)
    assert comp < max(10 * plain, 5e-2)
