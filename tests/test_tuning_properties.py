"""Property tests for the tuning subsystem (DESIGN.md §9/§14): budget
admissibility of every enumerated tiling, the tuner picking inside its
own space, and cache/calibration stores round-tripping identity.
Hypothesis-backed via the _hyp shim — skip-marked on bare runtime
installs, exercised on the CI legs that install requirements-dev.txt."""
import json

from _hyp import given, settings, st

from repro.core.qformats import QBLOCK
from repro.tuning import (
    Autotuner, BackendCoefficients, CalibratedCoefficients, TuningCache,
    TuningKey, TuningRecord, enumerate_candidates)
from repro.tuning.space import _claim_fn

# Dimension pools: mixes MXU-aligned sizes, Whisper's awkward 1504 =
# 2^5 x 47 padding, and sub-tile smalls — all within QBLOCK rules on K.
MS = (8, 24, 94, 128, 752, 1504)
NS = (128, 256, 384, 1152, 1536)
KS = (64, 384, 1536, 3072)
KERNS = ("q8_matmul", "q8_matvec", "bf16_matmul")
SRC = ("analytic", "calibrated", "measured")


@given(st.sampled_from(KERNS), st.sampled_from(MS), st.sampled_from(NS),
       st.sampled_from(KS), st.integers(2**13, 2**22))
@settings(max_examples=40, deadline=None)
def test_every_candidate_admissible(kernel, m, n, k, budget):
    """Every enumerated tiling divides its dims, honors the Q8_0 block
    rule, and its recorded VMEM claim both fits the budget and equals
    the kernel's own vmem_claim_bytes recomputation."""
    claim = _claim_fn(kernel)
    for c in enumerate_candidates(kernel, m, n, k,
                                  vmem_budget_bytes=budget):
        assert m % c.block_m == 0
        assert n % c.block_n == 0
        assert k % c.block_k == 0
        if kernel.startswith("q8"):
            assert c.block_k % QBLOCK == 0
        assert c.vmem_bytes <= budget
        if kernel == "q8_matvec":
            assert c.vmem_bytes == claim(b=m, k=k, block_n=c.block_n)
        else:
            assert c.vmem_bytes == claim(block_m=c.block_m,
                                         block_n=c.block_n,
                                         block_k=c.block_k)


@given(st.sampled_from(KERNS), st.sampled_from(MS), st.sampled_from(NS),
       st.sampled_from(KS), st.integers(2**15, 2**22), st.booleans())
@settings(max_examples=25, deadline=None)
def test_tuner_pick_is_in_its_own_space(kernel, m, n, k, budget,
                                        calibrated):
    """search() returns an element of enumerate_candidates for the same
    arguments (or None exactly when that space is empty) — under both
    the analytic and a calibrated ranking."""
    cal = None
    if calibrated:
        cal = CalibratedCoefficients()
        cal.put(BackendCoefficients("xla_ref", 2e12, 3e10, 5e-7))
    tun = Autotuner(vmem_budget_bytes=budget, mode="analytic",
                    calibration=cal)
    rec = tun.search(kernel, m, n, k)
    space = enumerate_candidates(kernel, m, n, k, vmem_budget_bytes=budget)
    if rec is None:
        assert space == []
        return
    assert (rec.block_m, rec.block_n, rec.block_k) in {
        (c.block_m, c.block_n, c.block_k) for c in space}
    assert rec.source == ("calibrated" if calibrated else "analytic")


def _keys():
    return st.builds(TuningKey, st.sampled_from(KERNS),
                     st.sampled_from(MS), st.sampled_from(NS),
                     st.sampled_from(KS), st.sampled_from(("q8_0", "bf16")),
                     st.integers(2**13, 2**24))


def _records():
    pos = st.floats(min_value=1e-9, max_value=1e3, allow_nan=False,
                    allow_infinity=False)
    return st.builds(TuningRecord, st.sampled_from((8, 94, 128, 1504)),
                     st.sampled_from((128, 384, 512)),
                     st.sampled_from((32, 64, 256, 1536)), pos,
                     st.integers(2**10, 2**22), st.sampled_from(SRC))


@given(st.dictionaries(_keys(), _records(), max_size=6))
@settings(max_examples=25, deadline=None)
def test_cache_roundtrips_identity(entries):
    """to_dict -> json text -> from_dict is the identity on entries —
    including float costs bit-for-bit (the store must not drift tuner
    decisions between runs)."""
    c = TuningCache()
    for k, r in entries.items():
        c.put(k, r)
    back = TuningCache.from_dict(json.loads(json.dumps(c.to_dict())))
    assert back.entries == c.entries
    assert back.to_dict() == c.to_dict()


@given(st.lists(st.tuples(
    st.sampled_from(("pallas_tpu", "xla_ref", "host_residual")),
    st.floats(min_value=1e6, max_value=1e15, allow_nan=False),
    st.floats(min_value=1e6, max_value=1e13, allow_nan=False),
    st.floats(min_value=0, max_value=1e-3, allow_nan=False)),
    min_size=1, max_size=3, unique_by=lambda t: t[0]))
@settings(max_examples=25, deadline=None)
def test_calibration_store_roundtrips_identity(rows):
    cal = CalibratedCoefficients()
    for b, ef, bw, oh in rows:
        cal.put(BackendCoefficients(b, ef, bw, oh, n_samples=3))
    back = CalibratedCoefficients.from_dict(
        json.loads(json.dumps(cal.to_dict())))
    assert back.to_dict() == cal.to_dict()
    for b, ef, bw, oh in rows:
        got = back.for_backend(b)
        assert (got.eff_flops, got.eff_bw, got.overhead_s) == (ef, bw, oh)


# ---------------------------------------------------------------------------
# deterministic pins of the same properties (collectable without
# hypothesis, so the bare-runtime suite still covers one example each)
# ---------------------------------------------------------------------------
def test_admissibility_example():
    claim = _claim_fn("q8_matmul")
    for c in enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                  vmem_budget_bytes=2**20):
        assert c.vmem_bytes <= 2**20
        assert c.vmem_bytes == claim(block_m=c.block_m, block_n=c.block_n,
                                     block_k=c.block_k)


def test_pick_in_space_example():
    tun = Autotuner(vmem_budget_bytes=2**20, mode="analytic")
    rec = tun.search("q8_matmul", 1504, 384, 1536)
    space = enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                 vmem_budget_bytes=2**20)
    assert (rec.block_m, rec.block_n, rec.block_k) in {
        (c.block_m, c.block_n, c.block_k) for c in space}


def test_cache_roundtrip_example():
    c = TuningCache()
    c.put(TuningKey("q8_matmul", 1504, 384, 1536, "q8_0", 2**21),
          TuningRecord(94, 384, 512, 1.2345678901234e-4, 2**20,
                       "calibrated"))
    back = TuningCache.from_dict(json.loads(json.dumps(c.to_dict())))
    assert back.entries == c.entries
