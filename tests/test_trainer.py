"""Trainer integration: convergence, resume-from-cursor, straggler metrics,
microbatched gradient accumulation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.train.step import init_train_state, make_train_step
from repro.train.trainer import Trainer


def _run_cfg(ckpt_dir, steps=6, arch="phi3-mini-3.8b"):
    return RunConfig(
        model=get_smoke_config(arch),
        shape=ShapeConfig("t", 32, 4, "train"),
        optimizer=OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=40),
        steps=steps, checkpoint_every=3, checkpoint_dir=ckpt_dir)


def test_loss_decreases(tmp_path):
    tr = Trainer(_run_cfg(str(tmp_path / "c"), steps=10), vocab_cap=64)
    tr.train()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_resume_cursor(tmp_path):
    d = str(tmp_path / "c")
    Trainer(_run_cfg(d, steps=6), vocab_cap=64).train()
    tr2 = Trainer(_run_cfg(d, steps=6), vocab_cap=64)
    tr2._init_or_restore()
    assert tr2._start_step == 6
    # training further continues without re-running old steps
    m = tr2.train(steps=8)
    steps_run = [h["step"] for h in tr2.history]
    assert steps_run == [6, 7]


def test_straggler_metrics_present(tmp_path):
    tr = Trainer(_run_cfg(str(tmp_path / "c"), steps=3), vocab_cap=64)
    tr.train()
    assert all("dt_s" in h and "straggler" in h for h in tr.history)


def test_microbatch_grads_match_monolithic():
    """K-way gradient accumulation == single big batch (same loss, params
    allclose after one step) — the dry-run's memory knob must not change
    the optimization trajectory."""
    cfg = get_smoke_config("qwen2.5-14b")
    opt = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    state0 = init_train_state(jax.random.PRNGKey(0), cfg, opt, 64)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    s1, m1 = make_train_step(cfg, opt)(state0, batch)
    state0b = init_train_state(jax.random.PRNGKey(0), cfg, opt, 64)
    s4, m4 = make_train_step(cfg, opt, microbatches=4)(state0b, batch)

    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_int8_ef_training_runs(tmp_path):
    run = RunConfig(
        model=get_smoke_config("phi3-mini-3.8b"),
        shape=ShapeConfig("t", 32, 4, "train"),
        optimizer=OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=40,
                                  grad_compress="int8_ef"),
        steps=6, checkpoint_every=100, checkpoint_dir=str(tmp_path / "c"))
    tr = Trainer(run, vocab_cap=64)
    tr.train()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0] * 1.2   # still converging
