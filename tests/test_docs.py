"""Documentation invariants: the DESIGN.md sections the code cites exist
(the CI docs gate, runnable locally), and the README documents the tier-1
verify command."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_design_refs_resolve():
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_design_refs.py")],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_design_has_tuning_section():
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        text = f.read()
    for anchor in ("§2", "§9.1", "§9.3", "§9.4"):
        assert anchor in text


def test_benchmark_index_covers_all_scripts():
    """Every benchmark with a run() entry point is linked from report.py's
    BENCHMARK_INDEX, and its docstring names the paper figure/table it
    reproduces plus a usage line."""
    import ast
    import glob
    with open(os.path.join(ROOT, "benchmarks", "report.py")) as f:
        report_src = f.read()
    for path in glob.glob(os.path.join(ROOT, "benchmarks", "*.py")):
        name = os.path.basename(path)[:-3]
        if name in ("run", "report", "common"):     # drivers/plumbing
            continue
        with open(path) as f:
            src = f.read()
        if "\ndef run(" not in src:
            continue
        assert f'("{name}"' in report_src, f"{name} missing from index"
        doc = ast.get_docstring(ast.parse(src)) or ""
        assert any(t in doc for t in ("Fig", "Table", "§")), \
            f"{name} docstring names no paper figure/table"
        assert f"benchmarks.{name}" in doc, f"{name} docstring lacks usage"


def test_readme_documents_install_and_verify():
    with open(os.path.join(ROOT, "README.md")) as f:
        text = f.read()
    assert "requirements.txt" in text
    assert "python -m pytest -x -q" in text     # ROADMAP's tier-1 command
    assert "quickstart" in text.lower()
