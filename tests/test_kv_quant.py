"""Int8 KV cache (beyond-paper §Perf C): numerics + equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import model as M
from repro.models.attention import (
    QKVCache, dequantize_kv, quantize_kv)


def test_quantize_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    qs, scale = quantize_kv(x)
    back = dequantize_kv(qs, scale, jnp.float32)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert np.all(err <= amax / 127.0 * 0.5 + 1e-6)
    assert qs.dtype == jnp.int8


def test_quantize_kv_zero_safe():
    qs, scale = quantize_kv(jnp.zeros((1, 2, 2, 8)))
    assert np.all(np.asarray(qs) == 0)
    back = dequantize_kv(qs, scale, jnp.float32)
    assert np.all(np.asarray(back) == 0)


def test_decode_with_q8_cache_matches_bf16():
    cfg = get_smoke_config("qwen2.5-14b")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                              cfg.vocab_size)

    def run(kv_quant):
        c = dataclasses.replace(cfg, kv_quant=kv_quant)
        st = M.init_serve_state(params, c, 2, 32)
        outs = []
        for t in range(10):
            lg, st = M.serve_step(params, c, toks[:, t:t + 1], st)
            outs.append(lg[:, 0])
        return jnp.stack(outs, 1), st

    ref, _ = run("none")
    q8, st8 = run("q8")
    # cache payload actually int8
    leaves = jax.tree_util.tree_leaves(st8.layer_states)
    assert any(l.dtype == jnp.int8 for l in leaves)
    rel = float(jnp.max(jnp.abs(ref - q8))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 0.05
    agree = float(jnp.mean(jnp.argmax(ref, -1) == jnp.argmax(q8, -1)))
    assert agree >= 0.9


def test_q8_cache_bytes_half():
    b, s, h, d = 2, 64, 4, 32
    from repro.models.attention import KVCache
    dense = KVCache.zeros(b, s, h, d, jnp.bfloat16)
    q8 = QKVCache.zeros(b, s, h, d)
    dense_b = sum(x.nbytes for x in jax.tree_util.tree_leaves(dense))
    q8_b = sum(x.nbytes for x in jax.tree_util.tree_leaves(q8))
    assert q8_b < 0.65 * dense_b   # int8 payload + f32/head scales
