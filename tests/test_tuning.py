"""Autotuning subsystem (DESIGN.md §9): candidate space budget enforcement,
deterministic winner selection, cache roundtrip/merge, and the
OffloadEngine cache-hit fast path."""
import jax
import numpy as np
import pytest

from repro.core.offload import OffloadEngine
from repro.core.mixed_exec import select_burst
from repro.core.qformats import QBLOCK, quantize_q8_0
from repro.tuning import (
    Autotuner, TuningCache, TuningKey, TuningRecord, analytic_cost,
    enumerate_candidates, kernel_for, padded_m)
from repro.tuning.space import VMEM_FULL_BYTES


# ---------------------------------------------------------------------------
# candidate space
# ---------------------------------------------------------------------------
def test_candidates_respect_vmem_budget():
    budget = 256 * 1024
    cands = enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                 vmem_budget_bytes=budget)
    assert cands
    assert all(c.vmem_bytes <= budget for c in cands)
    # every candidate tiles the problem exactly and honors the Q8_0 rule
    for c in cands:
        assert 1504 % c.block_m == 0
        assert 384 % c.block_n == 0
        assert 1536 % c.block_k == 0
        assert c.block_k % QBLOCK == 0


def test_budget_rejection_shrinks_space():
    big = enumerate_candidates("q8_matmul", 1504, 384, 1536,
                               vmem_budget_bytes=VMEM_FULL_BYTES)
    small = enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                 vmem_budget_bytes=64 * 1024)
    assert len(small) < len(big)
    oversized = [c for c in big if c.vmem_bytes > 64 * 1024]
    assert oversized                       # the big space has oversize tiles
    assert not [c for c in small if c.vmem_bytes > 64 * 1024]


def test_nothing_fits_tiny_budget():
    assert enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                vmem_budget_bytes=1024) == []


def test_matvec_space_streams_n_only():
    cands = enumerate_candidates("q8_matvec", 8, 1536, 384,
                                 vmem_budget_bytes=VMEM_FULL_BYTES)
    assert cands
    for c in cands:
        assert c.block_m == 8 and c.block_k == 384
        assert 1536 % c.block_n == 0


# ---------------------------------------------------------------------------
# deterministic winner under the analytic model
# ---------------------------------------------------------------------------
def test_winner_deterministic():
    a = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    b = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    ra = a.search("q8_matmul", 1504, 384, 1536)
    rb = b.search("q8_matmul", 1504, 384, 1536)
    assert ra == rb
    assert ra.source == "analytic"
    assert ra.vmem_bytes <= 2**21


def test_winner_beats_or_matches_every_candidate():
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    rec = tun.search("q8_matmul", 1504, 384, 1536)
    for c in enumerate_candidates("q8_matmul", 1504, 384, 1536,
                                  vmem_budget_bytes=2**21):
        assert rec.cost_s <= analytic_cost(c, 1504, 384, 1536).cost_s


def test_search_none_when_nothing_admissible():
    tun = Autotuner(vmem_budget_bytes=1024, mode="analytic")
    assert tun.search("q8_matmul", 1504, 384, 1536) is None
    assert tun.best_tiling("q8_matmul", 1504, 384, 1536, "q8_0") is None


def test_negative_results_memoized():
    """Shapes with no admissible tiling must not re-sweep on the hot
    dispatch path: one search, then memoized misses."""
    tun = Autotuner(vmem_budget_bytes=1024, mode="analytic")
    for _ in range(4):
        assert tun.best_tiling("q8_matmul", 1504, 384, 1536, "q8_0") is None
    assert tun.searches == 1


def test_sweep_grid_budget_monotone_and_admissible():
    from repro.tuning import budget_grid, sweep_grid
    budgets = budget_grid(min_kb=64, agg_units=1)
    cells = sweep_grid("q8_matmul", 1504, 384, 1536, budgets=budgets,
                       block_ks=(128, 256, 512))
    assert cells
    for budget, rep in cells:
        assert rep.cand.vmem_bytes <= budget
    # at a fixed block_k, more budget never makes the best cell worse
    for bk in (128, 256, 512):
        costs = [r.cost_s for b, r in cells if r.cand.block_k == bk]
        assert all(b2 <= b1 + 1e-15 for b1, b2 in zip(costs, costs[1:]))


# ---------------------------------------------------------------------------
# cache: roundtrip, merge policy
# ---------------------------------------------------------------------------
def _key(k=1536, budget=2**21):
    return TuningKey("q8_matmul", 1504, 384, k, "q8_0", budget)


def test_cache_roundtrip(tmp_path):
    c = TuningCache()
    c.put(_key(), TuningRecord(94, 384, 512, 1e-4, 2**20, "analytic"))
    c.put(_key(768), TuningRecord(188, 128, 256, 2e-4, 2**19, "measured"))
    p = str(tmp_path / "cache.json")
    c.save(p)
    c2 = TuningCache.load(p)
    assert c2.entries == c.entries
    # key identity survives the string encoding
    k = _key()
    assert TuningKey.decode(k.encode()) == k


def test_cache_merge_prefers_measured_then_cheaper():
    a, b = TuningCache(), TuningCache()
    a.put(_key(), TuningRecord(94, 384, 512, 1e-4, 2**20, "analytic"))
    b.put(_key(), TuningRecord(32, 128, 256, 5e-4, 2**18, "measured"))
    a.merge(b)
    assert a.entries[_key()].source == "measured"   # measured wins
    c = TuningCache()
    c.put(_key(), TuningRecord(16, 128, 128, 9e-4, 2**17, "measured"))
    a.merge(c)
    assert a.entries[_key()].cost_s == 5e-4         # cheaper measured wins


def test_cache_schema_guard(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text('{"schema": 999, "entries": {}}')
    with pytest.raises(ValueError):
        TuningCache.load(str(p))


def test_corrupt_cache_degrades_to_empty(tmp_path):
    """A cache is an optimization: a corrupt file must not fail engine
    construction — load_or_empty warns and starts empty."""
    p = tmp_path / "corrupt.json"
    p.write_text("garbage{{{")
    with pytest.warns(UserWarning, match="unreadable tuning cache"):
        tun = Autotuner(mode="analytic", cache_path=str(p))
    assert len(tun.cache) == 0


def test_autotuner_loads_cache_path(tmp_path):
    t1 = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    t1.best_tiling("q8_matmul", 1504, 384, 1536, "q8_0")
    p = str(tmp_path / "cache.json")
    t1.save(p)
    t2 = Autotuner(vmem_budget_bytes=2**21, mode="analytic", cache_path=p)
    rec = t2.best_tiling("q8_matmul", 1504, 384, 1536, "q8_0")
    assert t2.searches == 0                  # served from the loaded cache
    assert rec == t1.cache.entries[TuningKey("q8_matmul", 1504, 384, 1536,
                                             "q8_0", 2**21)]


# ---------------------------------------------------------------------------
# OffloadEngine integration: cache-hit fast path + numerical parity
# ---------------------------------------------------------------------------
def test_offload_engine_consumes_cached_tuning():
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    # pre-seed the cache with a distinctive winner for the full-K query the
    # engine makes; the engine must consume it without searching.
    key = TuningKey("q8_matvec", 8, 32, 64, "q8_0", 2**21)
    tun.cache.put(key, TuningRecord(8, 32, 32, 1e-6, 2**14, "measured"))
    eng = OffloadEngine(burst=256, prefer_pallas=True, interpret=True,
                        tuner=tun)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 64)) * 0.1
    y = eng.linear(x, quantize_q8_0(w), name="seeded")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T),
                               rtol=2e-2, atol=2e-2)
    assert eng.stats.tuned_calls == 1
    assert tun.searches == 0                # burst came from the cache...
    assert tun.cache.hits >= 1              # ...via the fast path
    # the seeded block_k=32 burst splits K=64 into main 64? no: 64//32*32=64,
    # so the whole K ran through the kernel with the cached tiling.


def test_offload_engine_fast_path_no_repeat_search():
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    eng = OffloadEngine(burst=32, prefer_pallas=True, interpret=True,
                        tuner=tun)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    wq = quantize_q8_0(jax.random.normal(jax.random.PRNGKey(1), (32, 64)) * 0.1)
    eng.linear(x, wq, name="a")
    n_first = tun.searches
    assert n_first >= 1
    for _ in range(3):
        eng.linear(x, wq, name="a")
    assert tun.searches == n_first          # later calls are dict lookups
    assert eng.stats.tuned_calls == 4


def test_tuned_parity_bf16_and_q8():
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    eng = OffloadEngine(burst=32, prefer_pallas=True, interpret=True,
                        tuner=tun)
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 96))
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 96)) * 0.1
    y = eng.linear(x, w, name="dense")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T),
                               rtol=2e-2, atol=2e-2)
    xq = jax.random.normal(jax.random.PRNGKey(4), (64, 128))
    wq_f = jax.random.normal(jax.random.PRNGKey(5), (96, 128)) * 0.1
    yq = eng.linear(xq, quantize_q8_0(wq_f), name="quant")
    np.testing.assert_allclose(np.asarray(yq), np.asarray(xq @ wq_f.T),
                               rtol=2e-2, atol=2e-2)


def test_select_burst_falls_back_without_tuner():
    assert select_burst(1536, None, default=256) == 256
    tun = Autotuner(vmem_budget_bytes=1024, mode="analytic")  # nothing fits
    assert select_burst(1536, tun, kernel="q8_matmul", m=1504, n=384,
                        dtype="q8_0", default=128) == 128


def test_kernel_for_matches_ops_dispatch():
    assert kernel_for(1, True) == "q8_matvec"       # decode batch
    assert kernel_for(16, True) == "q8_matvec"      # pads to 16
    assert kernel_for(17, True) == "q8_matmul"      # pads to 24 > 16
    assert kernel_for(1500, False) == "bf16_matmul"
    assert padded_m(1500) == 1504


def test_whisper_warm_tuning_populates_cache():
    from repro.configs.registry import get_config
    from repro.models.whisper import warm_tuning
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    eng = OffloadEngine(tuner=tun)
    cfg = get_config("whisper-tiny")
    n = warm_tuning(cfg, eng, n_frames=96, n_tokens=4)
    assert n > 0
    assert len(tun.cache) > 0
    assert warm_tuning(cfg, OffloadEngine()) == 0   # tunerless engine: no-op
