"""Roofline math + the trip-count-aware HLO cost parser, validated against
hand-computable jitted programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import get_config
from repro.roofline.analysis import HW, V5E, model_flops, parse_collectives
from repro.roofline.hlo_cost import analyze_hlo_text, parse_module


# ---------------------------------------------------------------------------
# model_flops
# ---------------------------------------------------------------------------
def test_model_flops_train_vs_decode():
    cfg = get_config("phi3-mini-3.8b")
    tr = model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    dec = model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    n = cfg.n_params()
    assert tr == pytest.approx(6 * n * 4096 * 256, rel=1e-6)
    assert dec == pytest.approx(2 * n * 128, rel=1e-6)


def test_model_flops_moe_uses_active():
    cfg = get_config("olmoe-1b-7b")
    assert model_flops(cfg, SHAPES_BY_NAME["train_4k"]) == pytest.approx(
        6 * cfg.n_active_params() * 4096 * 256, rel=1e-6)


# ---------------------------------------------------------------------------
# HLO parser on known programs
# ---------------------------------------------------------------------------
def _compile(f, *structs):
    return jax.jit(f).lower(*structs).compile()


def test_dot_flops_exact():
    m, k, n = 64, 128, 256
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((m, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
    t = analyze_hlo_text(c.as_text())
    assert t.flops == pytest.approx(2 * m * k * n, rel=0.01)


def test_scan_trip_count_scaling():
    n_iter, m = 9, 128

    def f(x, w):
        def body(c, _):
            y = jnp.dot(c, w, preferred_element_type=jnp.float32)
            return y.astype(x.dtype), None
        out, _ = jax.lax.scan(body, x, None, length=n_iter)
        return out

    c = _compile(f, jax.ShapeDtypeStruct((m, m), jnp.bfloat16),
                 jax.ShapeDtypeStruct((m, m), jnp.bfloat16))
    t = analyze_hlo_text(c.as_text())
    assert t.flops == pytest.approx(2 * m ** 3 * n_iter, rel=0.1)
    assert n_iter in t.while_trips.values()


def test_scan_xs_slicing_not_overcounted():
    """Reading stacked xs (R, m, m) via dynamic-slice per iteration must
    count ~R x slice bytes, not R x full-stack bytes."""
    r, m = 16, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    c = _compile(f, jax.ShapeDtypeStruct((m, m), jnp.float32),
                 jax.ShapeDtypeStruct((r, m, m), jnp.float32))
    t = analyze_hlo_text(c.as_text())
    stack_bytes = r * m * m * 4
    # naive accounting counts the full stack as a dynamic-slice operand on
    # every iteration: R x stack = 16x overcount. Correct accounting is
    # ~R x (a handful of slice-sized tensors) ~= 8 x stack here.
    assert stack_bytes < t.bytes < 0.6 * r * stack_bytes


def test_elementwise_estimate():
    c = _compile(lambda x: jnp.tanh(x) * 2 + 1,
                 jax.ShapeDtypeStruct((1024,), jnp.float32))
    t = analyze_hlo_text(c.as_text())
    assert 0 < t.flops < 64 * 1024    # ~1/elt, far below a matmul


def test_parse_module_structure():
    c = _compile(lambda x: x + 1, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_module(c.as_text())
    assert "__entry__" in comps


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------
def test_hw_constants():
    assert V5E.peak_flops == 197e12
    assert V5E.hbm_bw == 819e9
    assert V5E.link_bw == 50e9


def test_collective_regex_ignores_operand_mentions():
    txt = """
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %all-gather = f32[64,64]{1,0} all-gather(%p), replica_groups=[4,2]<=[8]
  ROOT %fusion.1 = f32[64,64]{1,0} fusion(%all-gather), kind=kLoop, calls=%fc
}
"""
    stats = parse_collectives(txt)
    assert stats.count == 1                      # fusion line not counted
    assert stats.raw_bytes == 64 * 64 * 4
