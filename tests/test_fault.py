"""Fault tolerance: restart supervision, straggler detection, preemption,
and end-to-end crash/resume through the Trainer."""
import shutil

import numpy as np
import pytest

from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.train.fault import (
    PreemptionHandler, RestartPolicy, StragglerMonitor, run_with_restarts)
from repro.train.trainer import Trainer


# ---------------------------------------------------------------------------
# run_with_restarts
# ---------------------------------------------------------------------------
def test_restarts_until_success():
    calls = []

    def make(attempt):
        def fn():
            calls.append(attempt)
            if attempt < 2:
                raise RuntimeError("node died")
            return "done"
        return fn

    out = run_with_restarts(make, RestartPolicy(max_restarts=3,
                                                backoff_s=0), sleep=lambda s: None)
    assert out == "done"
    assert calls == [0, 1, 2]


def test_exhausted_restarts_reraise():
    def make(attempt):
        def fn():
            raise RuntimeError("always")
        return fn
    with pytest.raises(RuntimeError):
        run_with_restarts(make, RestartPolicy(max_restarts=2, backoff_s=0),
                          sleep=lambda s: None)


def test_programming_errors_not_retried():
    calls = []

    def make(attempt):
        def fn():
            calls.append(attempt)
            raise TypeError("bug")
        return fn
    with pytest.raises(TypeError):
        run_with_restarts(make, RestartPolicy(max_restarts=5, backoff_s=0),
                          sleep=lambda s: None)
    assert calls == [0]


def test_backoff_grows():
    sleeps = []

    def make(attempt):
        def fn():
            raise RuntimeError("x")
        return fn
    with pytest.raises(RuntimeError):
        run_with_restarts(make,
                          RestartPolicy(max_restarts=3, backoff_s=0.1,
                                        backoff_factor=2.0),
                          sleep=sleeps.append)
    np.testing.assert_allclose(sleeps, [0.1, 0.2, 0.4], rtol=1e-6)


# ---------------------------------------------------------------------------
# Straggler monitor
# ---------------------------------------------------------------------------
def test_straggler_flagged():
    mon = StragglerMonitor(warmup_steps=5)
    for s in range(20):
        assert not mon.observe(s, 0.1 + 0.001 * (s % 3))
    assert mon.observe(20, 1.0)          # 10x the mean -> straggler
    assert mon.events and mon.events[0]["step"] == 20


def test_straggler_does_not_poison_ewma():
    mon = StragglerMonitor(warmup_steps=5)
    for s in range(10):
        mon.observe(s, 0.1)
    mean_before = mon.mean
    mon.observe(10, 5.0)                 # outlier
    assert mon.mean == pytest.approx(mean_before)   # EWMA unchanged
    assert not mon.observe(11, 0.1)      # normal step still normal


def test_gradual_drift_tolerated():
    mon = StragglerMonitor(warmup_steps=5, k_sigma=3.0)
    t = 0.1
    flags = 0
    for s in range(100):
        t *= 1.01                        # slow drift, not a straggler spike
        flags += mon.observe(s, t)
    assert flags <= 2


# ---------------------------------------------------------------------------
# Preemption + trainer crash/resume
# ---------------------------------------------------------------------------
def test_preemption_handler_flag():
    h = PreemptionHandler(install=False)
    assert not h.requested
    h._on_sigterm(None, None)
    assert h.requested


def _run(ckpt_dir, steps, fault_hook=None):
    cfg = get_smoke_config("phi3-mini-3.8b")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                              total_steps=50),
                    steps=steps, checkpoint_every=2, checkpoint_dir=ckpt_dir)
    tr = Trainer(run, vocab_cap=64, fault_hook=fault_hook)
    tr.train()
    return tr


def test_crash_resume_end_to_end(tmp_path):
    """Kill training at step 5; a fresh Trainer resumes from the last
    checkpoint (step 4) and finishes; losses match an uninterrupted run on
    the replayed steps (same data cursor, same params)."""
    d1 = str(tmp_path / "a")
    gold = _run(d1, 8)
    gold_losses = {h["step"]: h["loss"] for h in gold.history}

    d2 = str(tmp_path / "b")

    def bomb(step):
        if step == 5:
            raise RuntimeError("injected node failure")

    with pytest.raises(RuntimeError):
        _run(d2, 8, fault_hook=bomb)
    # resume (no bomb this time)
    tr2 = _run(d2, 8)
    resumed = {h["step"]: h["loss"] for h in tr2.history}
    # steps 4..7 ran after restore from step-4 checkpoint; bit-identical
    # state + stateless data => identical losses to the gold run
    for s in (4, 5, 6, 7):
        assert resumed[s] == pytest.approx(gold_losses[s], rel=1e-5), s
