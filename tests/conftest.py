"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real 1-CPU device view; only launch/dryrun.py (and the
subprocess-based mesh tests) force a multi-device platform."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
