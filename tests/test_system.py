"""System-level smoke: the public API end-to-end on one architecture —
init -> train 3 steps -> checkpoint -> serve with Q8_0 offload."""
import jax
import numpy as np

from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer


def test_train_then_serve_roundtrip(tmp_path):
    cfg = get_smoke_config("qwen2.5-14b")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                              total_steps=10),
                    steps=3, checkpoint_every=2,
                    checkpoint_dir=str(tmp_path / "ck"))
    tr = Trainer(run, vocab_cap=64)
    metrics = tr.train()
    assert np.isfinite(metrics["loss"])

    # serve the trained params through the paper's offload path
    off = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, tr.state.params, max_len=32, quant="q8_0",
                      offload=off, eos_id=-1)
    res = eng.generate(np.ones((2, 4), np.int32), max_new=4)
    assert len(res) == 2 and res[0].steps == 4
    assert off.stats.offloaded_calls > 0
    rep = eng.energy_report(res)
    assert rep["pdp_j"] > 0
