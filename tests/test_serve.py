"""Serving engine: batched generate/transcribe, Q8_0 parity, energy report."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as M
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_smoke_config("qwen2.5-14b")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    return cfg, params


@pytest.fixture(scope="module")
def whisper_setup():
    cfg = get_smoke_config("whisper-tiny")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    return cfg, params


def test_generate_batched(lm_setup):
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=-1)
    prompts = np.ones((3, 4), np.int32)
    res = eng.generate(prompts, max_new=5)
    assert len(res) == 3
    assert all(r.steps == 5 for r in res)
    assert all(0 <= t < cfg.vocab_size for r in res for t in r.tokens)
    # token contract: exactly the generated tokens, no prompt echo
    assert all(len(r.tokens) == r.steps for r in res)


def test_token_contract_consistent_across_paths(lm_setup, whisper_setup):
    """generate() and transcribe() return the same shape of result: the
    ``steps`` generated tokens, nothing prepended (serve/engine.py module
    docstring contract)."""
    cfg, params = lm_setup
    lm = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=-1)
    r_lm = lm.generate(np.ones((1, 3), np.int32), max_new=4)[0]
    assert len(r_lm.tokens) == r_lm.steps == 4
    acfg, aparams = whisper_setup
    au = ServeEngine(acfg, aparams, max_len=64, quant="none", eos_id=-1)
    mel = np.zeros((1, 8, acfg.n_mels), np.float32)
    r_au = au.transcribe(mel, max_new=4)[0]
    assert len(r_au.tokens) == r_au.steps == 4


def test_generate_deterministic(lm_setup):
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=-1)
    p = np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab_size
    r1 = eng.generate(p, max_new=4)
    r2 = eng.generate(p, max_new=4)
    assert [r.tokens for r in r1] == [r.tokens for r in r2]


def test_q8_tokens_match_dense(lm_setup):
    """The paper's Table 4/5 claim: Q8_0 offload changes transcripts by
    ~0.1% — on a smoke model greedy tokens should match dense exactly or
    nearly so."""
    cfg, params = lm_setup
    p = np.ones((2, 4), np.int32)
    dense = ServeEngine(cfg, params, max_len=64, quant="none",
                        eos_id=-1).generate(p, max_new=6)
    q8 = ServeEngine(cfg, params, max_len=64, quant="q8_0",
                     eos_id=-1).generate(p, max_new=6)
    agree = np.mean([int(a == b) for ra, rb in zip(dense, q8)
                     for a, b in zip(ra.tokens, rb.tokens)])
    assert agree >= 0.8


def test_transcribe_with_offload_engine(whisper_setup):
    cfg, params = whisper_setup
    off = OffloadEngine(interpret=True, prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=64, quant="q8_0", offload=off,
                      eos_id=-1)
    mel = np.random.default_rng(0).standard_normal((2, 16, cfg.n_mels)
                                                   ).astype(np.float32)
    res = eng.transcribe(mel, max_new=4)
    assert len(res) == 2 and res[0].steps == 4
    assert off.stats.offloaded_calls + off.stats.fallback_calls > 0
    rep = eng.energy_report(res)
    assert rep["pdp_j"] > 0 and rep["edp_js"] > 0
    assert rep["offload_rate"] > 0


@pytest.mark.parametrize("arch", ["whisper-base", "whisper-small"])
def test_transcribe_ladder_baselines(arch):
    """Plain ServeEngine decode on the ladder's verifier rungs — the
    baseline the speculative engine (DESIGN.md §17) must stay token-exact
    against. Deterministic across repeat calls, steps honored, dense and
    q8_0+offload agree on the token contract."""
    cfg = get_smoke_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    mel = np.random.default_rng(1).standard_normal(
        (2, 16, cfg.n_mels)).astype(np.float32)
    eng = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=-1)
    r1 = eng.transcribe(mel, max_new=6)
    r2 = eng.transcribe(mel, max_new=6)
    assert [r.tokens for r in r1] == [r.tokens for r in r2]
    assert all(r.steps == 6 and len(r.tokens) == 6 for r in r1)
    assert all(0 <= t < cfg.vocab_size for r in r1 for t in r.tokens)
    off = OffloadEngine(interpret=True)
    q8 = ServeEngine(cfg, params, max_len=64, quant="q8_0", offload=off,
                     eos_id=-1).transcribe(mel, max_new=6)
    assert all(r.steps == 6 for r in q8)
    assert off.stats.offloaded_calls + off.stats.fallback_calls > 0


def test_per_request_eos_truncation(lm_setup):
    """Early-finished rows no longer echo post-EOS argmax tokens or the
    batch-global step count: each row truncates at ITS first EOS
    (inclusive, matching a batch-1 run) and reports its own steps."""
    cfg, params = lm_setup
    probe = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=None)
    p_a = np.ones((1, 4), np.int32)
    p_b = (np.arange(4, dtype=np.int32)[None] + 2) % cfg.vocab_size
    t_a = probe.generate(p_a, max_new=6)[0].tokens
    t_b = probe.generate(p_b, max_new=6)[0].tokens
    eos = next((t for t in t_a if t not in t_b), None)
    if eos is None:
        pytest.skip("streams share every token on this seed")
    eng = ServeEngine(cfg, params, max_len=64, quant="none",
                      eos_id=int(eos))
    res = eng.generate(np.concatenate([p_a, p_b]), max_new=6)
    i = t_a.index(eos)
    assert res[0].steps == i + 1                 # own steps, not batch's
    assert res[0].tokens == t_a[:i + 1]          # EOS included, no echo
    assert res[1].tokens == t_b                  # other row unaffected
    assert all(len(r.tokens) == r.steps for r in res)


def test_transcribe_rows_truncate_at_first_eos(whisper_setup):
    """Same contract on the whisper path: if a row's stream contains the
    EOS it is that row's last token."""
    cfg, params = whisper_setup
    probe = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=None)
    rng = np.random.default_rng(0)
    mel = rng.standard_normal((2, 8, cfg.n_mels)).astype(np.float32)
    first = probe.transcribe(mel[:1], max_new=4)[0].tokens[0]
    eng = ServeEngine(cfg, params, max_len=64, quant="none",
                      eos_id=int(first))
    for r in eng.transcribe(mel, max_new=6):
        assert len(r.tokens) == r.steps
        if int(first) in r.tokens:
            assert r.tokens.index(int(first)) == len(r.tokens) - 1


def test_eos_stops_early(lm_setup):
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=None)
    p = np.ones((1, 2), np.int32)
    probe = eng.generate(p, max_new=3)
    first_tok = probe[0].tokens[0]          # first *generated* token
    eng2 = ServeEngine(cfg, params, max_len=64, quant="none",
                       eos_id=int(first_tok))
    res = eng2.generate(p, max_new=8)
    assert res[0].steps < 8


def test_energy_report_platform_scaling(lm_setup):
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, max_len=64, quant="none", eos_id=-1)
    res = eng.generate(np.ones((1, 2), np.int32), max_new=2)
    low = eng.energy_report(res, platform_w=1.0)
    high = eng.energy_report(res, platform_w=10.0)
    assert high["pdp_j"] == pytest.approx(10 * low["pdp_j"], rel=1e-6)
