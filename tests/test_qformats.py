"""Q8_0 block quantization: GGML exactness + the paper's §4.2 error figures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.qformats import (
    QBLOCK, QTensor, dequantize_q8_0, dequantize_tree, quantize_q8_0,
    quantize_tree, reconstruction_error)


def test_roundtrip_exact_for_quantized_grid():
    """A block whose amax/127 is an exact fp16 value reconstructs exactly."""
    d = 0.5
    q = np.concatenate([[127, -127], np.arange(-15, 15)]).astype(np.int8)
    w = jnp.asarray(q, jnp.float32)[None, :] * d   # amax = 63.5 -> scale 0.5
    t = quantize_q8_0(w)
    np.testing.assert_array_equal(np.asarray(dequantize_q8_0(t)),
                                  np.asarray(w))


def test_block_structure():
    w = jnp.ones((4, 128))
    t = quantize_q8_0(w)
    assert t.qs.shape == (4, 4, QBLOCK)
    assert t.scales.shape == (4, 4)
    assert t.qs.dtype == jnp.int8
    assert t.k == 128 and t.shape == (4, 128)


def test_scale_is_amax_over_127_fp16():
    w = jnp.zeros((1, 32)).at[0, 5].set(3.7)
    t = quantize_q8_0(w)
    expect = np.float32(np.float16(3.7 / 127.0))
    np.testing.assert_allclose(np.asarray(t.scales)[0, 0], expect, rtol=1e-7)
    # the amax element maps to exactly +-127
    assert int(np.asarray(t.qs)[0, 0, 5]) == 127


def test_k_not_multiple_raises():
    with pytest.raises(ValueError):
        quantize_q8_0(jnp.ones((2, 33)))


def test_paper_reconstruction_error_range():
    """§4.2: on fp16-scale weight tensors MAE ~1.39e-4, RMSE ~2.09e-4,
    max 3.41e-3, rel-L2 8.31e-3. Our synthetic whisper-tiny-shaped weights
    (normal, std=0.02-ish like trained weights) must land in the same
    order of magnitude."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (1536, 384)) * 0.02
    t = quantize_q8_0(w)
    err = reconstruction_error(w, t)
    assert 1e-5 < err["mae"] < 1e-3
    assert 1e-5 < err["rmse"] < 2e-3
    assert err["max_abs"] < 2e-2
    assert 1e-3 < err["rel_l2"] < 3e-2


def test_quantize_tree_predicate_and_inverse():
    params = {"w": jnp.ones((8, 64)), "norm": {"scale": jnp.ones((64,))},
              "odd": jnp.ones((4, 33))}
    qt = quantize_tree(params, predicate=lambda p, l: True)
    assert isinstance(qt["w"], QTensor)
    assert not isinstance(qt["norm"]["scale"], QTensor)   # 1D skipped
    assert not isinstance(qt["odd"], QTensor)             # K%32 != 0 skipped
    back = dequantize_tree(qt)
    np.testing.assert_allclose(back["w"], params["w"], rtol=1e-3)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8),
       st.floats(0.001, 100.0), st.integers(0, 2**31 - 1))
def test_roundtrip_error_bound_property(rows, blocks, scale, seed):
    """|w - deq(q(w))| <= amax/127 * (0.5 + fp16 scale rounding) per block,
    for any shape and magnitude."""
    k = blocks * QBLOCK
    w = jax.random.normal(jax.random.PRNGKey(seed), (rows, k)) * scale
    t = quantize_q8_0(w)
    back = dequantize_q8_0(t)
    amax = np.max(np.abs(np.asarray(w).reshape(rows, blocks, QBLOCK)),
                  axis=-1, keepdims=True)
    # 0.5 ulp of int8 rounding + 2^-11 relative fp16 scale rounding
    bound = amax / 127.0 * 0.5 + amax * 2e-3 + 1e-12
    err = np.abs(np.asarray(back - w)).reshape(rows, blocks, QBLOCK)
    assert np.all(err <= bound + 1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_idempotent(seed):
    """Quantizing a dequantized tensor is a fixed point (same qs)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (2, 64))
    t1 = quantize_q8_0(w)
    t2 = quantize_q8_0(dequantize_q8_0(t1))
    np.testing.assert_array_equal(np.asarray(t1.qs), np.asarray(t2.qs))
