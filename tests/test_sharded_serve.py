"""Sharded serving (DESIGN.md §13): mesh plan-key/plan-entry separation,
per-device ledger attribution, slot-state specs and serve-param specs (all
in-process on abstract meshes — this test process keeps its 1-CPU device
view, per conftest), plus the real 4-device parity/retrace gate in a
subprocess with the forced-host platform flag."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.offload import OffloadEngine, OffloadLedger
from repro.core.plan import plan_key, plan_linear
from repro.launch.mesh import abstract_mesh
from repro.models import model as M
from repro.models.model import ServeState
from repro.sharding import rules

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
MESH4 = abstract_mesh((4, 1), ("data", "model"))
SIG4 = (("data", 4), ("model", 1))


# ---------------------------------------------------------------------------
# mesh signature + plan keys (DESIGN.md §13.3)
# ---------------------------------------------------------------------------
def test_mesh_signature():
    assert rules.mesh_signature(None) is None
    assert rules.mesh_signature(MESH4) == SIG4
    multi = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert rules.mesh_signature(multi) == (("pod", 2), ("data", 16),
                                           ("model", 16))


def test_plan_key_mesh_separation():
    """Same shapes on a 1-device view vs a 4-device mesh must build
    DISTINCT plan-cache keys — and mesh=None keys stay byte-identical to
    the pre-mesh key family (the §11.3 sharing contract)."""
    base = plan_key("step", "q8_0", 4, 16)
    assert base == ("step", "q8_0", 4, 16)
    assert plan_key("step", "q8_0", 4, 16, mesh=None) == base
    keyed = plan_key("step", "q8_0", 4, 16, mesh=MESH4)
    assert keyed != base
    assert keyed[:len(base)] == base
    # signature tuples are accepted directly (what engines cache)
    assert plan_key("step", "q8_0", 4, 16, mesh=SIG4) == keyed
    # different mesh geometry -> different key
    mesh2 = abstract_mesh((2, 2), ("data", "model"))
    assert plan_key("step", "q8_0", 4, 16, mesh=mesh2) != keyed


def test_plan_entry_mesh_separates_signatures():
    kw = dict(quantized=True, vmem_budget_kb=8 * 1024, default_burst=256)
    e1 = plan_linear("l", 4, 384, 384, **kw)
    em = plan_linear("l", 4, 384, 384, mesh_sig=SIG4, **kw)
    assert e1.mesh is None and em.mesh == SIG4
    assert e1 != em                       # frozen dataclass equality
    assert e1 == plan_linear("l", 4, 384, 384, **kw)   # still deterministic


# ---------------------------------------------------------------------------
# per-device ledger attribution (DESIGN.md §13.3)
# ---------------------------------------------------------------------------
def test_ledger_by_device_sums_to_flop_total():
    led = OffloadLedger()
    kw = dict(vmem_budget_kb=8 * 1024, default_burst=256, mesh_sig=SIG4)
    offloaded = plan_linear("a", 4, 384, 384, quantized=True, **kw)
    fallback = plan_linear("b", 4096, 4096, 4096, quantized=False, **kw)
    assert offloaded.offload and not fallback.offload
    led.account(offloaded, times=3)
    led.account(fallback, times=2)
    s = led.totals
    total = s.offloaded_flops + s.fallback_flops + s.residual_flops
    assert sum(s.by_device.values()) == total
    assert set(s.by_device) == {f"dev{i}" for i in range(4)}


def test_ledger_by_device_unsharded_is_dev0():
    led = OffloadLedger()
    e = plan_linear("a", 4, 384, 384, quantized=True,
                    vmem_budget_kb=8 * 1024, default_burst=256)
    led.account(e, times=2)
    assert set(led.totals.by_device) == {"dev0"}
    assert led.totals.by_device["dev0"] == e.flops * 2


def test_offload_engine_stamps_mesh_sig():
    eng = OffloadEngine(mesh_sig=SIG4, prefer_pallas=False)
    assert eng.plan_entry(4, 384, 384, quantized=True).mesh == SIG4


# ---------------------------------------------------------------------------
# slot-state + serve-param specs (DESIGN.md §13.1)
# ---------------------------------------------------------------------------
def _slot_state(n_slots):
    # data leaves carry the batch on axis 1 already (the slot_layout
    # invariant); slot_layout broadcasts only the <=1-dim counters
    st = ServeState(
        layer_states={"k": jnp.zeros((2, n_slots, 8, 2, 4)),
                      "length": jnp.zeros((2,), jnp.int32)},
        step=jnp.zeros((), jnp.int32))
    return M.slot_layout(st, n_slots)


def test_slot_state_specs_shard_slot_axis():
    st = _slot_state(4)
    specs = M.slot_state_specs(st, MESH4)
    assert specs.step == P("data")
    assert specs.layer_states["k"] == P(None, "data")
    assert specs.layer_states["length"] == P(None, "data")


def test_slot_state_specs_indivisible_replicate():
    st = _slot_state(3)       # 3 slots on a 4-way data axis -> replicated
    specs = M.slot_state_specs(st, MESH4)
    assert specs.step == P()
    assert specs.layer_states["k"] == P()


def test_serve_param_specs_strip_fsdp_axis():
    pod = abstract_mesh((16, 16), ("data", "model"))
    params = {"attn": {"q": {"w": jnp.ones((256, 128))}},
              "norm": {"scale": jnp.ones((128,))}}
    train = rules.param_specs(params, pod)
    serve = rules.serve_param_specs(params, pod)
    assert train["attn"]["q"]["w"] == P("model", "data")
    assert serve["attn"]["q"]["w"] == P("model")   # replicated over data
    assert serve["norm"]["scale"] == P()


# ---------------------------------------------------------------------------
# the real thing: 4 forced host devices in a subprocess (conftest keeps
# this process at its 1-CPU view, like tests/test_dryrun_integration.py)
# ---------------------------------------------------------------------------
_PARITY_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
assert len(jax.devices()) == 4
from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.launch.mesh import make_serve_mesh
from repro.models import model as model_lib
from repro.serve.engine import ServeEngine

cfg = get_smoke_config("whisper-tiny")
params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 64)
rng = np.random.default_rng(0)
mels = [rng.standard_normal((1, 16, cfg.n_mels)).astype(np.float32)
        for _ in range(6)]
max_news = [int(rng.integers(3, 10)) for _ in range(6)]

def serve(mesh):
    eng = ServeEngine(cfg, params, max_len=24, quant="q8_0", eos_id=-1,
                      offload=OffloadEngine(interpret=True,
                                            prefer_pallas=False),
                      mesh=mesh)
    sched = eng.scheduler(n_slots=4, n_frames=16)
    rids = [sched.submit(m, max_new=mn) for m, mn in zip(mels, max_news)]
    got = sched.run()
    return eng, sched, [got[r].tokens for r in rids]

eng1, s1, t1 = serve(None)
engm, sm, tm = serve(make_serve_mesh())
# token-exact parity on the same arrival trace
assert t1 == tm, "sharded decode diverged from single-device tokens"
# zero retraces: ONE step trace per engine across the whole schedule
assert eng1._step_traces == 1 and engm._step_traces == 1, (
    eng1._step_traces, engm._step_traces)
# same shapes, distinct plan-cache entries (mesh signature)
assert not set(eng1._plans.plans) & set(engm._plans.plans)
# pool really sharded, admission balanced across device-local ranges
assert sm.pool.n_shards == 4 and sm.pool.shard_size == 1
st = engm.offload.stats
total = st.offloaded_flops + st.fallback_flops + st.residual_flops
by_dev = engm.energy_report([])["dispatch"]["by_device"]
assert sum(by_dev.values()) == total and len(by_dev) == 4
print("PARITY_OK")
"""


@pytest.mark.slow
def test_sharded_parity_zero_retrace_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    cp = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT],
                        capture_output=True, text=True, timeout=560,
                        env=env)
    assert cp.returncode == 0, cp.stdout[-2000:] + cp.stderr[-2000:]
    assert "PARITY_OK" in cp.stdout


def test_shard_aware_acquire_balances():
    """Device-local admission (DESIGN.md §13.2): with 8 slots on 4 shards,
    the first 4 acquisitions land one per shard; release/reacquire prefers
    the emptiest shard. Pure free-list logic — no devices needed."""
    from repro.serve.kvcache import SlotKVPool
    pool = object.__new__(SlotKVPool)
    pool.n_slots, pool.n_shards, pool.shard_size = 8, 4, 2
    pool._init_free()
    picks = [pool.acquire() for _ in range(4)]
    assert sorted(p // 2 for p in picks) == [0, 1, 2, 3]
    # shard 0 frees both its slots -> next admission goes there
    pool.release(0, reset=False)
    pool.release(1, reset=False)
    assert pool.acquire() // 2 == 0
