"""Per-arch smoke tests (assignment requirement: reduced config, one
forward/train step on CPU, output shapes + no NaNs) plus the deeper model
invariants: prefill==decode, SSD chunked==recurrent, MoE==dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.configs.registry import ASSIGNED, get_config, get_smoke_config
from repro.models import model as M
from repro.models import moe as moe_lib
from repro.models import ssm as S
from repro.train.step import init_train_state, make_train_step

ARCHS = sorted(ASSIGNED)


def _batch(cfg, b=2, s=16, key=7):
    toks = jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["mel"] = jnp.ones((b, s, cfg.n_mels), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((b, 4, cfg.vision_embed_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    b, s = 2, 16
    logits, aux = M.forward(params, cfg, _batch(cfg, b, s))
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, 64)
    step = make_train_step(cfg, opt)
    batch = _batch(cfg)
    state2, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(state2.opt.count) == 1
    # params actually moved
    l0 = jax.tree_util.tree_leaves(state.params)[1]
    l1 = jax.tree_util.tree_leaves(state2.params)[1]
    assert not np.array_equal(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "qwen2.5-14b",
                                  "internlm2-20b", "mamba2-780m",
                                  "olmoe-1b-7b", "jamba-v0.1-52b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced logits == step-by-step decode (capacity made no-drop
    for MoE archs, since capacity-dropping is sequence-level by design)."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    full, _ = M.forward(params, cfg, {"tokens": toks, "labels": toks})
    st = M.init_serve_state(params, cfg, b, 32)
    outs = []
    for t in range(s):
        lg, st = M.serve_step(params, cfg, toks[:, t:t + 1], st)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(dec, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_whisper_prefill_decode_consistency():
    cfg = get_smoke_config("whisper-tiny")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    from repro.models import whisper as W
    b, s = 2, 10
    mel = jax.random.normal(jax.random.PRNGKey(5), (b, 12, cfg.n_mels))
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    full, _ = M.forward(params, cfg, {"mel": mel, "tokens": toks,
                                      "labels": toks})
    memory = W.encode(params, cfg, mel)
    st = M.init_serve_state(params, cfg, b, 32, memory=memory)
    outs = []
    for t in range(s):
        lg, st = M.serve_step(params, cfg, toks[:, t:t + 1], st)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(dec, np.float32),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_ssd_chunked_matches_recurrence(chunk):
    key = jax.random.PRNGKey(1)
    b, s, h, p, g, n = 2, 32, 4, 8, 2, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    y1, st1 = S.ssd_scan(x, dt, A, B, C, chunk)
    y2, st2 = S.ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st1, st2, rtol=2e-4, atol=2e-4)


def test_ssd_state_handoff():
    """Scanning two halves with carried state == one full scan — the
    invariant that makes chunked prefill + decode handoff correct."""
    key = jax.random.PRNGKey(2)
    b, s, h, p, g, n = 1, 24, 2, 4, 1, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    y_full, st_full = S.ssd_scan(x, dt, A, B, C, 8)
    y_a, st_a = S.ssd_scan(x[:, :8], dt[:, :8], A, B[:, :8], C[:, :8], 8)
    y_b, st_b = S.ssd_scan(x[:, 8:], dt[:, 8:], A, B[:, 8:], C[:, 8:], 8,
                           initial_state=st_a)
    np.testing.assert_allclose(jnp.concatenate([y_a, y_b], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_b, st_full, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_grouped_dispatch_matches_dense_oracle():
    cfg = get_smoke_config("olmoe-1b-7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     dispatch_group=8))
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    y, aux = moe_lib.moe_ffn(p, cfg, x)
    yo = moe_lib.moe_ffn_dense_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yo),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens():
    """With tiny capacity some tokens must be dropped (combine weight 0) —
    outputs differ from the no-drop oracle, but stay finite."""
    cfg = get_smoke_config("olmoe-1b-7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25,
                                     dispatch_group=16))
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_lib.moe_ffn(p, cfg, x)
    yo = moe_lib.moe_ffn_dense_oracle(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert not np.allclose(np.asarray(y), np.asarray(yo), atol=1e-5)


def test_arctic_dense_residual_branch():
    cfg = get_smoke_config("arctic-480b")
    assert cfg.moe.dense_residual_d_ff > 0
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert "dense" in p


# ---------------------------------------------------------------------------
# Pattern / config structure
# ---------------------------------------------------------------------------
def test_jamba_pattern():
    from repro.models.transformer import layer_pattern
    cfg = get_config("jamba-v0.1-52b")
    pat = layer_pattern(cfg)
    assert len(pat) == 8
    assert sum(1 for s in pat if s.mixer == "attn") == 1     # 1:7 interleave
    assert pat[4].mixer == "attn"                            # offset 4
    assert sum(1 for s in pat if s.ffn == "moe") == 4        # every other


def test_full_configs_match_assignment():
    """The exact figures from the assignment table."""
    specs = {
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    }
    for arch, (L, d, hq, hkv, dff, v) in specs.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == hq, arch
        assert cfg.num_kv_heads == hkv, arch
        assert cfg.d_ff == dff, arch
        assert cfg.vocab_size == v, arch
    assert get_config("arctic-480b").moe.num_experts == 128
    assert get_config("arctic-480b").moe.experts_per_token == 2
    assert get_config("olmoe-1b-7b").moe.num_experts == 64
    assert get_config("olmoe-1b-7b").moe.experts_per_token == 8
    assert get_config("jamba-v0.1-52b").moe.num_experts == 16
    assert get_config("mamba2-780m").ssm.d_state == 128


def test_param_counts_plausible():
    """n_params() should land near the nameplate sizes."""
    expect = {"phi3-mini-3.8b": (3.0e9, 4.5e9),
              "qwen1.5-110b": (0.9e11, 1.3e11),
              "mamba2-780m": (0.6e9, 1.0e9),
              "olmoe-1b-7b": (6e9, 8e9),
              "arctic-480b": (4.0e11, 5.5e11),
              "whisper-tiny": (3e7, 5e7)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo < n < hi, (arch, n)
    # MoE active < total
    cfg = get_config("olmoe-1b-7b")
    assert cfg.n_active_params() < 0.4 * cfg.n_params()
