"""Speculative decoding (DESIGN.md §17): acceptance-rule properties,
token-exact greedy parity across the smoke ladder, zero-retrace under
mixed accept lengths, and two-model ledger attribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.speculative import (SpecScheduler, SpeculativeEngine,
                                     accept_spec)
from tests._hyp import given, settings, st


@pytest.fixture(scope="module")
def ladder():
    tiny = get_smoke_config("whisper-tiny")
    base = get_smoke_config("whisper-base")
    tp = M.init_params(jax.random.PRNGKey(0), tiny)
    bp = M.init_params(jax.random.PRNGKey(1), base)
    return tiny, tp, base, bp


@pytest.fixture(scope="module")
def mel(ladder):
    tiny = ladder[0]
    return np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                        (2, 16, tiny.n_mels)), np.float32)


# ---------------------------------------------------------------------------
# the acceptance rule (pure, DESIGN.md §17.1)
# ---------------------------------------------------------------------------

def _greedy_reference(drafts_row, vtoks_row):
    """What feeding the verifier one token at a time would emit: walk the
    window; at position j the verifier (having consumed j+1 window tokens)
    emits vtoks[j]; the round ends the first time the draft's next feed
    disagrees with that emission."""
    out = []
    k = len(drafts_row)
    for j in range(k):
        out.append(int(vtoks_row[j]))
        if drafts_row[j] != vtoks_row[j]:
            return out
    out.append(int(vtoks_row[k]))
    return out


def test_accept_spec_deterministic_cases():
    # full accept: drafts == verifier emissions -> k accepted + bonus
    a, c, n = accept_spec(np.array([[5, 6, 7]]), np.array([[5, 6, 7, 8]]))
    assert (a, list(c[0, :n[0]])) == (3, [5, 6, 7, 8])
    # first-token mismatch: zero accepted, verifier's token emitted
    a, c, n = accept_spec(np.array([[5, 6, 7]]), np.array([[9, 6, 7, 8]]))
    assert (a, n, list(c[0, :1])) == (0, 1, [9])
    # mid-window mismatch: prefix kept, correction replaces the miss
    a, c, n = accept_spec(np.array([[5, 6, 7]]), np.array([[5, 9, 7, 8]]))
    assert (a, n, list(c[0, :2])) == (1, 2, [5, 9])


@pytest.mark.parametrize("k", [1, 4, 8])
def test_accept_spec_matches_sequential_greedy(k):
    rng = np.random.default_rng(k)
    drafts = rng.integers(0, 4, size=(5, k))
    vtoks = rng.integers(0, 4, size=(5, k + 1))
    accept_len, committed, n_emit = accept_spec(drafts, vtoks)
    for r in range(5):
        ref = _greedy_reference(drafts[r], vtoks[r])
        assert list(committed[r, :n_emit[r]]) == ref
        assert accept_len[r] == len(ref) - 1


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_accept_spec_property(data):
    """For ANY drafts/vtoks pair, the committed prefix equals what pure
    sequential greedy on the verifier would emit, and every round makes
    progress (n_emit >= 1)."""
    k = data.draw(st.integers(min_value=1, max_value=8))
    b = data.draw(st.integers(min_value=1, max_value=4))
    tok = st.integers(min_value=0, max_value=9)
    drafts = np.array(data.draw(st.lists(
        st.lists(tok, min_size=k, max_size=k), min_size=b, max_size=b)))
    vtoks = np.array(data.draw(st.lists(
        st.lists(tok, min_size=k + 1, max_size=k + 1),
        min_size=b, max_size=b)))
    accept_len, committed, n_emit = accept_spec(drafts, vtoks)
    assert (n_emit >= 1).all() and (n_emit == accept_len + 1).all()
    for r in range(b):
        assert list(committed[r, :n_emit[r]]) == _greedy_reference(
            drafts[r], vtoks[r])


def test_accept_spec_rejects_bad_shapes():
    with pytest.raises(ValueError):
        accept_spec(np.zeros((2, 3), int), np.zeros((2, 3), int))


# ---------------------------------------------------------------------------
# end-to-end parity with the verifier's own greedy decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 4, 8])
def test_spec_parity_dense(ladder, mel, k):
    """Random-init ladder: draft disagrees constantly, so this drives the
    correction/rollback path — tokens must still be exactly the
    verifier's greedy output."""
    tiny, tp, base, bp = ladder
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    ref = v.transcribe(mel, sot_id=1, max_new=10)
    spec = v.speculative(tiny, tp, k=k)
    got = spec.transcribe(mel, sot_id=1, max_new=10)
    assert [r.tokens for r in ref] == [g.tokens for g in got]
    assert all(len(g.tokens) == 10 for g in got)


def test_spec_parity_q8_offload(ladder, mel):
    tiny, tp, base, bp = ladder
    off = OffloadEngine(interpret=True)
    v = ServeEngine(base, bp, max_len=64, quant="q8_0", offload=off,
                    eos_id=-1)
    ref = v.transcribe(mel, sot_id=1, max_new=8)
    spec = v.speculative(tiny, tp, k=4)
    got = spec.transcribe(mel, sot_id=1, max_new=8)
    assert [r.tokens for r in ref] == [g.tokens for g in got]


def test_spec_self_draft_full_accept(ladder, mel):
    """Draft == verifier -> every window fully accepted: k+1 tokens per
    round, acceptance rate 1.0, and parity still holds (the bonus-token
    path)."""
    _, _, base, bp = ladder
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    ref = v.transcribe(mel, sot_id=1, max_new=12)
    spec = v.speculative(base, bp, k=3)
    got = spec.transcribe(mel, sot_id=1, max_new=12)
    assert [r.tokens for r in ref] == [g.tokens for g in got]
    assert spec.acceptance_rate() == 1.0
    assert spec.rounds == 3          # ceil(12 / (k+1))


def test_spec_eos_truncation(ladder):
    """A row whose verifier output hits EOS mid-window must cut at EOS
    inclusive (the _finalize contract) and freeze — laggard rows keep
    decoding without overflowing the frozen row's cache."""
    tiny, tp, base, bp = ladder
    mel2 = np.asarray(jax.random.normal(jax.random.PRNGKey(7),
                                        (2, 16, tiny.n_mels)), np.float32)
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    ref = v.transcribe(mel2, sot_id=1, max_new=10)
    eos = int(ref[0].tokens[3])      # forge an EOS that fires mid-stream
    v_eos = ServeEngine(base, bp, max_len=64, quant="none", eos_id=eos)
    ref_eos = v_eos.transcribe(mel2, sot_id=1, max_new=10)
    spec = v_eos.speculative(tiny, tp, k=4)
    got = spec.transcribe(mel2, sot_id=1, max_new=10)
    assert [r.tokens for r in ref_eos] == [g.tokens for g in got]
    assert any(len(r.tokens) < 10 for r in ref_eos)  # EOS actually fired


def test_spec_max_len_guard(ladder, mel):
    tiny, tp, base, bp = ladder
    v = ServeEngine(base, bp, max_len=16, quant="none", eos_id=-1)
    spec = v.speculative(tiny, tp, k=4)
    with pytest.raises(ValueError, match="max_len"):
        spec.transcribe(mel, sot_id=1, max_new=16)


def test_spec_vocab_mismatch_rejected(ladder):
    tiny, tp, base, bp = ladder
    import dataclasses
    bad = dataclasses.replace(tiny, vocab_size=tiny.vocab_size + 16)
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    with pytest.raises(ValueError, match="vocab"):
        v.speculative(bad, tp, k=4)


@pytest.mark.parametrize("case,exc,match", [
    ("k", ValueError, "k must be >= 1"),
    ("max_len", ValueError, "max_len too small"),
    ("vocab", ValueError, "vocabulary"),
    ("family", NotImplementedError, "audio family"),
])
def test_spec_post_init_guards(ladder, case, exc, match):
    """Every ``__post_init__`` guard fires with its documented exception
    and message — in the cheapest-first order the constructor checks
    them (plain int compares before config inspection), so a multiply-
    wrong setup surfaces the cheap error deterministically."""
    import dataclasses
    tiny, tp, base, bp = ladder
    k, max_len, dcfg = 4, 64, tiny
    if case == "k":
        k = 0
        # also multiply-wrong: tiny max_len would trip the NEXT guard,
        # proving order — the k guard must win
        max_len = 3
    elif case == "max_len":
        max_len = 5                      # k + 2 = 6 > 5
    elif case == "vocab":
        dcfg = dataclasses.replace(tiny, vocab_size=tiny.vocab_size + 16)
    elif case == "family":
        dcfg = dataclasses.replace(tiny, family="dense")
    v = ServeEngine(base, bp, max_len=max_len, quant="none", eos_id=-1)
    d = ServeEngine(dcfg, tp, max_len=max_len, quant="none", eos_id=-1,
                    offload=None)
    with pytest.raises(exc, match=match):
        SpeculativeEngine(verifier=v, draft=d, k=k)


# ---------------------------------------------------------------------------
# zero-retrace + two-model ledger attribution (DESIGN.md §17.2/§17.3)
# ---------------------------------------------------------------------------

def test_spec_zero_retrace_mixed_accepts(ladder, mel):
    """Mixed accept lengths are data, not shapes: after the first round
    the draft step, verify window, and rollback splice must all be cache
    hits — across repeat calls too."""
    tiny, tp, base, bp = ladder
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    spec = v.speculative(tiny, tp, k=4)
    spec.transcribe(mel, sot_id=1, max_new=10)
    v_traces, d_traces = v._verify_traces, spec.draft._step_traces
    assert (v_traces, d_traces) == (1, 1)
    spec.transcribe(mel, sot_id=1, max_new=10)
    assert v._verify_traces == v_traces
    assert spec.draft._step_traces == d_traces
    # every round emits 1..k+1 tokens per row -> bounded round count
    assert 4 <= spec.rounds <= 20 and spec.stats()["verify_traces"] == 1


def test_spec_ledger_by_role(ladder, mel):
    """Draft and verifier commit into ONE ledger with role tags; the
    by_role split must sum exactly to the flop totals (the by_device-
    shaped invariant, DESIGN.md §17.2)."""
    tiny, tp, base, bp = ladder
    off = OffloadEngine(interpret=True)
    v = ServeEngine(base, bp, max_len=64, quant="q8_0", offload=off,
                    eos_id=-1)
    spec = v.speculative(tiny, tp, k=4)
    spec.transcribe(mel, sot_id=1, max_new=8)
    s = off.stats
    assert spec.draft.offload is not None
    assert spec.draft.offload.ledger is off.ledger
    assert s.by_role.get("draft", 0) > 0 and s.by_role.get("verify", 0) > 0
    total = s.offloaded_flops + s.fallback_flops + s.residual_flops
    assert sum(s.by_role.values()) == total
    # draft pinned to the cheapest backend (DESIGN.md §12.3)
    assert spec.draft.offload.prefer_pallas is False


def test_spec_ledger_span_exactness(ladder, mel):
    """Interleaved draft/verify commits inside per-round ledger spans keep
    the §16.2 integer invariant: claimed span FLOPs == ledger delta."""
    tiny, tp, base, bp = ladder
    tele = obs.Telemetry()
    off = OffloadEngine(interpret=True)
    v = ServeEngine(base, bp, max_len=64, quant="q8_0", offload=off,
                    eos_id=-1, telemetry=tele)
    spec = v.speculative(tiny, tp, k=3)
    spec.transcribe(mel, sot_id=1, max_new=6)
    rep = tele.ledger_consistent()
    assert rep["exact"], rep
    assert rep["claimed_flops"] > 0


def test_spec_counters_consistent(ladder, mel):
    tiny, tp, base, bp = ladder
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    spec = v.speculative(tiny, tp, k=4)
    spec.transcribe(mel, sot_id=1, max_new=10)
    st_ = spec.stats()
    # rows that finish early stop drafting, so <= rounds * k * B
    assert 0 < st_["drafted"] <= spec.rounds * 4 * mel.shape[0]
    assert 0 <= st_["accepted"] <= st_["drafted"]
    assert st_["acceptance_rate"] == spec.acceptance_rate()


# ---------------------------------------------------------------------------
# plan keys + scheduler
# ---------------------------------------------------------------------------

def test_spec_plan_keys_role_tagged(ladder, mel):
    """Speculative programs must never collide with plain greedy plans at
    the same shapes: the verify key carries role+k, the draft step key its
    role (DESIGN.md §17.2)."""
    tiny, tp, base, bp = ladder
    off = OffloadEngine(interpret=True)
    v = ServeEngine(base, bp, max_len=64, quant="q8_0", offload=off,
                    eos_id=-1)
    v.transcribe(mel, sot_id=1, max_new=4)          # plain keys first
    spec = v.speculative(tiny, tp, k=4)
    spec.transcribe(mel, sot_id=1, max_new=4)
    v_keys = set(v._plans.plans)
    assert any(("role", "verify") in k and ("k", 4) in k for k in v_keys
               if isinstance(k, tuple))
    d_keys = set(spec.draft._plans.plans)
    assert any(("role", "draft") in k for k in d_keys
               if isinstance(k, tuple))


def test_spec_scheduler_waves(ladder, mel):
    """Wave scheduler: per-request max_new truncation, short-wave padding,
    and token parity with the verifier's one-shot transcribe."""
    tiny, tp, base, bp = ladder
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    sch = SpecScheduler(v.speculative(tiny, tp, k=4), n_slots=2)
    rids = [sch.submit(mel[0], max_new=6), sch.submit(mel[1], max_new=10),
            sch.submit(mel[0], max_new=8)]
    assert sch.n_queued == 3
    res = sch.run()
    assert sch.n_queued == 0
    ref = v.transcribe(mel, sot_id=1, max_new=10)
    assert res[rids[0]].tokens == ref[0].tokens[:6]
    assert res[rids[1]].tokens == ref[1].tokens[:10]
    assert res[rids[2]].tokens == ref[0].tokens[:8]
    assert res[rids[2]].steps == 8


def test_spec_scheduler_rejects_mixed_frames(ladder, mel):
    tiny, tp, base, bp = ladder
    v = ServeEngine(base, bp, max_len=64, quant="none", eos_id=-1)
    sch = SpecScheduler(v.speculative(tiny, tp, k=2), n_slots=4)
    sch.submit(mel[0], max_new=4)
    sch.submit(np.zeros((8, tiny.n_mels), np.float32), max_new=4)
    with pytest.raises(ValueError, match="frame"):
        sch.run()


# ---------------------------------------------------------------------------
# backend forcing composition (the CI xla_ref matrix leg)
# ---------------------------------------------------------------------------

def test_spec_parity_under_backend_forcing(ladder, mel, monkeypatch):
    """REPRO_BACKEND=xla_ref outranks both the draft's pin and the
    verifier's routing (DESIGN.md §12.2) — parity and the ledger split
    must survive the forcing."""
    monkeypatch.setenv("REPRO_BACKEND", "xla_ref")
    tiny, tp, base, bp = ladder
    off = OffloadEngine(interpret=True)
    v = ServeEngine(base, bp, max_len=64, quant="q8_0", offload=off,
                    eos_id=-1)
    ref = v.transcribe(mel, sot_id=1, max_new=6)
    spec = v.speculative(tiny, tp, k=3)
    got = spec.transcribe(mel, sot_id=1, max_new=6)
    assert [r.tokens for r in ref] == [g.tokens for g in got]
    s = off.stats
    # forcing retargets every forceable main segment; only the structural
    # host-residual arm (forceable=False) may remain (DESIGN.md §12.2)
    assert set(s.by_backend) <= {"xla_ref", "host_residual"}
    assert "pallas_tpu" not in s.by_backend
    total = s.offloaded_flops + s.fallback_flops + s.residual_flops
    assert sum(s.by_role.values()) == total
