"""Pallas flash-attention kernel vs the jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd, vmem_claim_bytes


def _ref(q, k, v, causal):
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * q.shape[-1] ** -0.5
    if causal:
        qp = jnp.arange(q.shape[1])[:, None]
        kp = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(kp <= qp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("bh,sq,sk,d,bq,bk,causal", [
    (2, 64, 64, 32, 32, 32, True),
    (1, 128, 128, 64, 64, 64, True),
    (2, 64, 128, 32, 32, 64, False),
    (3, 96, 96, 16, 32, 32, True),
])
def test_flash_kernel_vs_ref(bh, sq, sk, d, bq, bk, causal):
    ks = jax.random.split(jax.random.PRNGKey(sq + sk), 3)
    q = jax.random.normal(ks[0], (bh, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (bh, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (bh, sk, d), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, block_q=bq,
                              block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(_ref(q, k, v, causal)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_kernel_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(ks[0], (1, 64, 32)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (1, 64, 32)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (1, 64, 32)) * 0.5).astype(dtype)
    got = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    want = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    assert got.dtype == jnp.float32


def test_ragged_rejected():
    q = jnp.ones((1, 60, 32))
    with pytest.raises(ValueError):
        flash_attention_fwd(q, q, q, block_q=32, block_k=32, interpret=True)


def test_vmem_claim_monotone():
    base = vmem_claim_bytes(256, 512, 128)
    assert vmem_claim_bytes(512, 512, 128) > base
    assert vmem_claim_bytes(256, 1024, 128) > base
    # default tiling fits v5e VMEM (~16 MiB) comfortably
    assert base < 4 * 2**20
