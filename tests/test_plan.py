"""Plan/ledger split (DESIGN.md §10): plan determinism, plan-cache hits,
ledger equivalence with the old in-trace counters, and jit purity of the
engine-attached decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine, OffloadLedger, OffloadStats
from repro.core.plan import DispatchPlan, PlanCache, plan_linear, record_plan
from repro.core.qformats import quantize_q8_0
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.tuning import Autotuner


@pytest.fixture(scope="module")
def whisper_setup():
    cfg = get_smoke_config("whisper-tiny")
    params = M.init_params(jax.random.PRNGKey(0), cfg, 64)
    return cfg, params


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------
def test_plan_linear_deterministic():
    kw = dict(quantized=True, vmem_budget_kb=8 * 1024, default_burst=256,
              tuner=None)
    a = plan_linear("ffn.up", 8, 384, 1536, **kw)
    b = plan_linear("ffn.up", 8, 384, 1536, **kw)
    assert a == b
    assert a.offload and a.dtype == "q8_0"
    assert a.k_main + a.k_res == a.k
    assert a.offloaded_flops + a.residual_flops == a.flops


def test_plan_linear_deterministic_with_tuner():
    """With a tuner, the first call may search; repeats are cache hits that
    resolve to the identical entry (including the tiling)."""
    tun = Autotuner(vmem_budget_bytes=2**21, mode="analytic")
    kw = dict(quantized=True, vmem_budget_kb=8 * 1024, default_burst=256,
              tuner=tun)
    a = plan_linear("q", 8, 64, 32, **kw)
    n_searches = tun.searches
    b = plan_linear("q", 8, 64, 32, **kw)
    assert a == b and a.tuned
    assert tun.searches == n_searches       # repeat resolution: dict hits


def test_plan_entry_fallback_accounting():
    e = plan_linear("big", 1024, 1024, 8, quantized=False, vmem_budget_kb=1,
                    default_burst=32, tuner=None)
    assert not e.offload
    assert e.fallback_flops == e.flops
    assert e.offloaded_flops == 0 and e.residual_flops == 0


def test_record_plan_deterministic(whisper_setup):
    """Two recordings of the same traced program yield identical routing —
    the static-shape-keyed decision property of the companion papers."""
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0",
                      offload=OffloadEngine(prefer_pallas=False), eos_id=-1)
    mel = jnp.zeros((1, 8, cfg.n_mels), jnp.float32)
    p1 = record_plan(eng.offload, eng._prefill_fn, eng._serve_params, mel)
    p2 = record_plan(eng.offload, eng._prefill_fn, eng._serve_params, mel)
    assert len(p1) > 0
    assert p1.signature() == p2.signature()
    # recording is accounting-free: nothing reached the ledger
    assert eng.offload.stats.offloaded_calls == 0
    assert eng.offload.stats.fallback_calls == 0


def test_plan_summary_totals():
    plan = DispatchPlan(key="k")
    plan.add(plan_linear("a", 8, 64, 32, quantized=True,
                         vmem_budget_kb=8 * 1024, default_burst=32,
                         tuner=None))
    plan.add(plan_linear("b", 1024, 1024, 8, quantized=False,
                         vmem_budget_kb=1, default_burst=32, tuner=None))
    s = plan.summary()
    assert s["calls"] == 2 and s["offloaded"] == 1
    assert s["fallback_flops"] == 2 * 1024 * 1024 * 8


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------
def test_plan_cache_hits_across_repeated_transcribe(whisper_setup):
    cfg, params = whisper_setup
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0",
                      offload=OffloadEngine(prefer_pallas=False), eos_id=-1)
    mel = np.zeros((2, 8, cfg.n_mels), np.float32)
    eng.transcribe(mel, max_new=3)
    n_plans = len(eng._plans)
    assert n_plans == 2                      # prefill + step
    assert eng._plans.misses == 2 and eng._plans.hits == 0
    eng.transcribe(mel, max_new=3)
    assert len(eng._plans) == n_plans        # steady state: no new plans
    assert eng._plans.hits == 2
    # a different batch shape is a different routing point
    eng.transcribe(np.zeros((1, 8, cfg.n_mels), np.float32), max_new=3)
    assert len(eng._plans) == 4


def test_plan_cache_get_or_build():
    pc = PlanCache()
    built = []

    def build():
        built.append(1)
        return DispatchPlan()

    p1 = pc.get_or_build(("k", 1), build)
    p2 = pc.get_or_build(("k", 1), build)
    assert p1 is p2 and len(built) == 1
    assert pc.hits == 1 and pc.misses == 1


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------
def test_ledger_commit_multiplies():
    led = OffloadLedger()
    plan = DispatchPlan()
    plan.add(plan_linear("x", 1, 64, 32, quantized=True,
                         vmem_budget_kb=8 * 1024, default_burst=32,
                         tuner=None))
    led.commit(plan, times=5)
    assert led.totals.offloaded_calls == 5
    assert led.totals.by_kernel["x"] == 5
    led.commit(None, times=3)                # no plan: no-op
    assert led.totals.offloaded_calls == 5


def test_ledger_matches_eager_reference_on_whisper_q8(whisper_setup):
    """The acceptance check of DESIGN.md §10.2: committed ledger totals on
    the whisper Q8_0 workload equal what the pre-refactor in-trace counters
    reported — i.e. an eager (un-jitted) run of the identical program."""
    cfg, params = whisper_setup
    mel = np.random.default_rng(0).standard_normal(
        (2, 8, cfg.n_mels)).astype(np.float32)
    max_new = 4

    served = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0", offload=served,
                      eos_id=-1)
    res = eng.transcribe(mel, max_new=max_new)
    steps = res[0].steps

    # reference with the OLD counting semantics: run the identical program
    # un-jitted, recording every linear call of every execution and
    # committing each execution once — exactly what the pre-refactor
    # in-trace counters added up when the decode fn could not jit
    ref = OffloadEngine(prefer_pallas=False)
    import repro.models.whisper as W
    p = DispatchPlan()
    with ref.recording(p):
        memory = W.encode(eng._serve_params, cfg, jnp.asarray(mel),
                          engine=ref)
        state = M.init_serve_state(eng._serve_params, cfg, mel.shape[0], 16,
                                   memory=memory, engine=ref)
    ref.ledger.commit(p, times=1)
    token = jnp.full((mel.shape[0], 1), 1, jnp.int32)
    for _ in range(steps):
        p = DispatchPlan()
        with ref.recording(p):
            logits, state = M.serve_step(eng._serve_params, cfg, token,
                                         state, engine=ref)
        ref.ledger.commit(p, times=1)
        token = jnp.argmax(
            logits[:, -1, :cfg.vocab_size], axis=-1).astype(jnp.int32)[:, None]

    assert served.stats.offloaded_calls == ref.stats.offloaded_calls
    assert served.stats.fallback_calls == ref.stats.fallback_calls
    assert served.stats.tuned_calls == ref.stats.tuned_calls
    assert served.stats.offloaded_flops == ref.stats.offloaded_flops
    assert served.stats.fallback_flops == ref.stats.fallback_flops
    assert served.stats.residual_flops == ref.stats.residual_flops
    assert served.stats.by_kernel == ref.stats.by_kernel


# ---------------------------------------------------------------------------
# Jit purity
# ---------------------------------------------------------------------------
def test_serve_step_jits_with_engine_attached(whisper_setup):
    """The tentpole regression test: serve_step is traceable/compilable
    with an offload engine, tracing leaves no accounting residue, and the
    serving engine's step really is wrapped in jax.jit."""
    cfg, params = whisper_setup
    off = OffloadEngine(prefer_pallas=False)
    eng = ServeEngine(cfg, params, max_len=16, quant="q8_0", offload=off,
                      eos_id=-1)
    assert isinstance(eng._decode_jit, jax.stages.Wrapped)
    assert isinstance(eng._step_jit, jax.stages.Wrapped)
    assert isinstance(eng._prefill_jit, jax.stages.Wrapped)

    mel = jnp.zeros((1, 8, cfg.n_mels), jnp.float32)
    memory, state = eng._prefill_jit(eng._serve_params, mel)
    token = jnp.full((1, 1), 1, jnp.int32)
    before = OffloadStats(**{k: (dict(v) if isinstance(v, dict) else v)
                             for k, v in vars(off.stats).items()})
    # abstract tracing of the engine-attached step must be side-effect free
    jax.eval_shape(eng._decode_fn, eng._serve_params, token, state)
    assert vars(off.stats) == vars(before)
    # and the compiled step executes (twice — no trace-count dependence)
    l1, s1 = eng._decode_jit(eng._serve_params, token, state)
    l2, _ = eng._decode_jit(eng._serve_params, token, state)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_eager_linear_still_accounts():
    """Standalone dispatcher API keeps its pre-§10 accounting: concrete
    (eager) calls hit the ledger directly."""
    eng = OffloadEngine(burst=32, prefer_pallas=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    wq = quantize_q8_0(jax.random.normal(jax.random.PRNGKey(1), (32, 64)))
    eng.linear(x, wq, name="eager")
    assert eng.stats.offloaded_calls == 1
    assert eng.stats.by_kernel["eager"] == 1


def test_traced_linear_without_recording_is_pure():
    """Inside someone else's jit trace (no recording active), linear must
    not account — that was exactly the old impurity."""
    eng = OffloadEngine(burst=32, prefer_pallas=False)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 64))

    @jax.jit
    def f(x):
        return eng.linear(x, w, name="traced")

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    y1 = f(x)
    y2 = f(x)                                # cache hit: no re-trace
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert eng.stats.offloaded_calls == 0
    assert eng.stats.fallback_calls == 0
