#!/usr/bin/env python
"""Perfetto trace validator (CI): structural checks on the trace_event
JSON that ``repro.obs.export`` emits (DESIGN.md §16.4).

Checks, per file:

  - top level is an object with a ``traceEvents`` list
  - every event has ``ph`` in {X, i, M, B, E} and integer-valued
    ``ts``/``pid``/``tid`` (metadata ``M`` events are exempt from ts)
  - complete events (``X``) carry ``dur >= 0`` and ``ts >= 0``
  - non-metadata events are in non-decreasing ``ts`` order (the exporter
    sorts; an unsorted trace means a clock or merge bug)
  - duration events balance per (pid, tid): every ``E`` matches an open
    ``B``, and leftover ``B`` events are reported — an unclosed lifecycle
    phase is exactly the leak the §16.2 closure invariant forbids

Run from the repo root:

  python tools/check_trace.py PATH [PATH ...]

Exit code 0 when every file validates; 1 otherwise (CI gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

VALID_PH = {"X", "i", "M", "B", "E"}


def validate(obj: Any) -> List[str]:
    """Return a list of human-readable problems (empty == valid)."""
    errors: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    last_ts = None
    open_b: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event[{i}]: not an object")
            continue
        ph = ev.get("ph")
        if ph not in VALID_PH:
            errors.append(f"event[{i}]: unknown ph {ph!r}")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event[{i}] ({ev.get('name')}): bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(f"event[{i}] ({ev.get('name')}): ts {ts} < "
                          f"previous {last_ts} (trace not sorted)")
        last_ts = ts
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event[{i}] ({ev.get('name')}): "
                              f"bad dur {dur!r}")
        elif ph == "B":
            open_b[key] = open_b.get(key, 0) + 1
        elif ph == "E":
            if open_b.get(key, 0) <= 0:
                errors.append(f"event[{i}]: 'E' with no open 'B' on "
                              f"track {key}")
            else:
                open_b[key] -= 1
    for key, n in sorted(open_b.items(), key=str):
        if n:
            errors.append(f"track {key}: {n} unclosed 'B' event(s) — "
                          "open lifecycle phase leaked (§16.2 closure)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+", help="trace_event JSON files")
    args = ap.parse_args(argv)
    bad = 0
    for path in args.paths:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{path}: unreadable ({e})")
            bad += 1
            continue
        errors = validate(obj)
        if errors:
            bad += 1
            print(f"{path}: INVALID")
            for e in errors:
                print(f"  - {e}")
        else:
            n = len(obj["traceEvents"])
            print(f"{path}: ok ({n} events)")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
