#!/usr/bin/env python
"""Docs-drift gate, bidirectional (CI):

forward   every ``DESIGN.md §X[.Y]`` cross-reference in the codebase must
          resolve to a section heading in DESIGN.md. A reference ``§6.3``
          is satisfied by a heading containing ``§6.3``; a bare ``§6`` is
          satisfied by ``§6`` itself (subsection headings do not satisfy
          their parent).
reverse   every top-level ``## §N`` section of DESIGN.md must be cited at
          least once from the scanned tree — a section nothing points at
          is drift in the other direction (stale design text, or code
          that silently stopped honoring it).
docstring every module under src/repro/serve/, src/repro/backends/, and
          src/repro/obs/ must open with a module docstring citing its
          DESIGN.md section (the serving/backend/observability layers
          are where the design doc and the code co-evolve fastest).

Run from the repo root:

  python tools/check_design_refs.py [--root PATH]

Exit code 0 when all three checks pass; 1 otherwise (CI gate).
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys

REF_RE = re.compile(r"DESIGN\.md\s+§([0-9]+(?:\.[0-9]+)?)")
HEADING_RE = re.compile(r"^#{1,6}\s+§([0-9]+(?:\.[0-9]+)?)\b", re.M)
TOP_HEADING_RE = re.compile(r"^##\s+§([0-9]+)\b", re.M)
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SCAN_EXTS = (".py", ".md")
DOCSTRING_DIRS = (os.path.join("src", "repro", "serve"),
                  os.path.join("src", "repro", "backends"),
                  os.path.join("src", "repro", "obs"))


def collect_refs(root: str):
    refs = {}          # section -> [file:line]
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, _, files in os.walk(base):
            for fn in files:
                if not fn.endswith(SCAN_EXTS):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8", errors="replace") as f:
                    for i, line in enumerate(f, 1):
                        for sec in REF_RE.findall(line):
                            rel = os.path.relpath(path, root)
                            refs.setdefault(sec, []).append(f"{rel}:{i}")
    return refs


def collect_anchors(root: str):
    path = os.path.join(root, "DESIGN.md")
    if not os.path.exists(path):
        return None, None
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return set(HEADING_RE.findall(text)), set(TOP_HEADING_RE.findall(text))


def check_docstrings(root: str):
    """Modules that must cite their DESIGN section from their docstring.
    Returns [(relpath, why)] failures."""
    bad = []
    for d in DOCSTRING_DIRS:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for fn in sorted(os.listdir(base)):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(base, fn)
            rel = os.path.relpath(path, root)
            with open(path, encoding="utf-8", errors="replace") as f:
                src = f.read()
            try:
                doc = ast.get_docstring(ast.parse(src))
            except SyntaxError:
                bad.append((rel, "does not parse"))
                continue
            if not doc:
                bad.append((rel, "no module docstring"))
            elif not REF_RE.search(doc):
                bad.append((rel, "docstring cites no DESIGN.md section"))
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    args = ap.parse_args(argv)
    anchors, top_sections = collect_anchors(args.root)
    if anchors is None:
        print("FAIL: DESIGN.md does not exist")
        return 1
    refs = collect_refs(args.root)
    print(f"{sum(len(v) for v in refs.values())} references to "
          f"{len(refs)} distinct sections; {len(anchors)} anchors in "
          "DESIGN.md")
    failed = False

    missing = {s: locs for s, locs in refs.items() if s not in anchors}
    if missing:
        failed = True
        for sec in sorted(missing):
            print(f"FAIL: §{sec} referenced but has no DESIGN.md heading:")
            for loc in missing[sec][:5]:
                print(f"    {loc}")

    # reverse direction: a top-level section counts as cited if it — or
    # any of its subsections — is referenced somewhere in the tree
    cited_tops = {s.split(".")[0] for s in refs}
    uncited = sorted(top_sections - cited_tops, key=int)
    if uncited:
        failed = True
        for sec in uncited:
            print(f"FAIL: DESIGN.md ## §{sec} is cited by nothing in "
                  f"{'/'.join(SCAN_DIRS)} — stale section or missing "
                  "docstring reference")

    for rel, why in check_docstrings(args.root):
        failed = True
        print(f"FAIL: {rel}: {why} (serve/ and backends/ modules must "
              "cite their DESIGN.md section)")

    if failed:
        return 1
    print("ok: all DESIGN.md references resolve, every top-level section "
          "is cited, and serve/backends docstrings cite their sections")
    return 0


if __name__ == "__main__":
    sys.exit(main())
