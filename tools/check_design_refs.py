#!/usr/bin/env python
"""Docs check: every ``DESIGN.md §X[.Y]`` cross-reference in the codebase
must resolve to a section heading in DESIGN.md.

A reference ``§6.3`` is satisfied by a heading containing ``§6.3``; a bare
``§6`` is satisfied by ``§6`` itself (subsection headings do not satisfy
their parent). Run from the repo root:

  python tools/check_design_refs.py [--root PATH]

Exit code 0 when all references resolve; 1 otherwise (CI gate).
"""
from __future__ import annotations

import argparse
import os
import re
import sys

REF_RE = re.compile(r"DESIGN\.md\s+§([0-9]+(?:\.[0-9]+)?)")
HEADING_RE = re.compile(r"^#{1,6}\s+§([0-9]+(?:\.[0-9]+)?)\b", re.M)
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SCAN_EXTS = (".py", ".md")


def collect_refs(root: str):
    refs = {}          # section -> [file:line]
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, _, files in os.walk(base):
            for fn in files:
                if not fn.endswith(SCAN_EXTS):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8", errors="replace") as f:
                    for i, line in enumerate(f, 1):
                        for sec in REF_RE.findall(line):
                            rel = os.path.relpath(path, root)
                            refs.setdefault(sec, []).append(f"{rel}:{i}")
    return refs


def collect_anchors(root: str):
    path = os.path.join(root, "DESIGN.md")
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as f:
        return set(HEADING_RE.findall(f.read()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    args = ap.parse_args(argv)
    anchors = collect_anchors(args.root)
    if anchors is None:
        print("FAIL: DESIGN.md does not exist")
        return 1
    refs = collect_refs(args.root)
    missing = {s: locs for s, locs in refs.items() if s not in anchors}
    print(f"{sum(len(v) for v in refs.values())} references to "
          f"{len(refs)} distinct sections; {len(anchors)} anchors in "
          "DESIGN.md")
    if missing:
        for sec in sorted(missing):
            print(f"FAIL: §{sec} referenced but has no DESIGN.md heading:")
            for loc in missing[sec][:5]:
                print(f"    {loc}")
        return 1
    print("ok: all DESIGN.md section references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
