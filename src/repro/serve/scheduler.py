"""Continuous-batching serve scheduler (DESIGN.md §11).

``ServeEngine.generate``/``transcribe`` decode static run-to-completion
batches: finished utterances keep burning jitted steps and new arrivals
head-of-line block until the whole batch drains — exactly the utilization
loss the paper's sustained multi-utterance evaluation (and the ROADMAP's
heavy-traffic north star) forbids. This scheduler decodes a fixed-width
slot batch instead (width ``n_slots`` static, so the engine's jitted
``step_fn`` and its ``PlanCache``/ledger machinery keep working with zero
retraces), admits queued requests into freed slots *between* steps, evicts
on EOS/max_new, and streams per-request tokens as they are produced.

Mechanics per step (DESIGN.md §11.2):
  admit   — one jitted batch-1 prefill per queued request (whisper
            encoder + cross-KV, or LM prompt scan), spliced into a free
            slot by ``kvcache.slot_insert``; prefill wall-time and its
            dispatch-plan ledger commit are attributed to that request
            exactly.
  decode  — ONE execution of the engine's fixed-shape ``step_fn`` over
            all ``n_slots`` rows (free slots compute garbage — the
            fixed-shape contract); its plan commits once per executed
            step, and its wall-time is split over the slots active that
            step, so per-request PDP attribution is exact-by-steps-lived
            rather than batch-averaged, and per-request totals sum to the
            batch total (DESIGN.md §11.3).
  evict   — EOS or ``max_new`` reached: the request's ``GenerationResult``
            is finalized from its per-slot step counter and the slot is
            returned to the free list (its row is overwritten whole by
            the next admission; ``kvcache.slot_reset`` exists for callers
            that want freed rows zeroed eagerly).

Plan keys are shared with the one-shot paths via ``ServeEngine._key``
(DESIGN.md §11.3): the slot-batched step at ``(n_slots, n_frames)`` IS
the static decode step at that shape, so no plan is ever re-recorded.
With a serving mesh attached (DESIGN.md §13) the pool's slot axis shards
over the mesh's "data" axis, admission targets device-local slot ranges
(``SlotKVPool.acquire`` balances across shards), and every plan key
carries the mesh signature so sharded steps never reuse unsharded plans.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.kvcache import SlotKVPool


@dataclass
class TokenEvent:
    """One streamed token: produced by request ``rid`` at its (1-based)
    per-request step ``step``; ``done`` marks the request's last token."""
    rid: int
    token: int
    step: int
    done: bool


@dataclass
class _QueuedRequest:
    rid: int
    payload: np.ndarray          # (1, F, n_mels) mel | (1, S) i32 prompt
    max_new: int
    sot_id: int = 1
    submit_t: float = 0.0        # perf_counter at submit: queue-wait base


@dataclass
class _ActiveSlot:
    rid: int
    max_new: int
    tokens: List[int] = field(default_factory=list)
    steps: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    # lifecycle timings (DESIGN.md §16.1), carried into GenerationResult
    submit_t: float = 0.0
    queue_wait_s: float = 0.0
    ttft_s: float = 0.0


class ContinuousBatchingScheduler:
    """Slot-batched continuous decode over a ``ServeEngine``.

    The engine supplies the jitted prefill/step functions, the serving
    params, the plan cache, and the offload ledger; the scheduler owns the
    ``SlotKVPool``, the admission queue, and per-request attribution.
    ``n_frames`` (audio only) fixes the pool's mel-frame capacity —
    admitted utterances are zero-padded to it so prefill and the slot
    splice see one static shape (real Whisper pads every utterance to the
    30 s window the same way).
    """

    def __init__(self, engine: ServeEngine, n_slots: int = 4,
                 n_frames: Optional[int] = None):
        self.engine = engine
        # the engine's nullable telemetry handle (DESIGN.md §16.2) — every
        # instrumentation site below is one ``is not None`` test when off
        self.telemetry = engine.telemetry
        if self.telemetry is not None:
            # pre-resolved per-step instruments + a change-gated gauge
            # cache: decode_step is the hot loop the ≤3% overhead budget
            # (benchmarks/telemetry_overhead.py) prices, so it must not
            # pay a registry lookup per metric per step
            m = self.telemetry.metrics
            self._step_instruments = (m.counter("repro_tokens_total"),
                                      m.histogram("repro_step_seconds"),
                                      m.histogram("repro_token_seconds"))
            self._step_gauges = (m.gauge("repro_queue_depth"),
                                 m.gauge("repro_slots_active"),
                                 m.gauge("repro_step_traces"),
                                 m.gauge("repro_kv_utilization"))
            self._gauge_state = None
            # per-step metric observations buffer in plain lists/ints on
            # the hot path and drain into the registry off it (run()/
            # attribution()/flush_telemetry) — registry calls are ~1-2 µs
            # each cold, and a decode step makes several (DESIGN.md §16.4)
            self._buf_steps: List[float] = []
            self._buf_shares: List[float] = []
            self._buf_ttft: List[float] = []
            self._buf_tokens = 0
            self._buf_finished = 0
        self.n_slots = n_slots
        cfg = engine.cfg
        self._audio = cfg.family == "audio"
        if self._audio and n_frames is None:
            raise ValueError("audio scheduler needs n_frames (the pool's "
                             "fixed mel-frame capacity)")
        self.n_frames = n_frames
        self.pool = self._make_pool()
        self.queue: Deque[_QueuedRequest] = deque()
        self.finished: Dict[int, GenerationResult] = {}
        self._active: Dict[int, _ActiveSlot] = {}      # slot -> request
        # device-resident next-token buffer: decode feeds the previous
        # step's output back without a host->device upload per step
        self._tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self._done0 = jnp.zeros((n_slots,), bool)      # step_fn done input
        if engine.mesh is not None and self.pool.n_shards > 1:
            # pin the per-slot buffers to the pool's slot sharding so the
            # sharded decode step reads device-local tokens (DESIGN.md §13)
            from jax.sharding import NamedSharding, PartitionSpec as P
            mesh = engine.mesh
            self._tokens = jax.device_put(
                self._tokens, NamedSharding(mesh, P("data", None)))
            self._done0 = jax.device_put(
                self._done0, NamedSharding(mesh, P("data")))
        self._next_rid = 0
        self._step_plan_ready = False
        self._step_plan = None
        # independently accumulated busy wall-time (every prefill + every
        # batch step, measured whole): the other side of the §11.3
        # attribution invariant, NOT derived from per-request shares.
        # _claimed_s is the busy time of results already handed out by
        # run(), so attribution stays exact across claim cycles.
        self._busy_s = 0.0
        self._claimed_s = 0.0
        # KV memory accounting (DESIGN.md §15.4): peak bytes of committed
        # state holding live request data, and peak concurrent admissions —
        # the serving benchmarks report kv_utilization = used_peak/committed
        self.kv_used_peak = 0
        self.active_peak = 0
        self._kv_committed: Optional[int] = None

    def _make_pool(self):
        """Pool factory — the paged scheduler (serve/paging.py,
        DESIGN.md §15) overrides this to swap in its ``PagedKVPool`` while
        inheriting the whole admit/decode/evict loop."""
        eng = self.engine
        return SlotKVPool(eng.cfg, eng._serve_params, self.n_slots,
                          eng.max_len, n_frames=self.n_frames,
                          mesh=eng.mesh)

    # -- KV accounting (DESIGN.md §15.4) --------------------------------
    @property
    def kv_committed_bytes(self) -> int:
        # cached: the pool's committed state is fixed-shape buffers
        # allocated at construction, but measuring it walks the whole
        # state pytree — far too slow for the per-step gauge update
        if self._kv_committed is None:
            self._kv_committed = self.pool.committed_kv_bytes()
        return self._kv_committed

    @property
    def kv_utilization_peak(self) -> float:
        c = self.kv_committed_bytes
        return self.kv_used_peak / c if c else 0.0

    def _note_kv_usage(self) -> None:
        """Sample KV usage at this step's height: every active slot is
        about to write (or just wrote) position ``steps``, so it holds
        ``steps + 1`` live entries."""
        lengths = {s: a.steps + 1 for s, a in self._active.items()}
        used = self.pool.used_kv_bytes(lengths)
        if used > self.kv_used_peak:
            self.kv_used_peak = used
        if len(self._active) > self.active_peak:
            self.active_peak = len(self._active)

    # -- queue ----------------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    @property
    def step_traces(self) -> int:
        """How often the engine's decode step_fn was traced — stays at 1
        after warmup for any admission schedule (tests/test_scheduler.py)."""
        return self.engine._step_traces

    def submit(self, payload: np.ndarray, max_new: int = 32,
               sot_id: int = 1) -> int:
        """Queue one request; returns its request id. ``payload`` is a
        mel (F, n_mels) / (1, F, n_mels) for audio engines (padded to the
        pool's ``n_frames``) or an int prompt (S,) / (1, S) for LMs."""
        arr = np.asarray(payload)
        want_ndim = 2 if self._audio else 1
        if arr.ndim == want_ndim:
            arr = arr[None]
        if arr.ndim != want_ndim + 1 or arr.shape[0] != 1:
            # one request per submit: a stacked batch would slot_insert
            # multiple rows at one slot and corrupt its neighbors' KV state
            raise ValueError(
                f"submit() takes ONE request — expected shape "
                f"({'F, n_mels' if self._audio else 'S'},) or batch-1, "
                f"got {arr.shape}; submit rows separately")
        if self._audio:
            f = arr.shape[1]
            if f > self.n_frames:
                raise ValueError(f"utterance has {f} frames > pool "
                                 f"capacity {self.n_frames}")
            if f < self.n_frames:
                arr = np.pad(arr, ((0, 0), (0, self.n_frames - f), (0, 0)))
        rid = self._next_rid
        self._next_rid += 1
        if max_new <= 0:
            # zero-budget requests finish immediately with the empty
            # result the one-shot path returns for max_new=0 — they never
            # occupy a slot (and skip the pointless prefill)
            self.finished[rid] = GenerationResult(tokens=[], prefill_s=0.0,
                                                  decode_s=0.0, steps=0)
            return rid
        self.queue.append(_QueuedRequest(rid, arr, max_new, sot_id,
                                         submit_t=time.perf_counter()))
        tele = self.telemetry
        if tele is not None:
            tele.instant("submit", rid=rid)
            tele.begin(rid, "queued")
            tele.inc("repro_requests_submitted_total")
            tele.gauge("repro_queue_depth", len(self.queue))
        return rid

    # -- admission ------------------------------------------------------
    def admit(self) -> List[int]:
        """Admit queued requests into free slots (one jitted batch-1
        prefill each, spliced in-place between decode steps). Returns the
        admitted request ids."""
        admitted = []
        eng = self.engine
        tele = self.telemetry
        while self.queue and self.pool.n_free:
            req = self.queue.popleft()
            queue_wait = (time.perf_counter() - req.submit_t
                          if req.submit_t else 0.0)
            if tele is not None:
                tele.end(req.rid, "queued", wait_s=queue_wait)
                tele.observe("repro_queue_wait_seconds", queue_wait)
            payload = jnp.asarray(req.payload)
            if self._audio:
                key = eng._key("prefill", 1, self.n_frames)
                times = 1
            else:
                key = eng._key("prefill", 1, payload.shape[1])
                times = payload.shape[1]
            plan = eng._plan(key, eng._prefill_fn, eng._serve_params, payload)
            # the ledger span tightly scopes this request's prefill exec +
            # commit, so its FLOP delta IS the prefill's attribution
            with obs.maybe_span(tele, "prefill", cat="lifecycle",
                                track=obs.request_track(req.rid),
                                rid=req.rid, ledger=True):
                t0 = time.perf_counter()
                out, state = eng._prefill_jit(eng._serve_params, payload)
                jax.block_until_ready(out)
                if self._audio:
                    first = np.full((1,), req.sot_id, np.int32)
                else:
                    first = np.asarray(eng._argmax(out[:, -1]))
                prefill_s = time.perf_counter() - t0
                self._busy_s += prefill_s
                if eng.offload is not None:
                    eng.offload.ledger.commit(plan, times=times)
            slot = self.pool.acquire()
            self.pool.insert(slot, state)
            self._tokens = self._tokens.at[slot, 0].set(int(first[0]))
            self._active[slot] = _ActiveSlot(rid=req.rid, max_new=req.max_new,
                                             prefill_s=prefill_s,
                                             submit_t=req.submit_t,
                                             queue_wait_s=queue_wait)
            if tele is not None:
                tele.observe("repro_prefill_seconds", prefill_s)
                tele.begin(req.rid, "decode")
            admitted.append(req.rid)
        return admitted

    # -- decode ---------------------------------------------------------
    def _ensure_step_plan(self) -> None:
        if self._step_plan_ready:
            return
        eng = self.engine
        extra = (self.n_frames,) if self._audio else ()
        key = eng._key("step", self.n_slots, *extra)
        token = jnp.zeros((self.n_slots, 1), jnp.int32)
        self._step_plan = eng._plan(key, eng._decode_fn, eng._serve_params,
                                    token, self.pool.state)
        self._step_plan_ready = True

    def decode_step(self) -> List[TokenEvent]:
        """One fixed-shape batch decode step: every slot advances (free
        slots compute garbage that is never read), active slots emit their
        next token, finished requests are evicted. Returns the step's
        ``TokenEvent`` stream in slot order."""
        if not self._active:
            return []
        self._ensure_step_plan()
        self._note_kv_usage()
        eng = self.engine
        tele = self.telemetry
        # the batch step's ledger span scopes exec + host sync + the one
        # plan commit — its FLOP delta is the step's exact attribution.
        # ledger_open/close, not the with-form: this step is what the
        # ≤3% budget prices, and the pair is 3 Python frames lighter
        if tele is not None:
            h = tele.ledger_open()
        t0 = time.perf_counter()
        nxt, _, state = eng._step_jit(eng._serve_params, self._tokens,
                                      self._done0, self.pool.state)
        self.pool.state = state
        self._tokens = nxt
        nxt_np = np.asarray(nxt)                       # host sync: streaming
        dt = time.perf_counter() - t0
        self._busy_s += dt
        if eng.offload is not None:
            eng.offload.ledger.commit(self._step_plan, times=1)
        if tele is not None:
            tele.ledger_close(h, "decode_step", cat="step",
                              args={"active": len(self._active)})
        share = dt / len(self._active)
        now = time.perf_counter()
        eos = eng.eos_id
        events = []
        for slot in sorted(self._active):
            a = self._active[slot]
            tok = int(nxt_np[slot, 0])
            a.tokens.append(tok)
            a.steps += 1
            a.decode_s += share
            if a.steps == 1 and a.ttft_s == 0.0 and a.submit_t > 0.0:
                # first generated token of this request: TTFT is wall time
                # from submit, inclusive of queue wait and prefill
                a.ttft_s = now - a.submit_t
                if tele is not None:
                    self._buf_ttft.append(a.ttft_s)
            done = a.steps >= a.max_new or (eos is not None and tok == eos)
            events.append(TokenEvent(a.rid, tok, a.steps, done))
            if done:
                self.finished[a.rid] = GenerationResult(
                    tokens=a.tokens, prefill_s=a.prefill_s,
                    decode_s=a.decode_s, steps=a.steps,
                    queue_wait_s=a.queue_wait_s, ttft_s=a.ttft_s)
                if tele is not None:
                    tele.instant("evict", rid=a.rid)
                    tele.end(a.rid, "decode", steps=a.steps)
                    self._buf_finished += 1
                del self._active[slot]
                # reset=False: insert() fully overwrites the slot on the
                # next admission and freed rows' garbage is never read —
                # skipping the reset saves a pool-state copy per eviction
                self.pool.release(slot, reset=False)
        if tele is not None:
            self._buf_tokens += len(events)
            self._buf_steps.append(dt)
            self._buf_shares.append(share)
            # change-gate on the plain-int peak, not the utilization
            # property — the ratio's denominator walks the state pytree
            g = (len(self.queue), len(self._active), eng._step_traces,
                 self.kv_used_peak)
            if g != self._gauge_state:      # gauges move rarely mid-drain
                self._gauge_state = g
                gq, gs, gt, gu = self._step_gauges
                gq.set(g[0])
                gs.set(g[1])
                gt.set(g[2])
                gu.set(self.kv_utilization_peak)
        return events

    # -- telemetry flush -------------------------------------------------
    def flush_telemetry(self) -> None:
        """Drain the buffered per-step metric observations into the
        registry (DESIGN.md §16.4). The hot path appends to plain lists
        and bumps plain ints; the registry work (label resolution, bucket
        search) happens here, off the per-token latency path. Called by
        ``run()`` and ``attribution()``; drive it yourself after a manual
        ``admit()``/``decode_step()`` loop before reading metrics."""
        tele = self.telemetry
        if tele is None:
            return
        ctok, hstep, htok = self._step_instruments
        if self._buf_tokens:
            ctok.inc(self._buf_tokens)
            self._buf_tokens = 0
        for v in self._buf_steps:
            hstep.observe(v)
        self._buf_steps.clear()
        for v in self._buf_shares:
            htok.observe(v)
        self._buf_shares.clear()
        for v in self._buf_ttft:
            tele.observe("repro_ttft_seconds", v)
        self._buf_ttft.clear()
        if self._buf_finished:
            tele.inc("repro_requests_finished_total", self._buf_finished)
            tele.inc("repro_evictions_total", self._buf_finished)
            self._buf_finished = 0

    # -- drain ----------------------------------------------------------
    def run(self, on_token: Optional[Callable[[TokenEvent], Any]] = None
            ) -> Dict[int, GenerationResult]:
        """Drain queue + slots to completion, streaming each token through
        ``on_token`` as it is produced. Returns {rid: GenerationResult}
        and CLAIMS those results — each result is handed out exactly once,
        so a long-running submit()/run() loop holds no unbounded history
        (results produced via manual admit()/decode_step() driving stay in
        ``finished`` until a run() claims them)."""
        while self.queue or self._active:
            self.admit()
            for ev in self.decode_step():
                if on_token is not None:
                    on_token(ev)
        out = dict(self.finished)
        self.finished.clear()
        self._claimed_s += sum(r.total_s for r in out.values())
        self.flush_telemetry()
        return out

    # -- attribution (DESIGN.md §11.3) ----------------------------------
    def attribution(self, power_w: Optional[float] = None) -> Dict[str, Any]:
        """Per-request PDP attribution: each finished request's PDP from
        its exact prefill time + its share of every step it was live for.
        The contract: per-request PDP sums to the batch total, where the
        batch total comes from the INDEPENDENTLY accumulated busy
        wall-time (whole prefills + whole batch steps, never per-request
        shares) — a mis-split in the share bookkeeping breaks the
        equality rather than cancelling out. Exact once all requests have
        drained (live slots still hold unfinalized shares); asserted by
        benchmarks/continuous_batching.py and tests/test_scheduler.py.
        Covers the UNCLAIMED results: busy time of results already handed
        out by run() is subtracted, so the invariant holds per claim
        window in a long-running serve loop."""
        from repro.core import energy
        self.flush_telemetry()
        w = energy.TPU_V5E_W if power_w is None else power_w
        per_req = {rid: r.pdp_j(w) for rid, r in self.finished.items()}
        window_s = self._busy_s - self._claimed_s
        return {"per_request_pdp_j": per_req,
                # lifecycle timings (DESIGN.md §16.1): wall queue wait and
                # submit->first-token per unclaimed finished request, so
                # launch/serve.py prints ONE consolidated report
                "per_request_queue_wait_s": {
                    rid: r.queue_wait_s for rid, r in self.finished.items()},
                "per_request_ttft_s": {
                    rid: r.ttft_s for rid, r in self.finished.items()},
                "batch_pdp_j": energy.pdp(window_s, w),
                "busy_s": window_s,
                "drained": not (self._active or self.queue)}
