"""Speculative decoding across the Whisper ladder (DESIGN.md §17).

The paper's scaling study runs tiny -> base -> small, and its PDP
advantage narrows exactly where steps get expensive (32KB local-memory
coverage drops from ~94% on tiny to ~66% on base/small). This module
spends cheap tiny-model FLOPs to amortize those expensive steps: a
``SpeculativeEngine`` drafts ``k`` tokens per request with the ladder's
cheapest model, scores the whole ``k+1``-token window in ONE jitted
verifier forward (``ServeEngine._verify_jit`` -> ``models.verify_step``,
DESIGN.md §17.1), accepts the longest draft prefix the verifier agrees
with, and falls back to the verifier's own token at the first mismatch —
so the emitted stream is token-exact with greedy decode on the verifier
alone (``accept_spec`` is the pure acceptance rule the property tests
drive).

Two models, one discipline (DESIGN.md §17.2): each model keeps its own
``PlanCache`` with role-tagged keys (draft/verify programs never collide
with plain greedy plans), the draft's dispatcher pins the cheapest
backend while the verifier keeps pallas/offload routing, and both commit
into ONE ``OffloadLedger`` with ``role="draft"``/``"verify"`` tags —
every round's interleaved commits sit inside one ledger span, so the
§16.2 integer-exactness invariant and the by_role split close together.

The acceptance loop is zero-retrace (DESIGN.md §17.3): per round it runs
``k+1`` draft step calls (the extra feed writes d_k's KV entry so a
full-accept rollforward is always cache-consistent), one verify call,
one jitted length splice per model (``model.set_slot_lengths`` — stale
window entries beyond the accepted prefix stay in place, masked then
overwritten), and ONE host sync — against the greedy loop's sync per
token, a second, structural source of the speedup next to the
draft/verifier FLOP gap.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as model_lib
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.kvcache import SlotKVPool
from repro.serve.paging import PagedScheduler
from repro.serve.scheduler import ContinuousBatchingScheduler, TokenEvent


def accept_spec(drafts: np.ndarray, vtoks: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The pure greedy-acceptance rule (DESIGN.md §17.1).

    drafts: (B, k) draft proposals d_1..d_k; vtoks: (B, k+1) verifier
    argmaxes over the window [t_0, d_1..d_k] — ``vtoks[:, j]`` is what
    greedy decode on the verifier would emit after consuming the first
    ``j+1`` window tokens. Returns ``(accept_len, committed, n_emit)``:
      accept_len (B,)     longest prefix with drafts[j] == vtoks[j]
      committed (B, k+1)  the emitted tokens — accepted drafts then the
                          verifier's own token at the first mismatch (or
                          its bonus token after a full accept); entries
                          past ``n_emit`` are padding
      n_emit (B,)         accept_len + 1 (every round emits >= 1 token)

    Token-exact by construction: the emitted prefix is precisely what
    feeding the verifier one token at a time would produce, for ANY
    draft/verify pair (tests/test_speculative.py property)."""
    drafts = np.asarray(drafts)
    vtoks = np.asarray(vtoks)
    b, k = drafts.shape
    if vtoks.shape != (b, k + 1):
        raise ValueError(f"vtoks must be (B, k+1); got {vtoks.shape} "
                         f"for drafts {drafts.shape}")
    mismatch = drafts != vtoks[:, :k]
    accept_len = np.where(mismatch.any(axis=1), mismatch.argmax(axis=1),
                          k).astype(np.int64)
    committed = np.concatenate(
        [drafts, np.zeros((b, 1), drafts.dtype)], axis=1)
    rows = np.arange(b)
    committed[rows, accept_len] = vtoks[rows, accept_len]
    return accept_len, committed, accept_len + 1


@jax.jit
def _rollback(state, new_len):
    """Jitted per-slot length splice (DESIGN.md §17.1): one compiled
    program per state structure (verifier + draft), zero retraces across
    rounds — mixed accept lengths are data, not shapes."""
    return model_lib.set_slot_lengths(state, new_len)


@dataclass
class SpeculativeEngine:
    """Two-model speculative decoder (DESIGN.md §17): ``draft`` proposes
    ``k`` tokens per round, ``verifier`` scores the k+1 window in one
    jitted forward, greedy acceptance keeps the output token-exact with
    ``verifier.transcribe()``. Build via ``ServeEngine.speculative()``
    (which pins the draft to the cheapest backend and shares the
    verifier's ledger); constructing directly works when the caller owns
    both engines."""
    verifier: ServeEngine
    draft: ServeEngine
    k: int = 4
    # lifetime counters (the acceptance-rate report, DESIGN.md §17.3)
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0

    def __post_init__(self):
        # validation runs cheapest-first (plain int compares before config
        # inspection), so a multiply-wrong setup surfaces its errors in a
        # fixed, documented order: k, max_len, vocab, family
        # (tests/test_speculative.py parametrizes every guard)
        if self.k < 1:
            raise ValueError("k must be >= 1")
        cap = min(self.verifier.max_len, self.draft.max_len)
        if cap < self.k + 2:
            raise ValueError(
                f"max_len too small for k={self.k}: one round feeds a "
                f"k+1-token window plus the bonus entry, so max_len must "
                f"be >= k + 2 = {self.k + 2} (verifier "
                f"{self.verifier.max_len}, draft {self.draft.max_len})")
        vc, dc = self.verifier.cfg, self.draft.cfg
        if dc.vocab_size != vc.vocab_size:
            raise ValueError(
                f"draft and verifier must share a vocabulary to compare "
                f"tokens: {dc.vocab_size} != {vc.vocab_size}")
        if vc.family != "audio" or dc.family != "audio":
            raise NotImplementedError(
                "speculative serving is wired for the audio family "
                "(the Whisper ladder, DESIGN.md §17)")

    # ------------------------------------------------------------------
    def transcribe(self, mel: np.ndarray, sot_id: int = 1,
                   max_new: int = 32) -> List[GenerationResult]:
        """Speculative twin of ``ServeEngine.transcribe`` — same token
        contract (the generated tokens only, rows truncated at their
        first EOS inclusive), token-exact with the verifier's own greedy
        decode of the same batch."""
        v, d, k = self.verifier, self.draft, self.k
        w = k + 1
        b, f = int(mel.shape[0]), int(mel.shape[1])
        need = max_new + k + 1           # window writes reach pos G + k
        if v.max_len < need or d.max_len < need:
            raise ValueError(
                f"max_len must be >= max_new + k + 1 = {need} "
                f"(verifier {v.max_len}, draft {d.max_len})")
        if v.offload is not None and v.offload.tuner is not None:
            tuner = v.offload.tuner
            n0 = tuner.searches
            from repro.models import whisper as whisper_lib
            whisper_lib.warm_tuning(v.cfg, v.offload, n_frames=f, batch=b,
                                    n_tokens=max_new, quant=v._serve_quant)
            # the verify window's m = B*(k+1) rows per linear
            whisper_lib.warm_tuning(v.cfg, v.offload, n_frames=f,
                                    batch=b * w, n_tokens=max_new,
                                    quant=v._serve_quant)
            if tuner.searches > n0:
                tuner.save()
        mel_j = jnp.asarray(mel)
        tele = v.telemetry

        # plans: prefills are the SAME traced programs as the plain path
        # (plain keys -> shared PlanCache entries); the draft step and the
        # verify window are role-keyed (DESIGN.md §17.2)
        v_prefill_plan = v._plan(v._key("prefill", b, f), v._prefill_fn,
                                 v._serve_params, mel_j)
        d_prefill_plan = d._plan(d._key("prefill", b, f), d._prefill_fn,
                                 d._serve_params, mel_j)

        t0 = time.perf_counter()
        with obs.maybe_span(tele, "spec_prefill", cat="engine", ledger=True,
                            args={"batch": b, "frames": f}):
            v_mem, v_state = v._prefill_jit(v._serve_params, mel_j)
            d_mem, d_state = d._prefill_jit(d._serve_params, mel_j)
            jax.block_until_ready(v_mem)
            jax.block_until_ready(d_mem)
            prefill_s = time.perf_counter() - t0
            if v.offload is not None:
                v.offload.ledger.commit(v_prefill_plan, times=1,
                                        role="verify")
            if d.offload is not None:
                d.offload.ledger.commit(d_prefill_plan, times=1,
                                        role="draft")

        # per-row accept lengths need per-slot positions: the slot layout
        # (DESIGN.md §11.1) inside a run-to-completion static batch
        v_state = model_lib.slot_layout(v_state, b)
        d_state = model_lib.slot_layout(d_state, b)

        cur = jnp.full((b, 1), sot_id, jnp.int32)
        nodone = jnp.zeros((b,), bool)
        d_step_plan = d._plan(d._key("step", b, f, role="draft"),
                              d._decode_fn, d._serve_params, cur, d_state)
        v_verify_plan = v._plan(
            v._key("verify", b, f, role="verify", k=k), v._verify_fn,
            v._serve_params, jnp.zeros((b, w), jnp.int32), v_state)

        toks: List[List[int]] = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        prev_len = np.zeros(b, np.int64)
        eos = v.eos_id if (v.eos_id is not None and v.eos_id >= 0) else None
        rows = np.arange(b)

        t0 = time.perf_counter()
        while not done.all():
            h = tele.ledger_open() if tele is not None else None
            active_mask = ~done
            active = int(active_mask.sum())
            # --- draft k tokens; the k+1-th feed writes d_k's KV entry
            # so a full accept leaves the draft cache consistent
            dtoks = []
            dt = cur
            for _ in range(k):
                dt, _, d_state = d._step_jit(d._serve_params, dt, nodone,
                                             d_state)
                dtoks.append(dt)
            _, _, d_state = d._step_jit(d._serve_params, dtoks[-1], nodone,
                                        d_state)
            # --- verify the whole window in ONE forward
            window = jnp.concatenate([cur] + dtoks, axis=1)      # (B, k+1)
            vlogits, v_state = v._verify_jit(v._serve_params, window,
                                             v_state)
            vtoks = v._argmax(vlogits)                           # (B, k+1)
            # --- the round's single host sync
            vt, win = jax.device_get((vtoks, window))
            accept_len, committed, n_emit = accept_spec(win[:, 1:], vt)
            # --- emit + rollback: fed == emitted per row, so the splice
            # target is prev + used; finished rows freeze (used = 0)
            new_len = prev_len.copy()
            for i in range(b):
                if done[i]:
                    continue
                used = 0
                for t in committed[i, :n_emit[i]]:
                    toks[i].append(int(t))
                    used += 1
                    if eos is not None and int(t) == eos:
                        done[i] = True
                        break
                    if len(toks[i]) >= max_new:
                        done[i] = True
                        break
                new_len[i] = prev_len[i] + used
            prev_len = new_len
            nl = jnp.asarray(new_len, jnp.int32)
            v_state = _rollback(v_state, nl)
            d_state = _rollback(d_state, nl)
            cur = jnp.asarray(vt[rows, accept_len][:, None].astype(np.int32))
            # --- accounting: draft + verify commits interleave inside
            # ONE ledger span (the §16.2 exactness the satellite gates)
            self.rounds += 1
            self.drafted += active * k
            self.accepted += int(accept_len[active_mask].sum())
            if d.offload is not None:
                d.offload.ledger.commit(d_step_plan, times=k + 1,
                                        role="draft")
            if v.offload is not None:
                v.offload.ledger.commit(v_verify_plan, times=1,
                                        role="verify")
            if tele is not None:
                tele.ledger_close(h, "spec_round", cat="step",
                                  args={"round": self.rounds,
                                        "active": int(active)})
                tele.inc("repro_spec_rounds_total")
                tele.inc("repro_spec_drafted_total", active * k)
                tele.inc("repro_spec_accepted_total",
                         int(accept_len[active_mask].sum()))
        jax.block_until_ready(cur)
        decode_s = time.perf_counter() - t0
        if tele is not None:
            tele.gauge("repro_spec_acceptance_rate", self.acceptance_rate())
            tele.gauge("repro_spec_verify_traces", v._verify_traces)
        return [GenerationResult(tokens=toks[i], prefill_s=prefill_s / b,
                                 decode_s=decode_s / b, steps=len(toks[i]))
                for i in range(b)]

    # ------------------------------------------------------------------
    # Round-boundary scheduling (DESIGN.md §17.4) — thin factories over
    # the mixin schedulers below; transcribe() stays the one-shot path.
    # ------------------------------------------------------------------
    def continuous(self, n_slots: int = 4,
                   n_frames: Optional[int] = None
                   ) -> "SpecContinuousScheduler":
        """A continuous-batching scheduler that decodes in speculative
        rounds (DESIGN.md §17.4): queued utterances admit into freed wave
        rows at round boundaries — the rollback splice freezes finished
        rows at ``used = 0``, so a round boundary is a safe admission
        point exactly like the §11 between-steps boundary."""
        return SpecContinuousScheduler(self, n_slots=n_slots,
                                      n_frames=n_frames)

    def paged(self, n_slots: int = 4, n_frames: Optional[int] = None,
              **page_cfg) -> "PagedSpecScheduler":
        """Speculative rounds over the §15 paged KV pool: the verify
        window reads/writes through the block tables (multi-entry
        scatter), the pre-round capacity pass allocates any page the
        window will cross into (CoW-first, preempting when the arena is
        dry), and the post-round trim releases pages a rejected suffix
        crossed into."""
        return PagedSpecScheduler(self, n_slots=n_slots, n_frames=n_frames,
                                  **page_cfg)

    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    def stats(self) -> Dict[str, Any]:
        """The consolidated speculative report (DESIGN.md §17.3):
        acceptance + the zero-retrace counters + the by_role FLOP split
        from the shared ledger."""
        out = {"k": self.k, "rounds": self.rounds, "drafted": self.drafted,
               "accepted": self.accepted,
               "acceptance_rate": self.acceptance_rate(),
               "verify_traces": self.verifier._verify_traces,
               "draft_step_traces": self.draft._step_traces}
        if self.verifier.offload is not None:
            out["by_role"] = dict(self.verifier.offload.stats.by_role)
        return out


@dataclass
class SpecScheduler:
    """Wave scheduler over a ``SpeculativeEngine`` (DESIGN.md §17.4):
    queued utterances run to completion in fixed-width waves — one
    compiled shape per (wave width, frame count), short waves padded with
    zero-mel rows — so steady-state serving reuses the engine's compiled
    draft/verify programs across waves. Deliberately simpler than the
    continuous-batching scheduler (DESIGN.md §11): run-to-completion
    waves keep the zero-retrace and token-exactness guarantees without a
    slot pool, which makes this the parity REFERENCE the round-boundary
    schedulers below (``SpecContinuousScheduler``/``PagedSpecScheduler``,
    DESIGN.md §17.4) are gated against."""
    engine: SpeculativeEngine
    n_slots: int = 4
    _queue: List[Tuple[int, np.ndarray, int, int]] = field(
        default_factory=list)
    _next_rid: int = 0

    def submit(self, mel: np.ndarray, max_new: int = 32,
               sot_id: int = 1) -> int:
        arr = np.asarray(mel, np.float32)
        if arr.ndim == 2:
            arr = arr[None]
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, arr, max_new, sot_id))
        return rid

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    def run(self) -> Dict[int, GenerationResult]:
        out: Dict[int, GenerationResult] = {}
        while self._queue:
            wave, self._queue = (self._queue[:self.n_slots],
                                 self._queue[self.n_slots:])
            frames = {q[1].shape[1] for q in wave}
            sots = {q[3] for q in wave}
            if len(frames) > 1 or len(sots) > 1:
                raise ValueError(
                    "a wave must share frame count and SOT token "
                    f"(got frames={sorted(frames)}, sot={sorted(sots)})")
            mels = [q[1] for q in wave]
            pad = self.n_slots - len(wave)
            if pad:
                mels.append(np.zeros((pad, *mels[0].shape[1:]), np.float32))
            batch = np.concatenate(mels, axis=0)
            max_new = max(q[2] for q in wave)
            results = self.engine.transcribe(batch, sot_id=wave[0][3],
                                             max_new=max_new)
            for (rid, _, req_max, _), r in zip(wave, results):
                row = r.tokens[:req_max]
                out[rid] = GenerationResult(
                    tokens=row, prefill_s=r.prefill_s,
                    decode_s=r.decode_s, steps=len(row))
        return out


# ---------------------------------------------------------------------------
# Round-boundary continuous/paged scheduling (DESIGN.md §17.4)
# ---------------------------------------------------------------------------
class _SpecRoundsMixin:
    """Speculative rounds over the §11 slot machinery (DESIGN.md §17.4).

    Placed FIRST in the MRO over ``ContinuousBatchingScheduler`` /
    ``PagedScheduler``: the base class keeps the whole queue / evict /
    attribution / telemetry apparatus, and this mixin swaps the per-step
    decode for a speculative ROUND — ``k+1`` draft steps at pool width,
    ONE verify forward over the (n_slots, k+1) window, the pure
    ``accept_spec`` rule, and one rollback splice per model. A round
    boundary is a safe admission point exactly like the §11 between-steps
    boundary: the splice freezes finished rows at ``used = 0``, so a
    freed slot's garbage rows never advance and the next ``admit()`` can
    overwrite them whole.

    The draft model mirrors the verifier's slot pool in a contiguous
    ``SlotKVPool`` whose own free list is never consulted — slot ids ARE
    the verifier pool's slot ids, ``insert()`` writes any row, and a
    row's lifetime is its verifier slot's lifetime. Both models roll back
    through the one shared ``_rollback`` jit, so each keeps one compiled
    splice per state structure.

    Attribution follows §11.3 unchanged: each round's wall time splits
    evenly over the slots active that round, draft admissions (prefill +
    preemption replay) land on the owning request AND the independent
    ``_busy_s`` accumulator, so per-request PDP still sums to the batch
    total. Single-device only: the rollback splice carries no sharded
    out_shardings yet (mesh composition stays with ``SpecScheduler``)."""

    def _init_spec(self, spec: SpeculativeEngine) -> None:
        v, d = spec.verifier, spec.draft
        if v.mesh is not None or d.mesh is not None:
            raise NotImplementedError(
                "speculative round scheduling is single-device: the "
                "rollback splice has no sharded out_shardings — use "
                "SpecScheduler waves on a mesh")
        self.spec = spec
        self._draft_pool = SlotKVPool(d.cfg, d._serve_params, self.n_slots,
                                      d.max_len, n_frames=self.n_frames)
        self._draft_step_plan = None
        self._verify_plan = None

    # -- admission (round boundary == between-steps boundary) -----------
    def submit(self, payload, max_new: int = 32, sot_id: int = 1) -> int:
        spec = self.spec
        need = max_new + spec.k + 1      # window writes reach pos G + k
        cap = min(spec.verifier.max_len, spec.draft.max_len)
        if max_new > 0 and need > cap:
            raise ValueError(
                f"max_len must be >= max_new + k + 1 = {need} "
                f"(verifier {spec.verifier.max_len}, draft "
                f"{spec.draft.max_len})")
        return super().submit(payload, max_new=max_new, sot_id=sot_id)

    def admit(self) -> List[int]:
        # snapshot the queue before the base admit pops it: the draft's
        # mirror admission needs each request's payload + SOT
        pend = {q.rid: q for q in self.queue}
        admitted = super().admit()
        if admitted:
            by_rid = {a.rid: slot for slot, a in self._active.items()}
            for rid in admitted:
                self._admit_draft(by_rid[rid], pend[rid])
        return admitted

    def _admit_draft(self, slot: int, req) -> None:
        """Mirror one admission into the draft pool: a batch-1 prefill,
        plus the deterministic replay of already-streamed tokens when the
        request was preempted mid-flight. Afterwards the draft row holds
        KV for ``[SOT, e_0..e_{L-2}]`` at length L with pending token
        ``e_{L-1}`` — the same invariant every speculative round
        maintains on the verifier slot, so drafting resumes seamlessly."""
        d = self.spec.draft
        tele = self.telemetry
        a = self._active[slot]
        tokens = list(a.tokens)          # non-empty only after preemption
        payload = jnp.asarray(req.payload)
        plan = d._plan(d._key("prefill", 1, self.n_frames), d._prefill_fn,
                       d._serve_params, payload)
        # the ledger span tightly scopes the draft-side prefill + replay
        # exec and commits, preserving §16.2 span exactness (the draft
        # shares the verifier's ledger, so unclaimed commits here would
        # break ledger_consistent on the serving telemetry)
        with obs.maybe_span(tele, "spec_draft_admit", cat="lifecycle",
                            track=obs.request_track(a.rid), rid=a.rid,
                            ledger=True):
            t0 = time.perf_counter()
            _, state = d._prefill_jit(d._serve_params, payload)
            if d.offload is not None:
                d.offload.ledger.commit(plan, times=1, role="draft")
            if tokens:
                inputs = [req.sot_id] + tokens[:-1]
                tok0 = jnp.full((1, 1), inputs[0], jnp.int32)
                rplan = d._plan(d._key("step", 1, self.n_frames,
                                       role="draft"),
                                d._decode_fn, d._serve_params, tok0, state)
                for t in inputs:
                    _, state = d._decode_jit(d._serve_params,
                                             jnp.full((1, 1), t, jnp.int32),
                                             state)
                if d.offload is not None:
                    d.offload.ledger.commit(rplan, times=len(inputs),
                                            role="draft")
            state = jax.block_until_ready(state)
            wall = time.perf_counter() - t0
        self._busy_s += wall
        a.prefill_s += wall
        self._draft_pool.insert(slot, state)
        if tele is not None:
            tele.instant("spec_admit", rid=a.rid, slot=slot,
                         replayed=len(tokens))
            tele.inc("repro_spec_admissions_total")

    # -- layout hooks (overridden by the paged subclass) ----------------
    def _pre_round(self, w: int) -> None:
        """Capacity hook before the round's W writes — a no-op on the
        contiguous pool (slots own max_len up front)."""

    def _evict_slot(self, slot: int, rid: int) -> None:
        self.pool.release(slot, reset=False)

    def _post_round(self, new_len: np.ndarray) -> None:
        """Rollback hook after the length splice — a no-op on the
        contiguous pool (stale window entries just get overwritten)."""

    # -- the speculative round ------------------------------------------
    def _ensure_step_plan(self) -> None:
        if self._step_plan_ready:
            return
        spec = self.spec
        v, d, k = spec.verifier, spec.draft, spec.k
        token = jnp.zeros((self.n_slots, 1), jnp.int32)
        self._draft_step_plan = d._plan(
            d._key("step", self.n_slots, self.n_frames, role="draft"),
            d._decode_fn, d._serve_params, token, self._draft_pool.state)
        window = jnp.zeros((self.n_slots, k + 1), jnp.int32)
        self._verify_plan = v._plan(
            v._key("verify", self.n_slots, self.n_frames,
                   pages=getattr(self.pool, "plan_geometry", None),
                   role="verify", k=k),
            v._verify_fn, v._serve_params, window, self.pool.state)
        self._step_plan_ready = True

    def decode_step(self) -> List[TokenEvent]:
        """One speculative round at pool width. Emits up to ``k+1``
        ``TokenEvent``s per active slot (each request's event stream
        stays ordered by its per-request ``step``); finished requests
        evict exactly as in the base scheduler, and their rows freeze at
        length 0 through the rollback splice."""
        if not self._active:
            return []
        spec = self.spec
        v, d, k = spec.verifier, spec.draft, spec.k
        self._pre_round(k + 1)
        if not self._active:             # capacity pass preempted them all
            return []
        self._ensure_step_plan()
        self._note_kv_usage()
        tele = self.telemetry
        if tele is not None:
            h = tele.ledger_open()
        t0 = time.perf_counter()
        dpool = self._draft_pool
        d_state = dpool.state
        # k draft steps; the k+1-th feed writes d_k's KV entry so a full
        # accept leaves the draft cache consistent (DESIGN.md §17.1)
        dt = self._tokens
        dtoks = []
        for _ in range(k):
            dt, _, d_state = d._step_jit(d._serve_params, dt, self._done0,
                                         d_state)
            dtoks.append(dt)
        _, _, d_state = d._step_jit(d._serve_params, dtoks[-1], self._done0,
                                    d_state)
        dpool.state = d_state
        # ONE verify forward over the whole window, then the round's
        # single host sync
        window = jnp.concatenate([self._tokens] + dtoks, axis=1)
        vlogits, v_state = v._verify_jit(v._serve_params, window,
                                         self.pool.state)
        self.pool.state = v_state
        vtoks = v._argmax(vlogits)
        vt, win = jax.device_get((vtoks, window))
        dt_s = time.perf_counter() - t0
        self._busy_s += dt_s
        if d.offload is not None:
            d.offload.ledger.commit(self._draft_step_plan, times=k + 1,
                                    role="draft")
        if v.offload is not None:
            v.offload.ledger.commit(self._verify_plan, times=1,
                                    role="verify")
        if tele is not None:
            tele.ledger_close(h, "spec_round", cat="step",
                              args={"active": len(self._active)})
        accept_len, committed, n_emit = accept_spec(win[:, 1:], vt)
        share = dt_s / len(self._active)
        now = time.perf_counter()
        eos = v.eos_id
        events: List[TokenEvent] = []
        new_len = np.zeros(self.n_slots, np.int64)
        pending = np.zeros(self.n_slots, np.int64)
        drafted = len(self._active) * k
        accepted = 0
        for slot in sorted(self._active):
            a = self._active[slot]
            a.decode_s += share
            accepted += int(accept_len[slot])
            done = False
            for t in committed[slot, :n_emit[slot]]:
                tok = int(t)
                a.tokens.append(tok)
                a.steps += 1
                if a.steps == 1 and a.ttft_s == 0.0 and a.submit_t > 0.0:
                    a.ttft_s = now - a.submit_t
                    if tele is not None:
                        self._buf_ttft.append(a.ttft_s)
                done = (a.steps >= a.max_new
                        or (eos is not None and tok == eos))
                events.append(TokenEvent(a.rid, tok, a.steps, done))
                if done:
                    break
            # fed == emitted per row: the splice target is the emitted
            # count, and the next round's feed is the last emitted token
            # (== the verifier's token at the mismatch/bonus position)
            new_len[slot] = a.steps
            pending[slot] = a.tokens[-1]
            if done:
                self.finished[a.rid] = GenerationResult(
                    tokens=a.tokens, prefill_s=a.prefill_s,
                    decode_s=a.decode_s, steps=a.steps,
                    queue_wait_s=a.queue_wait_s, ttft_s=a.ttft_s)
                if tele is not None:
                    tele.instant("evict", rid=a.rid)
                    tele.end(a.rid, "decode", steps=a.steps)
                    self._buf_finished += 1
                del self._active[slot]
                self._evict_slot(slot, a.rid)
                new_len[slot] = 0        # freeze the freed row
                pending[slot] = 0
        nl = jnp.asarray(new_len, jnp.int32)
        self.pool.state = _rollback(self.pool.state, nl)
        dpool.state = _rollback(dpool.state, nl)
        self._post_round(new_len)
        self._tokens = jnp.asarray(pending[:, None].astype(np.int32))
        spec.rounds += 1
        spec.drafted += drafted
        spec.accepted += accepted
        if tele is not None:
            self._buf_tokens += len(events)
            self._buf_steps.append(dt_s)
            self._buf_shares.append(share)
            tele.inc("repro_spec_rounds_total")
            tele.inc("repro_spec_drafted_total", drafted)
            tele.inc("repro_spec_accepted_total", accepted)
            g = (len(self.queue), len(self._active), v._verify_traces,
                 self.kv_used_peak)
            if g != self._gauge_state:
                self._gauge_state = g
                gq, gs, gt, gu = self._step_gauges
                gq.set(g[0])
                gs.set(g[1])
                gt.set(g[2])
                gu.set(self.kv_utilization_peak)
        return events


class SpecContinuousScheduler(_SpecRoundsMixin, ContinuousBatchingScheduler):
    """Continuous batching in speculative rounds over the contiguous slot
    pool (DESIGN.md §17.4) — build via ``SpeculativeEngine.continuous()``."""

    def __init__(self, spec: SpeculativeEngine, n_slots: int = 4,
                 n_frames: Optional[int] = None):
        super().__init__(spec.verifier, n_slots=n_slots, n_frames=n_frames)
        self._init_spec(spec)


class PagedSpecScheduler(_SpecRoundsMixin, PagedScheduler):
    """Speculative rounds over the §15 paged KV pool — build via
    ``SpeculativeEngine.paged()``. Three paged-specific moves per round:
    the pre-round capacity pass ensures private pages for all ``k+1``
    window positions (a window may straddle a page boundary — the
    crossing page allocates here, preempting the cheapest victim when the
    arena is dry), the verify window scatters through the block tables
    (``attention.paged_window_update``), and the post-round trim releases
    any page the REJECTED suffix crossed into, so arena accounting is
    exact after every round. The draft side stays contiguous: drafts are
    the cheap model, whose whole pool is smaller than one verifier arena;
    preempted requests replay into BOTH models on re-admission."""

    def __init__(self, spec: SpeculativeEngine, n_slots: int = 4,
                 n_frames: Optional[int] = None, **page_cfg):
        super().__init__(spec.verifier, n_slots=n_slots, n_frames=n_frames,
                         **page_cfg)
        self._init_spec(spec)

    def _pre_round(self, w: int) -> None:
        self._page_capacity_pass(w)
        self.pool.sync()

    def _evict_slot(self, slot: int, rid: int) -> None:
        self.pool.release(slot, reset=False)
        self._payloads.pop(rid, None)

    def _post_round(self, new_len: np.ndarray) -> None:
        # release pages the rejected suffix crossed into: after the
        # splice, pages whose first position sits at/past the new length
        # hold only dead entries (DESIGN.md §17.4)
        pool = self.pool
        released = 0
        for slot in sorted(self._active):
            keep = max(-(-int(new_len[slot]) // pool.page_size), 1)
            released += pool.trim_self_pages(slot, keep)
        if released and self.telemetry is not None:
            self.telemetry.instant("spec_trim", pages=released)
            self.telemetry.inc("repro_spec_pages_trimmed_total", released)
