"""Speculative decoding across the Whisper ladder (DESIGN.md §17).

The paper's scaling study runs tiny -> base -> small, and its PDP
advantage narrows exactly where steps get expensive (32KB local-memory
coverage drops from ~94% on tiny to ~66% on base/small). This module
spends cheap tiny-model FLOPs to amortize those expensive steps: a
``SpeculativeEngine`` drafts ``k`` tokens per request with the ladder's
cheapest model, scores the whole ``k+1``-token window in ONE jitted
verifier forward (``ServeEngine._verify_jit`` -> ``models.verify_step``,
DESIGN.md §17.1), accepts the longest draft prefix the verifier agrees
with, and falls back to the verifier's own token at the first mismatch —
so the emitted stream is token-exact with greedy decode on the verifier
alone (``accept_spec`` is the pure acceptance rule the property tests
drive).

Two models, one discipline (DESIGN.md §17.2): each model keeps its own
``PlanCache`` with role-tagged keys (draft/verify programs never collide
with plain greedy plans), the draft's dispatcher pins the cheapest
backend while the verifier keeps pallas/offload routing, and both commit
into ONE ``OffloadLedger`` with ``role="draft"``/``"verify"`` tags —
every round's interleaved commits sit inside one ledger span, so the
§16.2 integer-exactness invariant and the by_role split close together.

The acceptance loop is zero-retrace (DESIGN.md §17.3): per round it runs
``k+1`` draft step calls (the extra feed writes d_k's KV entry so a
full-accept rollforward is always cache-consistent), one verify call,
one jitted length splice per model (``model.set_slot_lengths`` — stale
window entries beyond the accepted prefix stay in place, masked then
overwritten), and ONE host sync — against the greedy loop's sync per
token, a second, structural source of the speedup next to the
draft/verifier FLOP gap.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as model_lib
from repro.serve.engine import GenerationResult, ServeEngine


def accept_spec(drafts: np.ndarray, vtoks: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The pure greedy-acceptance rule (DESIGN.md §17.1).

    drafts: (B, k) draft proposals d_1..d_k; vtoks: (B, k+1) verifier
    argmaxes over the window [t_0, d_1..d_k] — ``vtoks[:, j]`` is what
    greedy decode on the verifier would emit after consuming the first
    ``j+1`` window tokens. Returns ``(accept_len, committed, n_emit)``:
      accept_len (B,)     longest prefix with drafts[j] == vtoks[j]
      committed (B, k+1)  the emitted tokens — accepted drafts then the
                          verifier's own token at the first mismatch (or
                          its bonus token after a full accept); entries
                          past ``n_emit`` are padding
      n_emit (B,)         accept_len + 1 (every round emits >= 1 token)

    Token-exact by construction: the emitted prefix is precisely what
    feeding the verifier one token at a time would produce, for ANY
    draft/verify pair (tests/test_speculative.py property)."""
    drafts = np.asarray(drafts)
    vtoks = np.asarray(vtoks)
    b, k = drafts.shape
    if vtoks.shape != (b, k + 1):
        raise ValueError(f"vtoks must be (B, k+1); got {vtoks.shape} "
                         f"for drafts {drafts.shape}")
    mismatch = drafts != vtoks[:, :k]
    accept_len = np.where(mismatch.any(axis=1), mismatch.argmax(axis=1),
                          k).astype(np.int64)
    committed = np.concatenate(
        [drafts, np.zeros((b, 1), drafts.dtype)], axis=1)
    rows = np.arange(b)
    committed[rows, accept_len] = vtoks[rows, accept_len]
    return accept_len, committed, accept_len + 1


@jax.jit
def _rollback(state, new_len):
    """Jitted per-slot length splice (DESIGN.md §17.1): one compiled
    program per state structure (verifier + draft), zero retraces across
    rounds — mixed accept lengths are data, not shapes."""
    return model_lib.set_slot_lengths(state, new_len)


@dataclass
class SpeculativeEngine:
    """Two-model speculative decoder (DESIGN.md §17): ``draft`` proposes
    ``k`` tokens per round, ``verifier`` scores the k+1 window in one
    jitted forward, greedy acceptance keeps the output token-exact with
    ``verifier.transcribe()``. Build via ``ServeEngine.speculative()``
    (which pins the draft to the cheapest backend and shares the
    verifier's ledger); constructing directly works when the caller owns
    both engines."""
    verifier: ServeEngine
    draft: ServeEngine
    k: int = 4
    # lifetime counters (the acceptance-rate report, DESIGN.md §17.3)
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0

    def __post_init__(self):
        vc, dc = self.verifier.cfg, self.draft.cfg
        if vc.family != "audio" or dc.family != "audio":
            raise NotImplementedError(
                "speculative serving is wired for the audio family "
                "(the Whisper ladder, DESIGN.md §17)")
        if dc.vocab_size != vc.vocab_size:
            raise ValueError(
                f"draft and verifier must share a vocabulary to compare "
                f"tokens: {dc.vocab_size} != {vc.vocab_size}")
        if self.k < 1:
            raise ValueError("k must be >= 1")

    # ------------------------------------------------------------------
    def transcribe(self, mel: np.ndarray, sot_id: int = 1,
                   max_new: int = 32) -> List[GenerationResult]:
        """Speculative twin of ``ServeEngine.transcribe`` — same token
        contract (the generated tokens only, rows truncated at their
        first EOS inclusive), token-exact with the verifier's own greedy
        decode of the same batch."""
        v, d, k = self.verifier, self.draft, self.k
        w = k + 1
        b, f = int(mel.shape[0]), int(mel.shape[1])
        need = max_new + k + 1           # window writes reach pos G + k
        if v.max_len < need or d.max_len < need:
            raise ValueError(
                f"max_len must be >= max_new + k + 1 = {need} "
                f"(verifier {v.max_len}, draft {d.max_len})")
        if v.offload is not None and v.offload.tuner is not None:
            tuner = v.offload.tuner
            n0 = tuner.searches
            from repro.models import whisper as whisper_lib
            whisper_lib.warm_tuning(v.cfg, v.offload, n_frames=f, batch=b,
                                    n_tokens=max_new, quant=v._serve_quant)
            # the verify window's m = B*(k+1) rows per linear
            whisper_lib.warm_tuning(v.cfg, v.offload, n_frames=f,
                                    batch=b * w, n_tokens=max_new,
                                    quant=v._serve_quant)
            if tuner.searches > n0:
                tuner.save()
        mel_j = jnp.asarray(mel)
        tele = v.telemetry

        # plans: prefills are the SAME traced programs as the plain path
        # (plain keys -> shared PlanCache entries); the draft step and the
        # verify window are role-keyed (DESIGN.md §17.2)
        v_prefill_plan = v._plan(v._key("prefill", b, f), v._prefill_fn,
                                 v._serve_params, mel_j)
        d_prefill_plan = d._plan(d._key("prefill", b, f), d._prefill_fn,
                                 d._serve_params, mel_j)

        t0 = time.perf_counter()
        with obs.maybe_span(tele, "spec_prefill", cat="engine", ledger=True,
                            args={"batch": b, "frames": f}):
            v_mem, v_state = v._prefill_jit(v._serve_params, mel_j)
            d_mem, d_state = d._prefill_jit(d._serve_params, mel_j)
            jax.block_until_ready(v_mem)
            jax.block_until_ready(d_mem)
            prefill_s = time.perf_counter() - t0
            if v.offload is not None:
                v.offload.ledger.commit(v_prefill_plan, times=1,
                                        role="verify")
            if d.offload is not None:
                d.offload.ledger.commit(d_prefill_plan, times=1,
                                        role="draft")

        # per-row accept lengths need per-slot positions: the slot layout
        # (DESIGN.md §11.1) inside a run-to-completion static batch
        v_state = model_lib.slot_layout(v_state, b)
        d_state = model_lib.slot_layout(d_state, b)

        cur = jnp.full((b, 1), sot_id, jnp.int32)
        nodone = jnp.zeros((b,), bool)
        d_step_plan = d._plan(d._key("step", b, f, role="draft"),
                              d._decode_fn, d._serve_params, cur, d_state)
        v_verify_plan = v._plan(
            v._key("verify", b, f, role="verify", k=k), v._verify_fn,
            v._serve_params, jnp.zeros((b, w), jnp.int32), v_state)

        toks: List[List[int]] = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        prev_len = np.zeros(b, np.int64)
        eos = v.eos_id if (v.eos_id is not None and v.eos_id >= 0) else None
        rows = np.arange(b)

        t0 = time.perf_counter()
        while not done.all():
            h = tele.ledger_open() if tele is not None else None
            active_mask = ~done
            active = int(active_mask.sum())
            # --- draft k tokens; the k+1-th feed writes d_k's KV entry
            # so a full accept leaves the draft cache consistent
            dtoks = []
            dt = cur
            for _ in range(k):
                dt, _, d_state = d._step_jit(d._serve_params, dt, nodone,
                                             d_state)
                dtoks.append(dt)
            _, _, d_state = d._step_jit(d._serve_params, dtoks[-1], nodone,
                                        d_state)
            # --- verify the whole window in ONE forward
            window = jnp.concatenate([cur] + dtoks, axis=1)      # (B, k+1)
            vlogits, v_state = v._verify_jit(v._serve_params, window,
                                             v_state)
            vtoks = v._argmax(vlogits)                           # (B, k+1)
            # --- the round's single host sync
            vt, win = jax.device_get((vtoks, window))
            accept_len, committed, n_emit = accept_spec(win[:, 1:], vt)
            # --- emit + rollback: fed == emitted per row, so the splice
            # target is prev + used; finished rows freeze (used = 0)
            new_len = prev_len.copy()
            for i in range(b):
                if done[i]:
                    continue
                used = 0
                for t in committed[i, :n_emit[i]]:
                    toks[i].append(int(t))
                    used += 1
                    if eos is not None and int(t) == eos:
                        done[i] = True
                        break
                    if len(toks[i]) >= max_new:
                        done[i] = True
                        break
                new_len[i] = prev_len[i] + used
            prev_len = new_len
            nl = jnp.asarray(new_len, jnp.int32)
            v_state = _rollback(v_state, nl)
            d_state = _rollback(d_state, nl)
            cur = jnp.asarray(vt[rows, accept_len][:, None].astype(np.int32))
            # --- accounting: draft + verify commits interleave inside
            # ONE ledger span (the §16.2 exactness the satellite gates)
            self.rounds += 1
            self.drafted += active * k
            self.accepted += int(accept_len[active_mask].sum())
            if d.offload is not None:
                d.offload.ledger.commit(d_step_plan, times=k + 1,
                                        role="draft")
            if v.offload is not None:
                v.offload.ledger.commit(v_verify_plan, times=1,
                                        role="verify")
            if tele is not None:
                tele.ledger_close(h, "spec_round", cat="step",
                                  args={"round": self.rounds,
                                        "active": int(active)})
                tele.inc("repro_spec_rounds_total")
                tele.inc("repro_spec_drafted_total", active * k)
                tele.inc("repro_spec_accepted_total",
                         int(accept_len[active_mask].sum()))
        jax.block_until_ready(cur)
        decode_s = time.perf_counter() - t0
        if tele is not None:
            tele.gauge("repro_spec_acceptance_rate", self.acceptance_rate())
            tele.gauge("repro_spec_verify_traces", v._verify_traces)
        return [GenerationResult(tokens=toks[i], prefill_s=prefill_s / b,
                                 decode_s=decode_s / b, steps=len(toks[i]))
                for i in range(b)]

    # ------------------------------------------------------------------
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    def stats(self) -> Dict[str, Any]:
        """The consolidated speculative report (DESIGN.md §17.3):
        acceptance + the zero-retrace counters + the by_role FLOP split
        from the shared ledger."""
        out = {"k": self.k, "rounds": self.rounds, "drafted": self.drafted,
               "accepted": self.accepted,
               "acceptance_rate": self.acceptance_rate(),
               "verify_traces": self.verifier._verify_traces,
               "draft_step_traces": self.draft._step_traces}
        if self.verifier.offload is not None:
            out["by_role"] = dict(self.verifier.offload.stats.by_role)
        return out


@dataclass
class SpecScheduler:
    """Wave scheduler over a ``SpeculativeEngine`` (DESIGN.md §17.4):
    queued utterances run to completion in fixed-width waves — one
    compiled shape per (wave width, frame count), short waves padded with
    zero-mel rows — so steady-state serving reuses the engine's compiled
    draft/verify programs across waves. Deliberately simpler than the
    continuous-batching scheduler (DESIGN.md §11): speculative rounds
    advance rows by *different* amounts, so mid-flight admission would
    re-prefill anyway; run-to-completion waves keep the zero-retrace and
    token-exactness guarantees without a slot pool."""
    engine: SpeculativeEngine
    n_slots: int = 4
    _queue: List[Tuple[int, np.ndarray, int, int]] = field(
        default_factory=list)
    _next_rid: int = 0

    def submit(self, mel: np.ndarray, max_new: int = 32,
               sot_id: int = 1) -> int:
        arr = np.asarray(mel, np.float32)
        if arr.ndim == 2:
            arr = arr[None]
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, arr, max_new, sot_id))
        return rid

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    def run(self) -> Dict[int, GenerationResult]:
        out: Dict[int, GenerationResult] = {}
        while self._queue:
            wave, self._queue = (self._queue[:self.n_slots],
                                 self._queue[self.n_slots:])
            frames = {q[1].shape[1] for q in wave}
            sots = {q[3] for q in wave}
            if len(frames) > 1 or len(sots) > 1:
                raise ValueError(
                    "a wave must share frame count and SOT token "
                    f"(got frames={sorted(frames)}, sot={sorted(sots)})")
            mels = [q[1] for q in wave]
            pad = self.n_slots - len(wave)
            if pad:
                mels.append(np.zeros((pad, *mels[0].shape[1:]), np.float32))
            batch = np.concatenate(mels, axis=0)
            max_new = max(q[2] for q in wave)
            results = self.engine.transcribe(batch, sot_id=wave[0][3],
                                             max_new=max_new)
            for (rid, _, req_max, _), r in zip(wave, results):
                row = r.tokens[:req_max]
                out[rid] = GenerationResult(
                    tokens=row, prefill_s=r.prefill_s,
                    decode_s=r.decode_s, steps=len(row))
        return out
