"""Serving engine: batched autoregressive decode with the paper's Q8_0
offload path as a first-class option, plus per-request PDP/EDP accounting.

This is the system the paper builds in whisper.cpp terms: quantized weights
(Q8_0 blocks), the dominant dot-product kernels routed through the offload
dispatcher (core/offload.py — main segment on the accelerator kernel,
residual on the host), everything else on the plain XLA path, and the
energy model (core/energy.py) attributing accelerator-active vs host time
exactly like Eq. 2/3.

Dispatch is trace-pure (DESIGN.md §10): routing resolves at trace time
from static shapes, so prefill and the decode step are wrapped in
``jax.jit`` *unconditionally* — attaching an ``OffloadEngine`` no longer
forces the flagship offloaded configuration onto the slow un-jitted path.
Offload accounting comes from ``DispatchPlan``s recorded per
``(phase, batch, seq, quant)`` key (cached — steady-state requests re-use
them) and committed to the host-side ``OffloadLedger`` multiplied by the
executed step counts.

Request flow (DESIGN.md §11):
  submit(prompt)/submit_audio(mel) -> queued on the continuous-batching
           scheduler (serve/scheduler.py)
  run() -> admits queued requests into freed slots of the fixed-shape
           KV-cache pool *between* jitted decode steps, evicts on
           EOS/max_new, streams tokens as produced, and records wall-time
           and PDP per request.
``generate()``/``transcribe()`` remain the one-shot static-batch path —
prefill the whole batch, decode run-to-completion — used by callers that
already hold a full batch.

Sharded serving (DESIGN.md §13): constructing the engine with a
``mesh`` places the serving weights per ``sharding/rules.py
serve_param_specs`` (TP over "model" where divisible, replicated over
the slot-DP "data" axis), shards the scheduler's slot pool over "data",
appends the mesh signature to every plan key/entry, and reports
per-device FLOP attribution (``energy_report()["dispatch"]["by_device"]``).

Token contract: ``GenerationResult.tokens`` holds exactly the ``steps``
tokens *this request generated*, for both paths — prompt tokens (and the
SOT token) are never included, and rows that hit EOS before the batch
drained are truncated at their first EOS with ``steps`` reported
per-request (not the batch-global step count).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core import energy
from repro.core.offload import OffloadEngine
from repro.core.plan import DispatchPlan, PlanCache, plan_key, record_plan
from repro.core.qformats import quantize_tree
from repro.models import model as model_lib
from repro.models import whisper as whisper_lib
from repro.sharding import ctx as shard_ctx
from repro.sharding import rules as shard_rules


@dataclass
class GenerationResult:
    tokens: List[int]       # the ``steps`` generated tokens (no prompt/SOT)
    prefill_s: float
    decode_s: float
    steps: int
    # scheduler-path lifecycle timings (DESIGN.md §16.1): wall time spent
    # queued before admission, and submit -> first streamed token. The
    # one-shot generate()/transcribe() paths have no queue, so both stay
    # at their 0.0 defaults there.
    queue_wait_s: float = 0.0
    ttft_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    def pdp_j(self, power_w: float = energy.TPU_V5E_W) -> float:
        return energy.pdp(self.total_s, power_w)

    def edp_js(self, power_w: float = energy.TPU_V5E_W) -> float:
        return energy.edp(self.total_s, power_w)


def _keep_dense(path, leaf) -> bool:
    """Quantization predicate mirroring whisper.cpp: quantize big GEMM
    weights, keep norms / biases / positional tables / conv / router in
    fp16. Biases are matched by their full leaf name ('b'), NOT a '/b'
    substring (which would swallow everything under '/blocks/')."""
    parts = [str(getattr(k, "key", getattr(k, "name", k))).lower()
             for k in path]
    name = "/".join(parts)
    if parts and parts[-1] in ("b", "bias", "conv_w", "conv_b"):
        return False
    if any(s in name for s in ("norm", "pos", "a_log", "dt_bias", "router")):
        return False
    return True


@dataclass
class ServeEngine:
    cfg: ModelConfig
    params: Any
    max_len: int = 512
    quant: Optional[str] = None          # None -> cfg.quant
    offload: Optional[OffloadEngine] = None
    eos_id: Optional[int] = 0
    # serving mesh (DESIGN.md §13): weights are placed per
    # sharding/rules.serve_param_specs (TP over "model" where divisible,
    # replicated over the slot-DP "data" axis), the scheduler's slot pool
    # shards its slot axis over "data", and every plan key/entry carries
    # the mesh signature. None -> the single-device behavior, unchanged.
    mesh: Optional[Any] = None
    # nullable observability handle (DESIGN.md §16.2): None (the default)
    # keeps every instrumentation site a single ``is not None`` test and
    # allocates no spans; a Telemetry instruments the engine, both
    # schedulers, and the paged pool, binds the offload ledger for
    # span-level FLOP attribution, and becomes the process-global handle
    # the executor's trace-time dispatch counter consults.
    telemetry: Optional[obs.Telemetry] = None
    _serve_params: Any = field(default=None, repr=False)
    _decode_jit: Any = field(default=None, repr=False)
    _step_traces: int = field(default=0, repr=False)
    _verify_traces: int = field(default=0, repr=False)
    _scheduler: Any = field(default=None, repr=False)

    def __post_init__(self):
        q = self.quant if self.quant is not None else self.cfg.quant
        if q == "q8_0":
            self._serve_params = quantize_tree(self.params, _keep_dense)
        else:
            self._serve_params = self.params
        cfg = self.cfg
        # Pre-tune the canonical single-utterance workload (full 30s
        # window) so the common case never pays a first-invocation sweep
        # (DESIGN.md §9.4); transcribe() re-warms for the actual batch and
        # frame count before its timers start. Warming follows the
        # *resolved* quantization q, which may override cfg.quant.
        self._serve_quant = q
        if (self.offload is not None and self.offload.tuner is not None
                and cfg.family == "audio"):
            whisper_lib.warm_tuning(cfg, self.offload, quant=q)
            self.offload.tuner.save()

        if self.mesh is not None:
            # place serving weights on the mesh (DESIGN.md §13): TP over
            # "model" where dims divide, replicated over the slot-DP
            # "data" axis; Q8_0 qs/scales legs inherit the dense rule
            specs = shard_rules.serve_param_specs(self._serve_params,
                                                  self.mesh)
            self._serve_params = jax.device_put(
                self._serve_params, shard_rules.named(self.mesh, specs))
            if self.offload is not None:
                # stamp the signature into every PlanEntry this engine
                # resolves — sharded plans never equal unsharded ones
                self.offload.mesh_sig = shard_rules.mesh_signature(self.mesh)

        engine = self.offload
        mesh = self.mesh

        def decode_fn(params, token, state):
            # activation_sharding activates at trace time, which is when
            # the executor's ctx.constrain batch anchors bake in
            with shard_ctx.activation_sharding(mesh):
                return model_lib.serve_step(params, cfg, token, state,
                                            engine=engine)

        # dispatch is trace-pure (DESIGN.md §10.1): jit unconditionally,
        # engine attached or not — routing resolves at trace time and all
        # accounting happens via plan commits outside the traced fn
        self._decode_fn = decode_fn
        self._decode_jit = jax.jit(decode_fn)

        eos = -1 if self.eos_id is None else int(self.eos_id)

        def step_fn(params, token, done, state):
            """One greedy decode step with an on-device done-mask: emit
            the argmax token and fold its EOS test into ``done`` without
            leaving the device. Shape-stable across both serving modes —
            the continuous-batching scheduler drives the SAME compiled
            step at its pool width (DESIGN.md §11.2). The trace counter
            increments only when jax re-traces (host code runs at trace
            time), which is how tests and the continuous_batching
            benchmark assert zero retraces after warmup."""
            self._step_traces += 1
            logits, state = decode_fn(params, token, state)
            nxt = self._argmax(logits[:, -1])[:, None]
            done = done | (nxt[:, 0] == eos)
            return nxt, done, state

        self._step_jit = jax.jit(step_fn)

        def verify_core(params, tokens, state):
            """The k-position verify step (DESIGN.md §17.1): score a
            (B, W) window in one forward, advancing every cache length
            by W. Lives next to ``_decode_jit`` so the speculative
            engine drives the same compiled-program discipline — one
            trace per (B, W, frames) shape."""
            with shard_ctx.activation_sharding(mesh):
                return model_lib.verify_step(params, cfg, tokens, state,
                                             engine=engine)

        def verify_fn(params, tokens, state):
            # counted exactly like _step_traces (host code runs at trace
            # time); plan recording uses the counter-free _verify_fn so
            # an eval_shape never inflates the zero-retrace gate
            self._verify_traces += 1
            return verify_core(params, tokens, state)

        self._verify_fn = verify_core
        self._verify_jit = jax.jit(verify_fn)

        if cfg.family == "audio":
            def prefill_fn(params, mel):
                """Whisper prefill: encoder once per utterance batch +
                per-layer cross-K/V projection (paper Fig 1)."""
                with shard_ctx.activation_sharding(mesh):
                    memory = whisper_lib.encode(params, cfg, mel,
                                                engine=engine)
                    state = model_lib.init_serve_state(
                        params, cfg, mel.shape[0], self.max_len,
                        memory=memory, engine=engine)
                    return memory, state
        else:
            def prefill_fn(params, tokens):
                """LM prefill: one traced scan of serve_step over the
                prompt (fills the decode caches, returns last logits)."""
                with shard_ctx.activation_sharding(mesh):
                    state = model_lib.init_serve_state(
                        params, cfg, tokens.shape[0], self.max_len)
                    return model_lib.prefill(params, cfg,
                                             {"tokens": tokens},
                                             state, engine=engine)

        self._prefill_fn = prefill_fn
        self._prefill_jit = jax.jit(prefill_fn)
        self._plans = PlanCache()

        if self.telemetry is not None:
            # bind AFTER warm_tuning: warmup plan commits predate the
            # consistency window, so span-claimed FLOPs start from zero
            # exactly when the ledger baseline does (DESIGN.md §16.2)
            if self.offload is not None:
                self.telemetry.bind_ledger(self.offload.ledger)
            obs.activate(self.telemetry)

    def _argmax(self, logits: jax.Array) -> jax.Array:
        """Greedy pick over the true vocab (vocab_pad columns excluded)."""
        v = self.cfg.vocab_size
        if logits.shape[-1] > v:
            logits = logits[..., :v]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def _key(self, phase: str, batch: int, *extra: Hashable,
             pages: Optional[Any] = None, role: Optional[str] = None,
             k: Optional[int] = None) -> Hashable:
        """This engine's canonical plan key: ``(phase, quant, batch,
        *extra)`` plus the mesh signature when serving sharded
        (DESIGN.md §13), the page geometry when serving paged
        (DESIGN.md §15.5), and the draft/verify role + window size when
        serving speculatively (DESIGN.md §17.2) — the one-shot paths and
        every scheduler build keys here, so sharded/paged/speculative
        programs at the same shapes land in distinct ``PlanCache``
        entries."""
        return plan_key(phase, self._serve_quant, batch, *extra,
                        mesh=self.mesh, pages=pages, role=role, k=k)

    def _plan(self, key: Hashable, fn, *args) -> Optional[DispatchPlan]:
        """Routing plan for ``fn(*args)``, cached per shape key
        (DESIGN.md §10.3): repeat requests at the same (batch, seq,
        quant) point are dict hits and never re-trace."""
        if self.offload is None:
            return None
        tele = self.telemetry
        if tele is not None and key not in self._plans.plans:
            # trace the one-time plan-build (a real jax trace); cache hits
            # skip the span entirely — they are dict lookups
            with tele.span("plan_build", cat="engine",
                           args={"key": str(key)}):
                return self._plans.get_or_build(
                    key, lambda: record_plan(self.offload, fn, *args,
                                             key=key))
        return self._plans.get_or_build(
            key, lambda: record_plan(self.offload, fn, *args, key=key))

    def _greedy_loop(self, state, first_token: jax.Array,
                     max_new: int) -> Dict[str, Any]:
        b = first_token.shape[0]
        token = first_token
        done = jnp.zeros((b,), bool)
        toks = []
        t0 = time.perf_counter()
        steps = 0
        for _ in range(max_new):
            token, done, state = self._step_jit(self._serve_params, token,
                                                done, state)
            toks.append(token)
            steps += 1
            if bool(done.all()):
                break
        jax.block_until_ready(token)
        out = (np.concatenate([np.asarray(t) for t in toks], axis=1)
               if toks else np.zeros((b, 0), np.int32))
        return {"tokens": out, "decode_s": time.perf_counter() - t0,
                "steps": steps, "state": state}

    def _finalize(self, r: Dict[str, Any], prefill_s: float
                  ) -> List[GenerationResult]:
        """Per-request results from a batch greedy loop: each row is
        truncated at its first EOS (inclusive — matching what a batch-1
        run of the same request returns) and ``steps`` is that row's own
        generated count, NOT the batch-global step count. Rows that never
        hit EOS keep all ``r['steps']`` tokens."""
        out = r["tokens"]
        b = out.shape[0]
        eos = self.eos_id
        results = []
        for i in range(b):
            row = out[i].tolist()
            if eos is not None and eos in row:
                row = row[:row.index(eos) + 1]
            results.append(GenerationResult(
                tokens=row, prefill_s=prefill_s / b,
                decode_s=r["decode_s"] / b, steps=len(row)))
        return results

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int = 32
                 ) -> List[GenerationResult]:
        """LM families. prompts: (B, S_prompt) int32 (already padded).
        Returns one result per request; ``tokens`` are the generated
        tokens only (see the module-level token contract)."""
        b, s = prompts.shape
        tokens = jnp.asarray(prompts)
        prefill_plan = self._plan(self._key("prefill", b, s),
                                  self._prefill_fn, self._serve_params,
                                  tokens)
        t0 = time.perf_counter()
        with obs.maybe_span(self.telemetry, "prefill", cat="engine",
                            ledger=True, args={"batch": b, "seq": s}):
            logits, state = self._prefill_jit(self._serve_params, tokens)
            jax.block_until_ready(logits)
            first = self._argmax(logits[:, -1])[:, None]
            prefill_s = time.perf_counter() - t0
            if self.offload is not None:
                # the prefill plan records ONE scan-body execution; the
                # scan runs once per prompt token; committing inside the
                # ledger span attributes these FLOPs to prefill
                self.offload.ledger.commit(prefill_plan, times=s)
        step_plan = self._plan(self._key("step", b), self._decode_fn,
                               self._serve_params, first, state)
        with obs.maybe_span(self.telemetry, "decode", cat="engine",
                            ledger=True, args={"batch": b}):
            r = self._greedy_loop(state, first, max_new)
            if self.offload is not None:
                self.offload.ledger.commit(step_plan, times=r["steps"])
        return self._finalize(r, prefill_s)

    def transcribe(self, mel: np.ndarray, sot_id: int = 1,
                   max_new: int = 32) -> List[GenerationResult]:
        """Whisper path: encoder once per utterance batch, cross-KV cached,
        autoregressive decode (paper Fig 1). ``tokens`` are the generated
        tokens only (the SOT seed token is not echoed back) — identical
        contract to ``generate()``."""
        assert self.cfg.family == "audio"
        b, f = mel.shape[0], mel.shape[1]
        q = self._serve_quant
        if self.offload is not None and self.offload.tuner is not None:
            # warm the *actual* batch/frame-count keys (the construction-
            # time warm covers only the canonical 1x1500 shapes) so tuning
            # searches never land inside the timed request; repeat calls
            # are pure cache hits. Persist only when new winners appeared.
            tuner = self.offload.tuner
            n0 = tuner.searches
            whisper_lib.warm_tuning(self.cfg, self.offload,
                                    n_frames=f, batch=b, n_tokens=max_new,
                                    quant=q)
            if tuner.searches > n0:
                tuner.save()
        mel_j = jnp.asarray(mel)
        prefill_plan = self._plan(self._key("prefill", b, f),
                                  self._prefill_fn, self._serve_params,
                                  mel_j)
        t0 = time.perf_counter()
        with obs.maybe_span(self.telemetry, "prefill", cat="engine",
                            ledger=True, args={"batch": b, "frames": f}):
            memory, state = self._prefill_jit(self._serve_params, mel_j)
            jax.block_until_ready(memory)
            prefill_s = time.perf_counter() - t0
            if self.offload is not None:
                self.offload.ledger.commit(prefill_plan, times=1)
        first = jnp.full((b, 1), sot_id, jnp.int32)
        step_plan = self._plan(self._key("step", b, f), self._decode_fn,
                               self._serve_params, first, state)
        with obs.maybe_span(self.telemetry, "decode", cat="engine",
                            ledger=True, args={"batch": b}):
            r = self._greedy_loop(state, first, max_new)
            if self.offload is not None:
                self.offload.ledger.commit(step_plan, times=r["steps"])
        return self._finalize(r, prefill_s)

    # ------------------------------------------------------------------
    # Continuous batching (DESIGN.md §11) — thin wrappers over the slot
    # scheduler; generate()/transcribe() above stay the one-shot path.
    # ------------------------------------------------------------------
    def scheduler(self, n_slots: Optional[int] = None,
                  n_frames: Optional[int] = None):
        """The engine's continuous-batching scheduler. With no arguments
        (or matching geometry) the existing scheduler is returned; an
        explicit geometry CHANGE rebuilds the pool, refusing while the old
        scheduler still holds queued/active requests or unclaimed results.
        Audio engines need ``n_frames`` — the slot pool's fixed mel
        capacity — on first creation (the submit_audio wrapper infers it
        from the first utterance)."""
        from repro.serve.scheduler import ContinuousBatchingScheduler
        s = self._scheduler
        # dimensions left as None inherit from the live scheduler — an
        # n_frames-only change keeps the slot width and vice versa
        want_slots = n_slots if n_slots is not None else \
            (s.n_slots if s is not None else 4)
        want_frames = n_frames if n_frames is not None else \
            (s.n_frames if s is not None else None)
        if (s is None or s.n_slots != want_slots
                or s.n_frames != want_frames):
            if s is not None and (s.n_queued or s.n_active or s.finished):
                raise RuntimeError(
                    "scheduler geometry change with requests in flight or "
                    "unclaimed results — drain with run() first")
            self._scheduler = ContinuousBatchingScheduler(
                self, n_slots=want_slots, n_frames=want_frames)
        return self._scheduler

    def speculative(self, draft_cfg: ModelConfig, draft_params: Any, *,
                    k: int = 4, draft_quant: str = "none"):
        """A speculative-decoding engine over this verifier
        (serve/speculative.py, DESIGN.md §17): ``draft_cfg``/``draft_params``
        is the cheap ladder model (whisper-tiny against a base/small
        verifier) that proposes ``k`` tokens per round; this engine's
        jitted verify step scores the k+1 window and greedy acceptance
        keeps output token-exact with ``transcribe()`` alone.

        The draft model runs dense on the cheapest backend by default: its
        dispatcher pins ``xla_ref`` (prefer_pallas=False translated by the
        registry, DESIGN.md §12.3) while the verifier keeps its own
        pallas/offload routing — and both share ONE ``OffloadLedger`` so
        the by_role split and the §16.2 span exactness cover the whole
        two-model engine.

        The returned engine serves three ways: ``transcribe()`` for a
        one-shot batch, ``.continuous(n_slots, n_frames)`` for
        round-boundary admission over the §11 slot pool, and
        ``.paged(n_slots, n_frames, **geom)`` for speculative rounds over
        the §15 paged arenas with preempt-and-recompute (DESIGN.md
        §17.4)."""
        from repro.serve.speculative import SpeculativeEngine
        draft_offload = None
        if self.offload is not None:
            draft_offload = OffloadEngine(
                vmem_budget_kb=self.offload.vmem_budget_kb,
                burst=self.offload.burst,
                prefer_pallas=False,            # cheapest backend pin
                interpret=self.offload.interpret,
                ledger=self.offload.ledger)     # ONE ledger, two models
        draft = ServeEngine(draft_cfg, draft_params, max_len=self.max_len,
                            quant=draft_quant, offload=draft_offload,
                            eos_id=self.eos_id, mesh=self.mesh)
        return SpeculativeEngine(verifier=self, draft=draft, k=k)

    def paged_scheduler(self, n_slots: int = 4,
                        n_frames: Optional[int] = None, **page_cfg):
        """A paged-pool continuous-batching scheduler over this engine
        (serve/paging.py, DESIGN.md §15): fixed page arenas instead of
        per-slot preallocation, whole-utterance prefix sharing, and
        admission control that oversubscribes logical slots against
        physical pages with preempt-and-recompute. Built fresh per call —
        page geometry (``page_size``, ``n_pages``, ``cross_page_size``,
        ``n_cross_pages``) is workload-tuned and the caller owns the
        instance; the cached ``scheduler()`` stays the contiguous path."""
        from repro.serve.paging import PagedScheduler
        return PagedScheduler(self, n_slots=n_slots, n_frames=n_frames,
                              **page_cfg)

    def submit(self, prompt: np.ndarray, max_new: int = 32, *,
               n_slots: Optional[int] = None) -> int:
        """Queue one LM prompt (S,) / (1, S) on the scheduler."""
        return self.scheduler(n_slots).submit(prompt, max_new=max_new)

    def submit_audio(self, mel: np.ndarray, max_new: int = 32, *,
                     n_slots: Optional[int] = None,
                     n_frames: Optional[int] = None, sot_id: int = 1) -> int:
        """Queue one utterance (F, n_mels) / (1, F, n_mels); padded to the
        pool's frame capacity. ``n_frames`` fixes that capacity on first
        call — omitted, it is inferred from this utterance's frame count
        (later, longer utterances then need a fresh scheduler)."""
        if self._scheduler is None and n_frames is None:
            arr = np.asarray(mel)
            n_frames = int(arr.shape[0] if arr.ndim == 2 else arr.shape[1])
        return self.scheduler(n_slots, n_frames).submit(
            mel, max_new=max_new, sot_id=sot_id)

    def run(self, on_token=None) -> Dict[int, GenerationResult]:
        """Drain the scheduler: admit/decode/evict until queue and slots
        are empty, streaming tokens through ``on_token``. Returns
        {request id: GenerationResult}."""
        if self._scheduler is None:
            return {}
        return self._scheduler.run(on_token=on_token)

    # ------------------------------------------------------------------
    def energy_report(self, results: List[GenerationResult],
                      platform_w: float = energy.TPU_V5E_W) -> Dict[str, float]:
        total_s = sum(r.total_s for r in results)
        rep = {
            "requests": len(results),
            "total_s": total_s,
            "mean_s": total_s / max(len(results), 1),
            "pdp_j": energy.pdp(total_s, platform_w),
            "edp_js": energy.edp(total_s, platform_w),
            "offload_rate": (self.offload.stats.offload_rate()
                             if self.offload else 0.0),
        }
        if self.offload is not None:
            rep["dispatch"] = {"plans": len(self._plans),
                               "plan_hits": self._plans.hits,
                               "plan_misses": self._plans.misses,
                               "ledger_commits": self.offload.ledger.commits,
                               # per-backend call attribution from the
                               # plan-pinned backends (DESIGN.md §12.3)
                               "by_backend": dict(
                                   self.offload.stats.by_backend),
                               # per-device FLOP attribution under sharded
                               # serving (DESIGN.md §13); sums to the
                               # offloaded+fallback+residual flop total
                               "by_device": dict(
                                   self.offload.stats.by_device),
                               # per-role FLOP attribution for multi-model
                               # (speculative) engines (DESIGN.md §17.2);
                               # sums to the same flop total
                               "by_role": dict(
                                   self.offload.stats.by_role)}
        if self.offload is not None and self.offload.tuner is not None:
            t = self.offload.tuner
            rep["tuning"] = {"cache_hits": t.cache.hits,
                             "cache_misses": t.cache.misses,
                             "searches": t.searches,
                             "tuned_calls": self.offload.stats.tuned_calls}
        return rep
