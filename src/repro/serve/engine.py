"""Serving engine: batched autoregressive decode with the paper's Q8_0
offload path as a first-class option, plus per-request PDP/EDP accounting.

This is the system the paper builds in whisper.cpp terms: quantized weights
(Q8_0 blocks), the dominant dot-product kernels routed through the offload
dispatcher (core/offload.py — main segment on the accelerator kernel,
residual on the host), everything else on the plain XLA path, and the
energy model (core/energy.py) attributing accelerator-active vs host time
exactly like Eq. 2/3.

Request flow:
  submit(prompt)/submit_audio(mel) -> queued
  run() -> batches queued requests (padding to the batch size), prefills,
           then decodes greedily until EOS/max_new_tokens, recording
           wall-time and PDP per request.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import energy
from repro.core.offload import OffloadEngine
from repro.core.qformats import quantize_tree
from repro.models import model as model_lib
from repro.models import whisper as whisper_lib


@dataclass
class GenerationResult:
    tokens: List[int]
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    def pdp_j(self, power_w: float = energy.TPU_V5E_W) -> float:
        return energy.pdp(self.total_s, power_w)

    def edp_js(self, power_w: float = energy.TPU_V5E_W) -> float:
        return energy.edp(self.total_s, power_w)


def _keep_dense(path, leaf) -> bool:
    """Quantization predicate mirroring whisper.cpp: quantize big GEMM
    weights, keep norms / biases / positional tables / conv / router in
    fp16. Biases are matched by their full leaf name ('b'), NOT a '/b'
    substring (which would swallow everything under '/blocks/')."""
    parts = [str(getattr(k, "key", getattr(k, "name", k))).lower()
             for k in path]
    name = "/".join(parts)
    if parts and parts[-1] in ("b", "bias", "conv_w", "conv_b"):
        return False
    if any(s in name for s in ("norm", "pos", "a_log", "dt_bias", "router")):
        return False
    return True


@dataclass
class ServeEngine:
    cfg: ModelConfig
    params: Any
    max_len: int = 512
    quant: Optional[str] = None          # None -> cfg.quant
    offload: Optional[OffloadEngine] = None
    eos_id: int = 0
    _serve_params: Any = field(default=None, repr=False)
    _decode_jit: Any = field(default=None, repr=False)

    def __post_init__(self):
        q = self.quant if self.quant is not None else self.cfg.quant
        if q == "q8_0":
            self._serve_params = quantize_tree(self.params, _keep_dense)
        else:
            self._serve_params = self.params
        cfg = self.cfg
        # Pre-tune the canonical single-utterance workload (full 30s
        # window) so the common case never pays a first-invocation sweep
        # (DESIGN.md §9.4); transcribe() re-warms for the actual batch and
        # frame count before its timers start. Warming follows the
        # *resolved* quantization q, which may override cfg.quant.
        self._serve_quant = q
        if (self.offload is not None and self.offload.tuner is not None
                and cfg.family == "audio"):
            whisper_lib.warm_tuning(cfg, self.offload, quant=q)
            self.offload.tuner.save()

        def decode_fn(params, token, state):
            return model_lib.serve_step(params, cfg, token, state,
                                        engine=self.offload)

        # the offload engine's python-side stats accounting makes the fn
        # impure; jit only when no engine is attached
        self._decode_jit = (jax.jit(decode_fn) if self.offload is None
                            else decode_fn)

    def _argmax(self, logits: jax.Array) -> jax.Array:
        """Greedy pick over the true vocab (vocab_pad columns excluded)."""
        v = self.cfg.vocab_size
        if logits.shape[-1] > v:
            logits = logits[..., :v]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def _greedy_loop(self, state, first_token: jax.Array,
                     max_new: int) -> Dict[str, Any]:
        b = first_token.shape[0]
        token = first_token
        out = np.zeros((b, max_new), np.int32)
        done = np.zeros((b,), bool)
        t0 = time.perf_counter()
        steps = 0
        for i in range(max_new):
            logits, state = self._decode_jit(self._serve_params, token, state)
            token = self._argmax(logits[:, -1])[:, None]
            tok_np = np.asarray(token)[:, 0]
            out[:, i] = tok_np
            done |= tok_np == self.eos_id
            steps += 1
            if bool(done.all()):
                break
        jax.block_until_ready(token)
        return {"tokens": out[:, :steps], "decode_s": time.perf_counter() - t0,
                "steps": steps, "state": state}

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int = 32
                 ) -> List[GenerationResult]:
        """LM families. prompts: (B, S_prompt) int32 (already padded)."""
        b, s = prompts.shape
        t0 = time.perf_counter()
        state = model_lib.init_serve_state(
            self._serve_params, self.cfg, b, self.max_len)
        # prefill by stepping the prompt (cache-filling path)
        tok = jnp.asarray(prompts[:, :1])
        for t in range(s):
            tok = jnp.asarray(prompts[:, t:t + 1])
            logits, state = self._decode_jit(self._serve_params, tok, state)
        first = self._argmax(logits[:, -1])[:, None]
        prefill_s = time.perf_counter() - t0
        r = self._greedy_loop(state, first, max_new)
        return [GenerationResult(
            tokens=[int(prompts[i, -1])] + r["tokens"][i].tolist(),
            prefill_s=prefill_s / b, decode_s=r["decode_s"] / b,
            steps=r["steps"]) for i in range(b)]

    def transcribe(self, mel: np.ndarray, sot_id: int = 1,
                   max_new: int = 32) -> List[GenerationResult]:
        """Whisper path: encoder once per utterance batch, cross-KV cached,
        autoregressive decode (paper Fig 1)."""
        assert self.cfg.family == "audio"
        b = mel.shape[0]
        if self.offload is not None and self.offload.tuner is not None:
            # warm the *actual* batch/frame-count keys (the construction-
            # time warm covers only the canonical 1x1500 shapes) so tuning
            # searches never land inside the timed request; repeat calls
            # are pure cache hits. Persist only when new winners appeared.
            tuner = self.offload.tuner
            n0 = tuner.searches
            whisper_lib.warm_tuning(self.cfg, self.offload,
                                    n_frames=mel.shape[1], batch=b,
                                    n_tokens=max_new,
                                    quant=self._serve_quant)
            if tuner.searches > n0:
                tuner.save()
        t0 = time.perf_counter()
        memory = whisper_lib.encode(self._serve_params, self.cfg,
                                    jnp.asarray(mel), engine=self.offload)
        state = model_lib.init_serve_state(
            self._serve_params, self.cfg, b, self.max_len, memory=memory,
            engine=self.offload)
        jax.block_until_ready(memory)
        prefill_s = time.perf_counter() - t0
        first = jnp.full((b, 1), sot_id, jnp.int32)
        r = self._greedy_loop(state, first, max_new)
        return [GenerationResult(
            tokens=r["tokens"][i].tolist(), prefill_s=prefill_s / b,
            decode_s=r["decode_s"] / b, steps=r["steps"])
            for i in range(b)]

    # ------------------------------------------------------------------
    def energy_report(self, results: List[GenerationResult],
                      platform_w: float = energy.TPU_V5E_W) -> Dict[str, float]:
        total_s = sum(r.total_s for r in results)
        rep = {
            "requests": len(results),
            "total_s": total_s,
            "mean_s": total_s / max(len(results), 1),
            "pdp_j": energy.pdp(total_s, platform_w),
            "edp_js": energy.edp(total_s, platform_w),
            "offload_rate": (self.offload.stats.offload_rate()
                             if self.offload else 0.0),
        }
        if self.offload is not None and self.offload.tuner is not None:
            t = self.offload.tuner
            rep["tuning"] = {"cache_hits": t.cache.hits,
                             "cache_misses": t.cache.misses,
                             "searches": t.searches,
                             "tuned_calls": self.offload.stats.tuned_calls}
        return rep
