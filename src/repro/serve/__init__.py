"""Serving stack: the jitted ServeEngine with trace-pure offload dispatch
(DESIGN.md §10), the continuous-batching scheduler over a fixed-shape slot
KV-cache pool (DESIGN.md §11), and mesh-sharded serving — slot-axis DP
over the device mesh (DESIGN.md §13)."""
from repro.serve.engine import GenerationResult, ServeEngine  # noqa: F401
from repro.serve.kvcache import (  # noqa: F401
    SlotKVPool, slot_insert, slot_reset)
from repro.serve.scheduler import (  # noqa: F401
    ContinuousBatchingScheduler, TokenEvent)
