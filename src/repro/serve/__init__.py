from repro.serve.engine import GenerationResult, ServeEngine  # noqa: F401
from repro.serve.kvcache import (  # noqa: F401
    SlotKVPool, slot_insert, slot_reset)
from repro.serve.scheduler import (  # noqa: F401
    ContinuousBatchingScheduler, TokenEvent)
