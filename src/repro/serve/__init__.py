from repro.serve.engine import GenerationResult, ServeEngine  # noqa: F401
