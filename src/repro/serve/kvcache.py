"""Fixed-shape slot KV-cache pool for continuous batching (DESIGN.md §11.1).

The pool preallocates ONE slot-layout decode state of static width
``n_slots`` (and, for whisper, static frame capacity ``n_frames``) at
construction, and never reshapes it: admission and eviction are pure
``jax.lax.dynamic_update_*`` splices along the batch axis, so the jitted
decode ``step_fn`` of serve/engine.py keeps seeing one shape forever —
zero retraces across any admission/eviction schedule (the property
tests/test_scheduler.py regression-gates, in the style of
tests/test_plan.py).

Ops (all jit-compiled once per pool shape, shared module-level caches):

  slot_insert(pool, slot, req)  splice a single-request prefill state
                                (whisper encoder + cross-KV, or LM prompt
                                scan — standard layout, batch 1) into live
                                slot ``slot``; counters land as per-slot
                                vectors via ``model.slot_layout``.
  slot_reset(pool, slot)        zero the slot row (KV buffers + counters)
                                on eviction, bounding the free slot's
                                counter drift between occupants.

Free slots keep decoding garbage — that is the fixed-shape contract (the
batch always computes all ``n_slots`` rows; the paper's CGLA keeps its
lanes busy the same way) — and every insert overwrites the entire slot
row, so stale state can never leak into a new request.

Sharded pools (DESIGN.md §13): with a serving mesh attached, the slot
axis shards over the mesh's "data" axis (``model.slot_state_specs``) and
the pool becomes the data axis of sharded serving. The splice jits get
``out_shardings`` pinned to the pool's sharding, so admission/eviction
never un-shards the state and nothing is gathered to the host between
steps; ``acquire`` becomes shard-aware — it admits into the slot range of
the least-loaded device so active slots spread across the mesh.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.model import ServeState
from repro.sharding import rules as shard_rules


def slot_insert(pool: ServeState, slot: jax.Array,
                req: ServeState) -> ServeState:
    """Pure slot splice: write single-request decode state ``req``
    (standard layout, batch 1) into row ``slot`` of the slot-layout
    ``pool``. jit-safe: ``slot`` may be traced; every leaf updates via
    ``jax.lax.dynamic_update_slice_in_dim`` on its batch axis
    (``model.slot_batch_axis``)."""
    req = model_lib.slot_layout(req, 1)

    def upd(p, r):
        return jax.lax.dynamic_update_slice_in_dim(
            p, r.astype(p.dtype), slot, axis=model_lib.slot_batch_axis(False))

    step = jax.lax.dynamic_update_slice_in_dim(
        pool.step, req.step.astype(pool.step.dtype), slot,
        axis=model_lib.slot_batch_axis(True))
    return ServeState(
        layer_states=jax.tree_util.tree_map(upd, pool.layer_states,
                                            req.layer_states),
        step=step)


def slot_reset(pool: ServeState, slot: jax.Array) -> ServeState:
    """Pure slot clear: zero row ``slot`` of every leaf (KV buffers,
    counters, step). Not required for correctness — ``slot_insert``
    overwrites the whole row — but it pins freed slots' per-slot counters
    back to 0 so an idle slot's position never drifts toward the cache
    horizon between occupants."""
    def zero(p):
        ax = model_lib.slot_batch_axis(False)
        shape = p.shape[:ax] + (1,) + p.shape[ax + 1:]
        return jax.lax.dynamic_update_slice_in_dim(
            p, jnp.zeros(shape, p.dtype), slot, axis=ax)

    step = jax.lax.dynamic_update_slice_in_dim(
        pool.step, jnp.zeros((1,), pool.step.dtype), slot,
        axis=model_lib.slot_batch_axis(True))
    return ServeState(
        layer_states=jax.tree_util.tree_map(zero, pool.layer_states),
        step=step)


# Module-level jits: shared across every pool instance, so repeatedly
# constructing schedulers (tests, benchmarks) re-traces only on a genuinely
# new pool shape.
_INSERT_JIT = jax.jit(slot_insert)
_RESET_JIT = jax.jit(slot_reset)


class SlotKVPool:
    """The preallocated slot pool + host-side free-slot bookkeeping.

    ``state`` is a slot-layout ``ServeState`` of static shape
    ``(n_slots, max_len, ...)`` built once at construction (for whisper,
    the cross-KV rows are sized to the fixed ``n_frames`` capacity every
    admitted utterance is padded to). ``acquire``/``release`` manage the
    free list; ``insert`` is the splice a scheduler calls on admission.
    ``mesh`` shards the slot axis over the mesh's "data" axis
    (DESIGN.md §13); slots then partition into ``n_shards`` device-local
    ranges of ``shard_size`` and ``acquire`` balances admission across
    them.
    """

    def __init__(self, cfg, params, n_slots: int, max_len: int,
                 n_frames: Optional[int] = None, mesh=None):
        self.n_slots = n_slots
        self.max_len = max_len
        self.n_frames = n_frames
        dtype = model_lib._dtype(cfg)
        if cfg.family == "audio":
            if n_frames is None:
                raise ValueError("audio slot pool needs a fixed n_frames "
                                 "capacity (utterances are padded to it)")
            # zeros memory only shapes the cross-KV rows; insert()
            # overwrites them with the request's real prefill state.
            # engine=None: pool init must not touch the offload ledger.
            memory = jnp.zeros((n_slots, n_frames, cfg.d_model), dtype)
            st = model_lib.init_serve_state(params, cfg, n_slots, max_len,
                                            memory=memory, engine=None)
        else:
            st = model_lib.init_serve_state(params, cfg, n_slots, max_len)
        self.state: ServeState = model_lib.slot_layout(st, n_slots)
        self.mesh = mesh
        self.n_shards = 1
        self._insert_jit = _INSERT_JIT
        self._reset_jit = _RESET_JIT
        if mesh is not None:
            specs = model_lib.slot_state_specs(self.state, mesh)
            shardings = shard_rules.named(mesh, specs)
            self.state = jax.device_put(self.state, shardings)
            # per-pool jits with out_shardings pinned: the splice can
            # never silently un-shard the pool, whatever GSPMD would
            # propagate from the batch-1 request operand
            self._insert_jit = jax.jit(slot_insert, out_shardings=shardings)
            self._reset_jit = jax.jit(slot_reset, out_shardings=shardings)
            dsize = (mesh.shape["data"]
                     if "data" in mesh.axis_names else 1)
            if dsize > 1 and n_slots % dsize == 0:
                self.n_shards = dsize
        self.shard_size = n_slots // self.n_shards
        self._init_free()

    # -- free-slot bookkeeping (host side) -----------------------------
    def _init_free(self) -> None:
        """Per-shard sorted free lists — occupancy is maintained
        incrementally, so ``acquire`` is O(n_shards) instead of the old
        per-call scan over every free slot (ISSUE 7: the oversubscribing
        paged scheduler multiplies admission passes, so admission cost
        must not grow with pool width)."""
        self._free_by_shard: List[List[int]] = [
            list(range(s * self.shard_size, (s + 1) * self.shard_size))
            for s in range(self.n_shards)]
        self._n_free = self.n_slots

    @property
    def n_free(self) -> int:
        return self._n_free

    def slot_shard(self, slot: int) -> int:
        """Device-shard index owning ``slot`` (0 when unsharded)."""
        return slot // self.shard_size

    def acquire(self) -> int:
        """Claim a free slot (raises when full). Unsharded pools take the
        lowest index; sharded pools admit into the device-local slot range
        with the fewest active occupants (ties -> lowest index), so load
        spreads across the mesh instead of piling onto shard 0
        (DESIGN.md §13). O(n_shards): the per-shard free lists carry the
        occupancy counters, so nothing is scanned per call."""
        if self._n_free == 0:
            raise IndexError("pool full: no free slot")
        # fewest active == most free; prefer the lower shard on ties —
        # identical pick order to the old full-scan implementation
        shard = max(range(self.n_shards),
                    key=lambda s: (len(self._free_by_shard[s]), -s))
        self._n_free -= 1
        return self._free_by_shard[shard].pop(0)

    def release(self, slot: int, reset: bool = True) -> None:
        """Return ``slot`` to the free list. ``reset=False`` skips zeroing
        the row — safe because ``insert`` overwrites the entire slot before
        reuse and freed slots' garbage is never read (the scheduler's hot
        path uses it; a reset is a full pool-state copy per eviction)."""
        if reset:
            self.state = self._reset_jit(self.state, slot)
        bisect.insort(self._free_by_shard[self.slot_shard(slot)], slot)
        self._n_free += 1

    # -- memory accounting (DESIGN.md §15.4) ----------------------------
    def committed_kv_bytes(self) -> int:
        """Bytes preallocated for the whole pool state — what this
        contiguous layout commits regardless of occupancy."""
        return model_lib.state_kv_bytes(self.state)

    def used_kv_bytes(self, lengths: Dict[int, int]) -> int:
        """Bytes of committed state holding live request data, given the
        active slots' decode lengths: positional KV rows count
        proportionally to their filled length, fixed-size rows (whisper
        cross-KV) count whole per active slot. ``kv_utilization`` in the
        serving benchmarks is used/committed."""
        if not lengths:
            return 0
        n_active = len(lengths)
        frac = sum(min(l, self.max_len)
                   for l in lengths.values()) / self.max_len
        total = 0.0
        for leaf in jax.tree_util.tree_leaves(self.state.layer_states):
            per_slot = leaf.size // leaf.shape[1] * leaf.dtype.itemsize
            if leaf.ndim >= 3 and leaf.shape[2] == self.max_len:
                total += per_slot * frac
            else:
                total += per_slot * n_active
        return int(total)

    # -- state ops ------------------------------------------------------
    def insert(self, slot: int, req_state: ServeState) -> None:
        """Splice a batch-1 prefill state into ``slot`` (jitted; sharded
        pools keep their slot-axis sharding via pinned out_shardings)."""
        self.state = self._insert_jit(self.state, slot, req_state)
