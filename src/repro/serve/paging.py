"""Paged KV-cache pool: prefix sharing, preemption, admission control
(DESIGN.md §15).

The contiguous ``SlotKVPool`` (DESIGN.md §11.1) commits
``n_slots x max_len`` self-KV plus ``n_slots x n_frames`` cross-KV up
front: short utterances pay for the longest, identical utterances (hot
audio preambles) duplicate their cross-KV wholesale, and the scheduler can
never admit more requests than physical slots. This module replaces that
with vLLM-style paging restated under the repo's zero-retrace discipline
(DESIGN.md §10): all KV lives in ONE fixed-shape page arena per kind
(self/cross), each slot reaches its pages through a per-slot int32 block
table gathered inside the jitted step (``attention.PagedKVCache``), and
every admission/eviction/preemption is a host-side table edit plus at most
one pre-traced splice — the compiled decode step sees one shape forever.

Pieces (DESIGN.md §15.1-§15.5):

  ``PageAllocator``     refcounted physical pages, host side. Page 0 is
                        reserved as the trash page free slots write/read
                        through; per-shard free ranges give shard-aware
                        placement under a serving mesh.
  ``PagedKVPool``       the two arenas + block tables + allocators.
                        Prefix sharing: identical padded utterances hash
                        to the same cross-KV page list (whole-utterance
                        identity — whisper's encoder is bidirectional, so
                        a *partial* mel prefix does not determine any
                        cross-KV prefix; token-prefix sharing for LM
                        families plugs in through the same refcount +
                        ``ensure_private`` copy-on-write machinery, which
                        is why self pages carry refcounts at all).
  ``PagedScheduler``    ``ContinuousBatchingScheduler`` with admission
                        control against pages instead of slots: logical
                        slots oversubscribe the arena, a pre-step capacity
                        pass allocates page-boundary crossings (CoW-
                        splitting shared pages before any write), and
                        exhaustion preempts the victim losing the fewest
                        pages — preempt-and-recompute replays its tokens
                        through the batch-1 decode (greedy decode is
                        deterministic, so the replay is token-exact), with
                        the replay's plan commits and wall time attributed
                        to that request so PDP stays exact-by-steps-lived
                        (DESIGN.md §11.3).

Gates: ``benchmarks/paged_serving.py`` holds the paged path to token-exact
parity with the contiguous scheduler, zero step retraces after warmup, and
>=2x admitted-requests-per-GB on a shared-prefix trace (DESIGN.md §15.4).
"""
from __future__ import annotations

import hashlib
import time
from bisect import insort
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as model_lib
from repro.models.model import ServeState
from repro.models.whisper import WhisperPagedDecodeState
from repro.serve.scheduler import (ContinuousBatchingScheduler, _ActiveSlot,
                                   _QueuedRequest)
from repro.sharding import rules as shard_rules


class PagesExhausted(RuntimeError):
    """Arena out of free pages — the scheduler's cue to preempt."""


class PageAllocator:
    """Refcounted physical-page allocator (host side, DESIGN.md §15.1).

    The first ``reserve`` pages are never handed out — page 0 is the trash
    page every freed slot's table row points back to, so garbage rows of
    the fixed-shape batch write into memory nobody owns. ``n_shards``
    partitions the allocatable pages into contiguous ranges so a sharded
    arena can prefer device-local pages (DESIGN.md §15.3); allocation
    picks the preferred shard when it has a free page, else the shard with
    the most free pages (ties -> lowest), lowest page index within it —
    deterministic for a deterministic op sequence.

    Invariants (property-tested in tests/test_paging_properties.py):
    ``alloc`` never returns a page with refcount > 0; free + allocated
    always equals the allocatable arena size; ``release`` to refcount 0
    returns the page to the free list.
    """

    def __init__(self, n_pages: int, n_shards: int = 1, reserve: int = 1):
        if n_pages <= reserve:
            raise ValueError(f"arena of {n_pages} pages leaves nothing to "
                             f"allocate past the {reserve} reserved")
        if n_shards < 1 or n_pages % n_shards:
            n_shards = 1
        self.n_pages = n_pages
        self.reserve = reserve
        self.n_shards = n_shards
        self._shard_size = n_pages // n_shards
        self.refcount = np.zeros(n_pages, np.int64)
        self._free: List[List[int]] = [
            [p for p in range(s * self._shard_size,
                              (s + 1) * self._shard_size) if p >= reserve]
            for s in range(n_shards)]
        self._n_free = n_pages - reserve

    @property
    def n_allocatable(self) -> int:
        return self.n_pages - self.reserve

    @property
    def n_free(self) -> int:
        return self._n_free

    @property
    def n_allocated(self) -> int:
        return self.n_allocatable - self._n_free

    def page_shard(self, page: int) -> int:
        return page // self._shard_size

    def can_alloc(self, n: int) -> bool:
        return self._n_free >= n

    def alloc(self, prefer: Optional[int] = None) -> int:
        """Claim a free page at refcount 1; raises ``PagesExhausted`` when
        the arena is dry (never resizes — fixed shapes are the law)."""
        if self._n_free == 0:
            raise PagesExhausted(
                f"all {self.n_allocatable} pages allocated")
        if prefer is not None and self._free[prefer % self.n_shards]:
            shard = prefer % self.n_shards
        else:
            shard = max(range(self.n_shards),
                        key=lambda s: (len(self._free[s]), -s))
        page = self._free[shard].pop(0)
        assert self.refcount[page] == 0
        self.refcount[page] = 1
        self._n_free -= 1
        return page

    def retain(self, page: int) -> None:
        """Add a reference (prefix sharing / page aliasing)."""
        if self.refcount[page] <= 0:
            raise ValueError(f"retain of unallocated page {page}")
        self.refcount[page] += 1

    def release(self, page: int) -> bool:
        """Drop a reference; at refcount 0 the page returns to its shard's
        free list immediately (a just-evicted request's pages are
        admissible in the same scheduler pass — ISSUE 7 satellite).
        Returns True when the page was actually freed."""
        if self.refcount[page] <= 0:
            raise ValueError(f"release of unallocated page {page}")
        self.refcount[page] -= 1
        if self.refcount[page]:
            return False
        insort(self._free[self.page_shard(page)], page)
        self._n_free += 1
        return True


# ---------------------------------------------------------------------------
# Jitted arena ops (module-level: shared across pools of one geometry)
# ---------------------------------------------------------------------------
def paged_insert(state: ServeState, slot, bt_row, ct_row, req: ServeState,
                 *, write_cross: bool) -> ServeState:
    """Splice a batch-1 contiguous prefill/replay state into the arenas at
    ``slot``'s pages (DESIGN.md §15.2). Self-KV copies page-sized chunks
    of the request's contiguous cache into ``bt_row``'s physical pages
    (rows past the allocation point at trash page 0 absorb the copy
    harmlessly); ``write_cross`` statically gates the cross-KV copy —
    False on a prefix-share hit, whose pages are already populated."""
    ls = state.layer_states
    wd = req.layer_states
    sk, sv = ls.self_k, ls.self_v
    ps = sk.shape[2]
    src_k, src_v = wd.self_kv.k, wd.self_kv.v          # (R, 1, S, Hkv, hd)
    s_req = src_k.shape[2]
    for lp in range(min(bt_row.shape[0], -(-s_req // ps))):
        end = min((lp + 1) * ps, s_req)
        ck_ = src_k[:, 0, lp * ps:end]
        cv_ = src_v[:, 0, lp * ps:end]
        if end - lp * ps < ps:
            pad = ((0, 0), (0, ps - (end - lp * ps)), (0, 0), (0, 0))
            ck_, cv_ = jnp.pad(ck_, pad), jnp.pad(cv_, pad)
        sk = sk.at[:, bt_row[lp]].set(ck_.astype(sk.dtype))
        sv = sv.at[:, bt_row[lp]].set(cv_.astype(sv.dtype))
    xk, xv = ls.cross_k, ls.cross_v
    if write_cross:
        cps = xk.shape[2]
        csrc_k, csrc_v = wd.cross_kv                   # (R, 1, F, Hkv, hd)
        for cp in range(ct_row.shape[0]):
            xk = xk.at[:, ct_row[cp]].set(
                csrc_k[:, 0, cp * cps:(cp + 1) * cps].astype(xk.dtype))
            xv = xv.at[:, ct_row[cp]].set(
                csrc_v[:, 0, cp * cps:(cp + 1) * cps].astype(xv.dtype))
    lsrc = wd.self_kv.length
    l0 = lsrc[0] if lsrc.ndim else lsrc                # stacked (R,) -> ()
    length = ls.length.at[:, slot].set(l0.astype(ls.length.dtype))
    step = state.step.at[slot].set(req.step.astype(state.step.dtype))
    return ServeState(ls._replace(self_k=sk, self_v=sv, cross_k=xk,
                                  cross_v=xv, length=length), step)


def paged_attach(state: ServeState, slot) -> ServeState:
    """Zero ``slot``'s length/step counters — the whole device-side cost
    of admitting a prefix-share hit (its cross pages already hold the
    right values; its first self page starts empty)."""
    ls = state.layer_states
    return ServeState(ls._replace(length=ls.length.at[:, slot].set(0)),
                      state.step.at[slot].set(0))


def paged_copy_page(state: ServeState, src, dst) -> ServeState:
    """Copy-on-write split: duplicate self-KV physical page ``src`` into
    ``dst`` (all layers, K and V) so the writer's table can repoint to a
    private page while every other referent keeps reading ``src``."""
    ls = state.layer_states
    return ServeState(ls._replace(
        self_k=ls.self_k.at[:, dst].set(ls.self_k[:, src]),
        self_v=ls.self_v.at[:, dst].set(ls.self_v[:, src])), state.step)


_INSERT_JIT = jax.jit(paged_insert, static_argnames=("write_cross",))
_ATTACH_JIT = jax.jit(paged_attach)
_COPY_JIT = jax.jit(paged_copy_page)


def _mel_digest(payload: np.ndarray) -> str:
    """Identity hash of one padded utterance — the prefix-sharing key
    (whole-utterance: see the module docstring on why audio cannot share
    partial prefixes)."""
    return hashlib.blake2b(np.ascontiguousarray(payload).tobytes(),
                           digest_size=16).hexdigest()


class PagedKVPool:
    """Fixed-shape paged arenas + host-side page/table bookkeeping
    (DESIGN.md §15.2).

    Self-KV arena: ``(R, n_pages, page_size, Hkv, hd)`` x2, one block
    table row of ``max_pages = ceil(max_len/page_size)`` logical pages per
    slot. Cross-KV arena: ``(R, n_cross_pages, cross_page_size, ...)`` x2
    with ``n_frames/cross_page_size`` pages per distinct utterance —
    identical utterances share one page list by content hash. Block
    tables are host-authoritative numpy; ``sync()`` uploads them (dirty-
    flagged) before each decode step, so evictions and preemptions are
    pure host edits. Under a mesh the arenas shard their page axis and the
    tables their slot axis per ``sharding/rules.paged_state_specs``
    (DESIGN.md §15.3), and every splice jit pins ``out_shardings``.

    Only the audio family is implemented: whisper is the paper's workload
    and the only family with the fixed per-request cross-KV block that
    makes whole-utterance sharing pay; LM families keep the contiguous
    ``SlotKVPool`` until a token-prefix front-end lands on the same
    allocator/CoW machinery (the §15 generalization hook).
    """

    def __init__(self, cfg, params, n_slots: int, max_len: int,
                 n_frames: Optional[int] = None, *, page_size: int = 8,
                 n_pages: Optional[int] = None,
                 cross_page_size: Optional[int] = None,
                 n_cross_pages: Optional[int] = None, mesh=None):
        if cfg.family != "audio":
            raise NotImplementedError(
                "PagedKVPool currently serves the audio family only "
                "(DESIGN.md §15); LM families use the contiguous "
                "SlotKVPool")
        if n_frames is None:
            raise ValueError("audio paged pool needs a fixed n_frames "
                             "capacity (utterances are padded to it)")
        if page_size < 1 or page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, got "
                             f"{page_size}")
        cross_page_size = (n_frames if cross_page_size is None
                           else cross_page_size)
        if n_frames % cross_page_size:
            # an inexact split would leave a ragged tail page whose
            # gathered view shifts cross positions — parity would break
            raise ValueError(f"cross_page_size {cross_page_size} must "
                             f"divide n_frames {n_frames}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.n_frames = n_frames
        self.page_size = page_size
        self.cross_page_size = cross_page_size
        self.max_pages = -(-max_len // page_size)
        self.n_cross_per_req = n_frames // cross_page_size
        if n_pages is None:
            n_pages = 1 + n_slots * self.max_pages     # no oversubscription
        if n_cross_pages is None:
            n_cross_pages = 1 + n_slots * self.n_cross_per_req
        self.n_pages = n_pages
        self.n_cross_pages = n_cross_pages
        self.mesh = mesh

        r, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        dtype = model_lib._dtype(cfg)
        ls = WhisperPagedDecodeState(
            self_k=jnp.zeros((r, n_pages, page_size, hkv, hd), dtype),
            self_v=jnp.zeros((r, n_pages, page_size, hkv, hd), dtype),
            cross_k=jnp.zeros((r, n_cross_pages, cross_page_size, hkv, hd),
                              dtype),
            cross_v=jnp.zeros((r, n_cross_pages, cross_page_size, hkv, hd),
                              dtype),
            block_table=jnp.zeros((n_slots, self.max_pages), jnp.int32),
            cross_table=jnp.zeros((n_slots, self.n_cross_per_req),
                                  jnp.int32),
            length=jnp.zeros((r, n_slots), jnp.int32))
        self.state = ServeState(ls, jnp.zeros((n_slots,), jnp.int32))
        itemsize = jnp.zeros((), dtype).dtype.itemsize
        self.page_bytes = 2 * r * page_size * hkv * hd * itemsize
        self.cross_page_bytes = 2 * r * cross_page_size * hkv * hd * itemsize

        # slot + page shard geometry (DESIGN.md §15.3)
        self.n_shards = 1
        page_shards = cross_shards = 1
        self._insert_jit, self._attach_jit = _INSERT_JIT, _ATTACH_JIT
        self._copy_jit = _COPY_JIT
        self._table_shardings = None
        if mesh is not None:
            specs = shard_rules.paged_state_specs(self.state, mesh)
            shardings = shard_rules.named(mesh, specs)
            self.state = jax.device_put(self.state, shardings)
            self._insert_jit = jax.jit(paged_insert, out_shardings=shardings,
                                       static_argnames=("write_cross",))
            self._attach_jit = jax.jit(paged_attach, out_shardings=shardings)
            self._copy_jit = jax.jit(paged_copy_page, out_shardings=shardings)
            ls_sh = shardings.layer_states
            self._table_shardings = (ls_sh.block_table, ls_sh.cross_table)
            dsize = (mesh.shape["data"] if "data" in mesh.axis_names else 1)
            if dsize > 1 and n_slots % dsize == 0:
                self.n_shards = dsize
            if dsize > 1 and n_pages % dsize == 0:
                page_shards = dsize
            if dsize > 1 and n_cross_pages % dsize == 0:
                cross_shards = dsize
        self.shard_size = n_slots // self.n_shards

        # host-authoritative bookkeeping
        self._slots = PageAllocator(n_slots, self.n_shards, reserve=0)
        self.self_alloc = PageAllocator(n_pages, page_shards, reserve=1)
        self.cross_alloc = PageAllocator(n_cross_pages, cross_shards,
                                         reserve=1)
        self._bt = np.zeros((n_slots, self.max_pages), np.int32)
        self._ct = np.zeros((n_slots, self.n_cross_per_req), np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self._slot_cross: List[Optional[Tuple[str, List[int]]]] = (
            [None] * n_slots)
        self._shared: Dict[str, List[int]] = {}
        self._dirty = False
        # nullable telemetry (DESIGN.md §16.2): the owning PagedScheduler
        # hands down its handle so page-level events (cow_split) record
        self.telemetry = None

    @property
    def plan_geometry(self) -> Tuple[int, int, int, int]:
        """The page-shape component of this pool's plan keys — paged and
        contiguous programs never share a ``PlanCache`` entry."""
        return (self.page_size, self.n_pages, self.cross_page_size,
                self.n_cross_pages)

    # -- slot free list (same pick order as SlotKVPool.acquire) ---------
    @property
    def n_free(self) -> int:
        return self._slots.n_free

    def slot_shard(self, slot: int) -> int:
        return slot // self.shard_size

    def acquire(self) -> int:
        return self._slots.alloc()

    # -- admission-control surface (DESIGN.md §15.5) --------------------
    def has_shared(self, digest: str) -> bool:
        return digest in self._shared

    def can_alloc(self, n_self: int, n_cross: int) -> bool:
        return (self.self_alloc.can_alloc(n_self)
                and self.cross_alloc.can_alloc(n_cross))

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    def alloc_self_page(self, slot: int) -> int:
        """Append the next logical page for ``slot`` (shard-local when the
        arena is sharded). Raises ``PagesExhausted`` when dry."""
        page = self.self_alloc.alloc(prefer=self.slot_shard(slot))
        lp = len(self._slot_pages[slot])
        if lp >= self.max_pages:
            self.self_alloc.release(page)
            raise ValueError(f"slot {slot} already at max_pages")
        self._slot_pages[slot].append(page)
        self._bt[slot, lp] = page
        self._dirty = True
        return page

    def alias_self_page(self, dst: int, src: int, lp: int) -> int:
        """Map ``dst``'s next logical page onto ``src``'s physical page at
        ``lp`` (refcount++) — the token-prefix sharing hook; writes split
        via ``ensure_private`` before touching the shared page."""
        if len(self._slot_pages[dst]) != lp:
            raise ValueError("alias must extend dst's table contiguously")
        page = self._slot_pages[src][lp]
        self.self_alloc.retain(page)
        self._slot_pages[dst].append(page)
        self._bt[dst, lp] = page
        self._dirty = True
        return page

    def ensure_private(self, slot: int, lp: int) -> int:
        """Copy-on-write: if ``slot``'s page at logical index ``lp`` is
        shared (refcount > 1), copy it into a fresh private page and
        repoint only this slot's table — the shared page is never mutated
        (property-tested). No-op on already-private pages."""
        page = self._slot_pages[slot][lp]
        if self.self_alloc.refcount[page] <= 1:
            return page
        fresh = self.self_alloc.alloc(prefer=self.slot_shard(slot))
        self.state = self._copy_jit(self.state, page, fresh)
        self.self_alloc.release(page)
        self._slot_pages[slot][lp] = fresh
        self._bt[slot, lp] = fresh
        self._dirty = True
        if self.telemetry is not None:
            self.telemetry.instant("cow_split", slot=slot, lp=lp,
                                   src=int(page), dst=int(fresh))
            self.telemetry.inc("repro_cow_splits_total")
        return fresh

    def attach_shared(self, slot: int, digest: str) -> None:
        """Prefix-share hit: point ``slot``'s cross table at the existing
        page list (refcount++ each) — no encoder run, no copies."""
        pages = self._shared[digest]
        for p in pages:
            self.cross_alloc.retain(p)
        self._slot_cross[slot] = (digest, list(pages))
        self._ct[slot, :] = pages
        self._dirty = True

    def alloc_cross_pages(self, slot: int, digest: str) -> List[int]:
        """First sight of ``digest``: allocate its cross pages and publish
        them for sharing. Raises ``PagesExhausted`` when dry."""
        pages: List[int] = []
        try:
            for _ in range(self.n_cross_per_req):
                pages.append(self.cross_alloc.alloc(
                    prefer=self.slot_shard(slot)))
        except PagesExhausted:
            for p in pages:
                self.cross_alloc.release(p)
            raise
        self._shared[digest] = list(pages)
        self._slot_cross[slot] = (digest, list(pages))
        self._ct[slot, :] = pages
        self._dirty = True
        return pages

    def release(self, slot: int, reset: bool = False) -> None:
        """Evict ``slot``: every page reference returns to its allocator
        BEFORE this call returns, so the same scheduler pass can admit a
        queued request into the freed pages (ISSUE 7 satellite). The
        slot's table rows repoint to the trash page so its garbage decode
        rows stop referencing (and scatter-writing!) memory that may be
        reallocated — synced to device before the next step."""
        del reset                                      # row zeroing is the reset
        for p in self._slot_pages[slot]:
            self.self_alloc.release(p)
        self._slot_pages[slot] = []
        entry = self._slot_cross[slot]
        if entry is not None:
            digest, pages = entry
            for p in pages:
                self.cross_alloc.release(p)
            if self.cross_alloc.refcount[pages[0]] == 0:
                self._shared.pop(digest, None)
            self._slot_cross[slot] = None
        self._bt[slot, :] = 0
        self._ct[slot, :] = 0
        self._dirty = True
        self._slots.release(slot)

    def trim_self_pages(self, slot: int, n_keep: int) -> int:
        """Release ``slot``'s self pages past logical index ``n_keep - 1``
        — the paged half of the speculative rollback (DESIGN.md §17.4).
        A rejected verify suffix may have crossed into pages the pre-round
        capacity pass allocated; after the splice rewinds ``length``, any
        page whose first position ``lp * page_size`` is at or past the
        spliced length holds only dead entries, so it returns to the
        allocator here (trash-pointing the table row like ``release``).
        Shared (aliased) pages just drop a refcount. Returns the number of
        references released."""
        dropped = self._slot_pages[slot][n_keep:]
        if not dropped:
            return 0
        del self._slot_pages[slot][n_keep:]
        for p in dropped:
            self.self_alloc.release(p)
        self._bt[slot, n_keep:] = 0
        self._dirty = True
        return len(dropped)

    # -- device sync ----------------------------------------------------
    def sync(self) -> None:
        """Upload the host block tables when dirty — called once before
        each decode step, so any number of admissions/evictions between
        steps costs at most one table upload."""
        if not self._dirty:
            return
        bt, ct = jnp.asarray(self._bt), jnp.asarray(self._ct)
        if self._table_shardings is not None:
            bt = jax.device_put(bt, self._table_shardings[0])
            ct = jax.device_put(ct, self._table_shardings[1])
        ls = self.state.layer_states._replace(block_table=bt, cross_table=ct)
        self.state = ServeState(ls, self.state.step)
        self._dirty = False

    def insert(self, slot: int, req_state: ServeState,
               write_cross: bool = True) -> None:
        """Splice a batch-1 contiguous prefill/replay state into the
        arenas at ``slot``'s allocated pages (jitted; sharded pools keep
        their sharding via pinned out_shardings)."""
        self.state = self._insert_jit(
            self.state, slot, jnp.asarray(self._bt[slot]),
            jnp.asarray(self._ct[slot]), req_state, write_cross=write_cross)

    def attach_reset(self, slot: int) -> None:
        """Device-side half of a share-hit admission: zero the slot's
        counters (its tables were set on the host)."""
        self.state = self._attach_jit(self.state, slot)

    # -- memory accounting (DESIGN.md §15.4) ----------------------------
    def committed_kv_bytes(self) -> int:
        return model_lib.state_kv_bytes(self.state)

    def used_kv_bytes(self, lengths=None) -> int:
        """Allocated pages x page bytes — exact by construction (the
        contiguous pool's length-proportional estimate becomes a count of
        real allocations here). ``lengths`` accepted for interface parity
        with ``SlotKVPool`` and ignored."""
        del lengths
        return (self.self_alloc.n_allocated * self.page_bytes
                + self.cross_alloc.n_allocated * self.cross_page_bytes)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------
@dataclass
class _PreemptedRequest(_QueuedRequest):
    """A preempted request back at the head of the queue: carries its
    already-streamed tokens for the deterministic replay, and the wall
    time already attributed to it (PDP attribution survives preemption
    exact-by-steps-lived, DESIGN.md §11.3)."""
    tokens: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    # lifecycle carry-through (DESIGN.md §16.1): queue wait accumulates
    # across preemption rounds (requeue_t is the wait base for THIS round;
    # submit_t stays the original submit for TTFT), TTFT survives as-is
    queue_wait_s: float = 0.0
    ttft_s: float = 0.0
    requeue_t: float = 0.0


class PagedScheduler(ContinuousBatchingScheduler):
    """Continuous batching over a ``PagedKVPool`` (DESIGN.md §15.5).

    Inherits the whole decode/evict/attribution loop — the jitted step is
    the engine's same ``step_fn`` at pool width, just traced over the
    paged state (its plan key carries the page geometry, so paged and
    contiguous programs never share ``PlanCache`` entries). What changes:

      admission  gates on free PAGES, not free slots: a logical slot is
                 admitted only when its first self page plus (on a prefix
                 miss) its cross pages fit the arenas. A prefix HIT skips
                 the encoder entirely and attaches the shared pages.
      pre-step   slots crossing a page boundary get their next page
                 allocated (CoW-splitting shared pages first); exhaustion
                 preempts the active slot losing the fewest pages —
                 requeued at the FRONT with its tokens for replay.
      evict      pages return to the allocators before the next admit
                 pass, so an EOS mid-burst immediately admits the queue
                 head (regression-tested).
    """

    def __init__(self, engine, n_slots: int = 4,
                 n_frames: Optional[int] = None, *, page_size: int = 8,
                 n_pages: Optional[int] = None,
                 cross_page_size: Optional[int] = None,
                 n_cross_pages: Optional[int] = None):
        self._page_cfg = dict(page_size=page_size, n_pages=n_pages,
                              cross_page_size=cross_page_size,
                              n_cross_pages=n_cross_pages)
        super().__init__(engine, n_slots=n_slots, n_frames=n_frames)
        self.pool.telemetry = self.telemetry
        self._kv_gauge_state = None
        self.preemptions = 0
        self.shared_hits = 0
        # padded payloads of in-flight requests, kept for the replay a
        # preemption may later need; dropped when the request finishes
        self._payloads: Dict[int, np.ndarray] = {}

    def _make_pool(self):
        eng = self.engine
        return PagedKVPool(eng.cfg, eng._serve_params, self.n_slots,
                           eng.max_len, n_frames=self.n_frames,
                           mesh=eng.mesh, **self._page_cfg)

    # -- plan key (page geometry appended, DESIGN.md §15.5) -------------
    def _ensure_step_plan(self) -> None:
        if self._step_plan_ready:
            return
        eng = self.engine
        key = eng._key("step", self.n_slots, self.n_frames,
                       pages=self.pool.plan_geometry)
        token = jnp.zeros((self.n_slots, 1), jnp.int32)
        self._step_plan = eng._plan(key, eng._decode_fn, eng._serve_params,
                                    token, self.pool.state)
        self._step_plan_ready = True

    # -- admission ------------------------------------------------------
    def admit(self) -> List[int]:
        admitted = []
        eng = self.engine
        pool = self.pool
        tele = self.telemetry
        while self.queue and pool.n_free:
            req = self.queue[0]
            digest = _mel_digest(req.payload)
            replay = isinstance(req, _PreemptedRequest)
            ntok = len(req.tokens) if replay else 0
            need_self = min(ntok // pool.page_size + 1, pool.max_pages)
            shared = pool.has_shared(digest)
            need_cross = 0 if shared else pool.n_cross_per_req
            if not pool.can_alloc(need_self, need_cross):
                if not self._active:
                    raise RuntimeError(
                        f"arena too small: request {req.rid} needs "
                        f"{need_self} self + {need_cross} cross pages with "
                        f"nothing left to preempt "
                        f"(free: {pool.self_alloc.n_free}/"
                        f"{pool.cross_alloc.n_free})")
                break                                  # wait for evictions
            self.queue.popleft()
            # queue wait accumulates across preemption rounds: a replayed
            # request's base is its requeue time, not the original submit
            wait_base = req.requeue_t if replay else req.submit_t
            queue_wait = (req.queue_wait_s if replay else 0.0) + (
                time.perf_counter() - wait_base if wait_base else 0.0)
            if tele is not None:
                tele.end(req.rid, "queued", wait_s=queue_wait)
                tele.observe("repro_queue_wait_seconds", queue_wait)
            slot = pool.acquire()
            if shared and not replay:
                # prefix hit: no encoder, no prefill — attach the shared
                # cross pages and zero the slot's counters. No ledger
                # commit either: no GEMM ran, so attributing plan work
                # here would break the PDP invariant. The ledger span's
                # zero FLOP delta is the checkable form of that claim.
                self.shared_hits += 1
                if tele is not None:
                    tele.instant("prefix_hit", rid=req.rid)
                    tele.inc("repro_prefix_hits_total")
                with obs.maybe_span(tele, "attach", cat="lifecycle",
                                    track=obs.request_track(req.rid),
                                    rid=req.rid, ledger=True):
                    t0 = time.perf_counter()
                    pool.attach_shared(slot, digest)
                    for _ in range(need_self):
                        pool.alloc_self_page(slot)
                    pool.attach_reset(slot)
                    prefill_s = time.perf_counter() - t0
                    self._busy_s += prefill_s
                first = req.sot_id
                active = _ActiveSlot(rid=req.rid, max_new=req.max_new,
                                     prefill_s=prefill_s,
                                     submit_t=req.submit_t,
                                     queue_wait_s=queue_wait)
            else:
                payload = jnp.asarray(req.payload)
                key = eng._key("prefill", 1, self.n_frames)
                plan = eng._plan(key, eng._prefill_fn, eng._serve_params,
                                 payload)
                with obs.maybe_span(tele, "prefill", cat="lifecycle",
                                    track=obs.request_track(req.rid),
                                    rid=req.rid, ledger=True):
                    t0 = time.perf_counter()
                    out, state = eng._prefill_jit(eng._serve_params, payload)
                    jax.block_until_ready(out)
                    prefill_s = time.perf_counter() - t0
                    self._busy_s += prefill_s
                    if eng.offload is not None:
                        eng.offload.ledger.commit(plan, times=1)
                if tele is not None:
                    tele.observe("repro_prefill_seconds", prefill_s)
                if shared:
                    pool.attach_shared(slot, digest)
                else:
                    pool.alloc_cross_pages(slot, digest)
                for _ in range(need_self):
                    pool.alloc_self_page(slot)
                decode_s = 0.0
                if replay and req.tokens:
                    state, decode_s = self._replay(state, req)
                pool.insert(slot, state, write_cross=not shared)
                first = (req.tokens[-1] if replay and req.tokens
                         else req.sot_id)
                active = _ActiveSlot(
                    rid=req.rid, max_new=req.max_new,
                    tokens=list(req.tokens) if replay else [],
                    steps=ntok,
                    prefill_s=prefill_s + (req.prefill_s if replay else 0.0),
                    decode_s=decode_s + (req.decode_s if replay else 0.0),
                    submit_t=req.submit_t,
                    queue_wait_s=queue_wait,
                    ttft_s=req.ttft_s if replay else 0.0)
            if tele is not None:
                tele.begin(req.rid, "decode")
            self._tokens = self._tokens.at[slot, 0].set(int(first))
            self._active[slot] = active
            admitted.append(req.rid)
        if admitted:
            self._note_kv_usage()
        return admitted

    def _replay(self, state: ServeState, req: _PreemptedRequest):
        """Preempt-and-recompute (DESIGN.md §15.5): rebuild the evicted
        request's self-KV by feeding its SOT + all-but-last streamed
        tokens through the batch-1 contiguous decode. Greedy decode is
        deterministic, so the rebuilt state continues token-exactly; the
        replay's wall time and its per-step plan commits land on THIS
        request, keeping PDP attribution exact-by-steps-lived."""
        eng = self.engine
        tele = self.telemetry
        inputs = [req.sot_id] + req.tokens[:-1]
        tok0 = jnp.full((1, 1), inputs[0], jnp.int32)
        plan = eng._plan(eng._key("step", 1, self.n_frames),
                         eng._decode_fn, eng._serve_params, tok0, state)
        with obs.maybe_span(tele, "replay", cat="lifecycle",
                            track=obs.request_track(req.rid), rid=req.rid,
                            ledger=True, args={"tokens": len(inputs)}):
            t0 = time.perf_counter()
            for t in inputs:
                _, state = eng._decode_jit(eng._serve_params,
                                           jnp.full((1, 1), t, jnp.int32),
                                           state)
            state = jax.block_until_ready(state)
            replay_s = time.perf_counter() - t0
            self._busy_s += replay_s
            if eng.offload is not None:
                eng.offload.ledger.commit(plan, times=len(inputs))
        if tele is not None:
            tele.instant("replay", rid=req.rid, tokens=len(inputs))
            tele.inc("repro_replays_total")
            tele.observe("repro_replay_seconds", replay_s)
        return state, replay_s

    # -- pre-step capacity pass (DESIGN.md §15.5) -----------------------
    def _pick_victim(self) -> int:
        """Preemption victim: the active slot losing the fewest pages
        (least recompute work thrown away), ties -> lowest slot."""
        return min(self._active,
                   key=lambda s: (len(self.pool._slot_pages[s]), s))

    def _preempt(self, slot: int) -> None:
        a = self._active.pop(slot)
        self.preemptions += 1
        tele = self.telemetry
        if tele is not None:
            tele.instant("preempt", rid=a.rid)
            tele.inc("repro_preemptions_total")
            tele.end(a.rid, "decode", preempted=True, steps=a.steps)
            tele.begin(a.rid, "queued")
        # FRONT of the queue: a preempted request outranks every waiter
        # (it already holds streamed-token obligations)
        # payload stays in _payloads: the request may be preempted again
        self.queue.appendleft(_PreemptedRequest(
            rid=a.rid, payload=self._payloads[a.rid], max_new=a.max_new,
            submit_t=a.submit_t, tokens=list(a.tokens),
            prefill_s=a.prefill_s, decode_s=a.decode_s,
            queue_wait_s=a.queue_wait_s, ttft_s=a.ttft_s,
            requeue_t=time.perf_counter()))
        self.pool.release(slot)

    def submit(self, payload, max_new: int = 32, sot_id: int = 1) -> int:
        rid = super().submit(payload, max_new=max_new, sot_id=sot_id)
        if self.queue and self.queue[-1].rid == rid:
            # keep the padded payload for preempt-and-recompute
            self._payloads[rid] = self.queue[-1].payload
        return rid

    def _page_capacity_pass(self, w: int = 1) -> None:
        """Ensure every active slot owns private pages for the next ``w``
        write positions (``w == 1`` is the plain decode step; ``w == k+1``
        is a speculative round's verify window, which may straddle a page
        boundary — the crossing page allocates here, CoW-first, same as
        the single-step path). Exhaustion preempts the victim losing the
        fewest pages until the remaining actives fit."""
        pool = self.pool
        for slot in sorted(self._active):
            if slot not in self._active:
                continue                               # preempted below
            a = self._active[slot]
            lp0 = a.steps // pool.page_size            # first page written
            lp1 = min((a.steps + w - 1) // pool.page_size,
                      pool.max_pages - 1)              # writes clamp past cap
            for lp in range(lp0, lp1 + 1):
                while slot in self._active:
                    try:
                        if len(pool._slot_pages[slot]) <= lp:
                            pool.alloc_self_page(slot)
                            continue
                        pool.ensure_private(slot, lp)  # CoW before the write
                        break
                    except PagesExhausted:
                        self._preempt(self._pick_victim())
                if slot not in self._active:
                    break

    def decode_step(self):
        if not self._active:
            return []
        self._page_capacity_pass()
        self.pool.sync()
        events = super().decode_step()
        for ev in events:
            if ev.done:                   # finished: replay no longer possible
                self._payloads.pop(ev.rid, None)
        tele = self.telemetry
        if tele is not None:
            pool = self.pool
            g = (pool.self_alloc.n_free, pool.cross_alloc.n_free,
                 pool.self_alloc.n_allocated, pool.cross_alloc.n_allocated,
                 int(np.count_nonzero(pool.self_alloc.refcount > 1)),
                 int(np.count_nonzero(pool.cross_alloc.refcount > 1)))
            if g != self._kv_gauge_state:  # page counts move on admit/
                self._kv_gauge_state = g   # evict, not every step
                tele.gauge("repro_kv_pages_free", g[0], kind="self")
                tele.gauge("repro_kv_pages_free", g[1], kind="cross")
                tele.gauge("repro_kv_pages_used", g[2], kind="self")
                tele.gauge("repro_kv_pages_used", g[3], kind="cross")
                tele.gauge("repro_kv_pages_shared", g[4], kind="self")
                tele.gauge("repro_kv_pages_shared", g[5], kind="cross")
        return events
