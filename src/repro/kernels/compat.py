"""Pallas-TPU API shims across jax versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
jax releases; the kernels target the new name and this shim keeps them
running on the older toolchain baked into CI containers.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
