"""Pallas TPU kernel: decode-path Q8_0 matvec (the paper's per-token
dot-product: M=1 activations against a quantized weight matrix).

Decode is the regime the paper profiles hardest (the decoder dominates
invocation counts) and on TPU it is *memory-bound*: arithmetic intensity of a
(B<=8, K) x (N, K) contraction is ~B FLOPs/byte, far below the 240 FLOP/byte
v5e ridge. The kernel therefore optimizes HBM bytes, not MXU utilization:

* weights stream as int8 + scales (the Q8_0 2x cut — the paper's point),
* the activation tile is loaded once and kept VMEM-resident across the whole
  N sweep (grid iterates N only; K is a single block),
* the batch dim pads to the 8-sublane minimum in the ops wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.qformats import QBLOCK

DEFAULT_BLOCK_N = 512


def vmem_claim_bytes(b: int = 8, k: int = 384,
                     block_n: int = DEFAULT_BLOCK_N,
                     x_bytes: int = 2) -> int:
    """VMEM working set of one grid step (autotuner input, DESIGN.md §9.1):
    the whole (B, K) activation stays resident across the N sweep; the int8
    payload + scales tiles double-buffer; the out tile is written per step."""
    db = 2
    return (b * k * x_bytes                          # resident activation
            + db * (block_n * k                      # int8 payload tile
                    + block_n * (k // QBLOCK) * 4)   # scales tile
            + b * block_n * 4)                       # out tile


def _q8_matvec_kernel(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                  # (B, K) resident
    q = q_ref[...]                                      # (bn, K) int8
    s = s_ref[...]                                      # (bn, K//32)
    bn, k = q.shape
    w = q.astype(jnp.float32).reshape(bn, k // QBLOCK, QBLOCK) * s[..., None]
    w = w.reshape(bn, k)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def q8_matvec(x: jax.Array, qs: jax.Array, scales: jax.Array, *,
              block_n: int = DEFAULT_BLOCK_N,
              interpret: bool = False) -> jax.Array:
    """x (B, K) x Q8_0 W (N, K) -> (B, N) f32; B small (decode batch tile)."""
    b, k = x.shape
    n, k2 = qs.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError(f"N={n} not tiled by block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _q8_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),            # resident
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),      # streamed
            pl.BlockSpec((block_n, k // QBLOCK), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x, qs, scales)
