"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These definitions are the single source of truth for kernel semantics; the
Pallas kernels and the XLA fallback paths are tested allclose against them.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.qformats import QTensor


def matmul_f32_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y[M,N] = x[M,K] @ w[N,K]^T, f32 accumulation."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)


def matmul_bf16_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """The paper's FP16 kernel semantics: 16-bit operands, inline-converted,
    fp32 accumulated (IMAX ALU2 conversion + SIMD FMA -> MXU bf16xbf16->f32)."""
    return jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16).T,
                   preferred_element_type=jnp.float32)


def q8_matmul_ref(x: jnp.ndarray, wq: QTensor) -> jnp.ndarray:
    """The paper's Q8_0 kernel semantics: per-32-block dequant then f32 MAC.
    x: (M, K); wq: QTensor over W[N, K]. Returns (M, N) f32."""
    w = wq.qs.astype(jnp.float32) * wq.scales[..., None]       # (N, K/32, 32)
    w = w.reshape(wq.shape)                                     # (N, K)
    return jnp.dot(x.astype(jnp.float32), w.T,
                   preferred_element_type=jnp.float32)


def q8_matvec_ref(x: jnp.ndarray, wq: QTensor) -> jnp.ndarray:
    """Decode-path dot product: x (B, K) against quantized W[N, K]."""
    return q8_matmul_ref(x, wq)
