"""Pallas TPU kernel: 16-bit matmul with f32 accumulation (the paper's FP16
dot-product kernel, §3.2 Fig 5, re-tiled for the MXU).

IMAX converts FP16->FP32 inline on ALU2 and runs 2-way SIMD FMA on a 64-bit
datapath; the MXU does the same job natively on bf16 operands with an f32
accumulator tree (``preferred_element_type=f32``). The tiling mirrors
q8_matmul so the burst (block_k) sweep applies to both paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 256


def vmem_claim_bytes(block_m: int = DEFAULT_BLOCK_M,
                     block_n: int = DEFAULT_BLOCK_N,
                     block_k: int = DEFAULT_BLOCK_K,
                     x_bytes: int = 2) -> int:
    """VMEM working set of one grid step (the LMM-sizing analog used by the
    autotuner, DESIGN.md §9.1): double-buffered bf16 x/w tiles + f32
    accumulator scratch + out tile."""
    db = 2  # pallas pipeline double-buffers inputs
    return (db * (block_m * block_k * x_bytes       # x tile
                  + block_n * block_k * 2)          # bf16 weight tile
            + block_m * block_n * 4                 # accumulator scratch
            + block_m * block_n * 4)                # out tile


def _bf16_matmul_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Inline 16->32 conversion happens in the MXU datapath: bf16 operands,
    # f32 accumulation (the IMAX ALU2 analog; DESIGN.md §2).
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.bfloat16), w_ref[...].astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def bf16_matmul(x: jax.Array, w: jax.Array, *,
                block_m: int = DEFAULT_BLOCK_M,
                block_n: int = DEFAULT_BLOCK_N,
                block_k: int = DEFAULT_BLOCK_K,
                interpret: bool = False) -> jax.Array:
    """x (M,K) @ w (N,K)^T -> (M,N) f32. Exact tiling required; ragged sizes
    go through core.mixed_exec."""
    m, k = x.shape
    n, k2 = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"({m},{n},{k}) not tiled by "
                         f"({block_m},{block_n},{block_k})")
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _bf16_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, w)
