"""Pallas TPU kernel: Q8_0 block-dequant matmul (the paper's Q8_0 dot-product
kernel, §3.2 Fig 6, re-tiled for the MXU).

IMAX streams 34-byte Q8_0 blocks through a 46-PE lane with packed int8 MACs
(OP_SML8) and pipeline adds (OP_AD32). The TPU-native mapping (DESIGN.md §2):

* HBM traffic stays int8 + per-block scales — the 2x footprint cut is the
  whole point of the paper's Q8_0 path and directly halves the *memory*
  roofline term for decode.
* Dequantization happens inside VMEM (the LMM analog) right before the MXU
  contraction, like IMAX's inline dequant on ALU3 — no dedicated conversion
  pass, no dequantized weights ever resident in HBM.
* The grid pipelines HBM->VMEM copies against compute (the LMM's
  hardware-managed double buffering).
* ``block_k`` is the burst-length analog; it must divide by 32 (whole Q8_0
  blocks per burst — the paper picks bursts holding whole packed words).

Layouts:
  x:      (M, K)   bf16/f32 activations
  qs:     (N, K)   int8   (Q8_0 payload, blocks flattened)
  scales: (N, K//32) f32  (fp16-valued)
  out:    (M, N)   f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.qformats import QBLOCK

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 256   # burst analog; VMEM claim scales with it


def _q8_matmul_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc += x_tile @ dequant(q_tile, s_tile)^T."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                  # (bm, bk)
    q = q_ref[...]                                      # (bn, bk) int8
    s = s_ref[...]                                      # (bn, bk//32) f32
    bn, bk = q.shape
    # In-VMEM block dequant: expand each per-32 scale across its block.
    w = q.astype(jnp.float32).reshape(bn, bk // QBLOCK, QBLOCK) * s[..., None]
    w = w.reshape(bn, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def q8_matmul(x: jax.Array, qs: jax.Array, scales: jax.Array, *,
              block_m: int = DEFAULT_BLOCK_M,
              block_n: int = DEFAULT_BLOCK_N,
              block_k: int = DEFAULT_BLOCK_K,
              interpret: bool = False) -> jax.Array:
    """x (M,K) x Q8_0 W (N,K) -> (M,N) f32. Shapes must tile exactly —
    callers route ragged sizes through core.mixed_exec (the paper's
    main/residual split), so the kernel never sees a partial burst."""
    m, k = x.shape
    n, k2 = qs.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    if block_k % QBLOCK:
        raise ValueError("block_k must hold whole Q8_0 blocks")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"({m},{n},{k}) not tiled by "
                         f"({block_m},{block_n},{block_k})")
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _q8_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, block_k // QBLOCK), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, qs, scales)


def vmem_claim_bytes(block_m: int = DEFAULT_BLOCK_M,
                     block_n: int = DEFAULT_BLOCK_N,
                     block_k: int = DEFAULT_BLOCK_K,
                     x_bytes: int = 2) -> int:
    """The VMEM working set this tiling claims (the LMM-sizing analog):
    double-buffered x/q/s tiles + f32 accumulator + out tile."""
    db = 2  # pallas pipeline double-buffers inputs
    return (db * (block_m * block_k * x_bytes            # x tile
                  + block_n * block_k                    # int8 payload
                  + block_n * (block_k // QBLOCK) * 4)   # scales
            + block_m * block_n * 4                      # accumulator
            + block_m * block_n * 4)                     # out tile
