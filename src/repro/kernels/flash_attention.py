"""Pallas TPU kernel: flash-2 attention forward (the beyond-paper §Perf
optimization, as a VMEM-tiled kernel rather than an XLA-level scan).

models/attention.py's ``_flash_attention`` expresses the k-blocked online
softmax at the jnp level so the 512-device dry-run can lower it on CPU;
THIS kernel is what the schedule compiles to on a real TPU: q tiles stay
VMEM-resident across the k sweep (the LMM-residency idea from the paper's
double-buffered operand streaming), k/v tiles stream HBM->VMEM through the
pallas pipeline, and the (block_q, block_k) score tile never touches HBM.

Grid: (batch*heads, Sq/block_q, Sk/block_k) with the k axis innermost
("arbitrary") carrying running (m, l, acc) in VMEM scratch.

Layouts:  q (BH, Sq, D) | k,v (BH, Sk, D) -> out (BH, Sq, D) f32.
The ops wrapper folds (B, H) and handles GQA head repetition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, scale, causal,
                      block_q, block_k):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                      # (bq, d)
    k = k_ref[0]                                      # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = kk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _store():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jax.Array:
    """q (BH, Sq, D) x k,v (BH, Sk, D) -> (BH, Sq, D) f32.

    Exact tiling required (ragged sizes go through the jnp path — the same
    main/residual contract as the matmul kernels)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"(Sq={sq}, Sk={sk}) not tiled by "
                         f"({block_q}, {block_k})")
    grid = (bh, sq // block_q, sk // block_k)
    kernel = functools.partial(_flash_fwd_kernel, scale=d ** -0.5,
                               causal=causal, block_q=block_q,
                               block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max
            pltpu.VMEM((block_q, 1), jnp.float32),     # running denom
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)


def vmem_claim_bytes(block_q: int = DEFAULT_BLOCK_Q,
                     block_k: int = DEFAULT_BLOCK_K,
                     d: int = 128, in_bytes: int = 2) -> int:
    """VMEM working set (the LMM-sizing analog): double-buffered q/k/v
    tiles + f32 stats/acc scratch + out tile."""
    db = 2
    return (db * (block_q * d * in_bytes + 2 * block_k * d * in_bytes)
            + block_q * (2 + d) * 4        # m, l, acc scratch
            + block_q * d * 4)             # out tile
