"""Public jit'd wrappers over the Pallas kernels — now a thin shim over the
backend registry (DESIGN.md §12).

.. deprecated:: kept for API compatibility. ``matmul`` used to select the
   execution path inline (TPU-vs-interpret, quantized-vs-dense if-ladders);
   that selection now lives in ``repro.backends``: every segment of the
   mixed-execution split becomes a ``KernelRequest`` and
   ``registry.dispatch`` picks the backend (pallas_tpu / xla_ref /
   host_residual). This module only translates the legacy
   ``prefer_pallas`` tri-state into a registry pin. New code should call
   ``repro.backends.executor.matmul`` (or better, route through
   ``core.offload.OffloadEngine`` so planning and accounting apply).

Path selection (DESIGN.md §6.3, now §12.2 capability resolution): on TPU
the Pallas kernels run natively; on this CPU container they run in
``interpret=True`` for correctness tests, and the model/dry-run path uses
the XLA implementation of the *same* dequant math (``ref.py`` semantics).
``matmul`` remains the single entry point the model zoo calls; it handles
leading batch dims, the mixed-execution split, and the sublane padding for
skinny decode batches — all via the executor.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax

# submodule imports (not the package) so this shim stays importable while
# repro.backends' own __init__ is mid-flight (it imports the kernels)
from repro.backends import executor
from repro.backends.registry import pin_for_prefer
from repro.core.qformats import QTensor

Weight = Union[jax.Array, QTensor]


def matmul(x: jax.Array, w: Weight, *,
           burst: int = 256,
           prefer_pallas: Optional[bool] = None,
           interpret: Optional[bool] = None,
           block_k: int = 256,
           tuner=None,
           tiling: Optional[Tuple[int, int, int]] = None) -> jax.Array:
    """y = x @ W^T for dense or Q8_0 weights, via the paper's mixed-execution
    split. x: (..., K); W: (N, K) array or QTensor. Returns (..., N) f32.

    prefer_pallas=None -> registry capability resolution (pallas on TPU,
    XLA elsewhere — dry-run lowers XLA); True/False pin the pallas_tpu /
    xla_ref backend (DESIGN.md §12.2). ``tiling`` pins the main-segment
    tile shapes to a trace-time plan entry's resolution (DESIGN.md §10.1)
    — with it this function is a pure function of its arguments, no cache
    lookups at execution. ``tuner`` (a tuning.Autotuner) instead resolves
    tiles via cached winners at call time; ``burst``/``block_k`` remain
    the untuned fallbacks.
    """
    return executor.matmul(x, w, burst=burst,
                           backend=pin_for_prefer(prefer_pallas),
                           interpret=interpret, block_k=block_k,
                           tuner=tuner, tiling=tiling)
