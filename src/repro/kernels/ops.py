"""Public jit'd wrappers over the Pallas kernels.

Path selection (DESIGN.md §6.3): on TPU the Pallas kernels run natively; on
this CPU container they run in ``interpret=True`` for correctness tests, and
the model/dry-run path uses the XLA implementation of the *same* dequant
math (``ref.py`` semantics). ``matmul`` is the single entry point the model
zoo calls; it handles leading batch dims, the mixed-execution split, and the
sublane padding for skinny decode batches.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixed_exec import mixed_matmul, mixed_matmul_q8
from repro.core.qformats import QBLOCK, QTensor
from repro.kernels import ref
from repro.kernels.bf16_matmul import bf16_matmul
from repro.kernels.q8_matmul import q8_matmul
from repro.kernels.q8_matvec import q8_matvec

Weight = Union[jax.Array, QTensor]

_SUBLANE = 8  # f32 min sublane tile on TPU


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten_leading(x: jax.Array):
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    return x.reshape(m, x.shape[-1]), lead


def _pad_m(x: jax.Array, mult: int = _SUBLANE):
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def _tuned(tuner, kernel: str, m: int, n: int, k: int, dtype: str):
    """Winning tiling for the *main-segment* shape, or None (tuner absent or
    nothing admissible under its VMEM budget)."""
    if tuner is None:
        return None
    return tuner.best_tiling(kernel, m, n, k, dtype)


def _pallas_q8_main(x2d: jax.Array, wq: QTensor, interpret: bool,
                    block_k: int, tuner=None, tiling=None) -> jax.Array:
    """Aligned-segment Q8_0 path: matvec variant for skinny M, tiled matmul
    otherwise. Handles M/N padding so the kernel only sees full tiles.
    Tile shapes come (in precedence order) from an explicit ``tiling`` — a
    trace-time plan entry's resolved ``(block_m, block_n, block_k)``
    (DESIGN.md §10.1) — else a tuner-cache lookup (DESIGN.md §9.4), else
    the module-level defaults."""
    qs2d = wq.flat_qs()
    n, k = qs2d.shape
    xp, m = _pad_m(x2d)
    mp = xp.shape[0]
    if mp <= 2 * _SUBLANE:
        rec = tiling or _tuned(tuner, "q8_matvec", mp, n, k, "q8_0")
        # decode: N tiled at 512 when divisible, else largest divisor tile
        bn = _block_shape(rec)[1] if rec else _largest_tile(n, 512)
        out = q8_matvec(xp, qs2d, wq.scales, block_n=bn, interpret=interpret)
    else:
        rec = tiling or _tuned(tuner, "q8_matmul", mp, n, k, "q8_0")
        if rec:
            bm, bn, bk = _block_shape(rec)
        else:
            bm = _largest_tile(mp, 128)
            bn = _largest_tile(n, 256)
            bk = _largest_tile(k, block_k, mult=QBLOCK)
        out = q8_matmul(xp, qs2d, wq.scales, block_m=bm, block_n=bn,
                        block_k=bk, interpret=interpret)
    return out[:m]


def _pallas_bf16_main(x2d: jax.Array, w: jax.Array, interpret: bool,
                      block_k: int, tuner=None, tiling=None) -> jax.Array:
    xp, m = _pad_m(x2d)
    mp = xp.shape[0]
    n, k = w.shape
    rec = tiling or _tuned(tuner, "bf16_matmul", mp, n, k, "bf16")
    if rec:
        bm, bn, bk = _block_shape(rec)
    else:
        bm = _largest_tile(mp, 128)
        bn = _largest_tile(n, 256)
        bk = _largest_tile(k, block_k)
    return bf16_matmul(xp, w, block_m=bm, block_n=bn, block_k=bk,
                       interpret=interpret)[:m]


def _block_shape(rec) -> Tuple[int, int, int]:
    """Normalize a tiling source — TuningRecord or plan-entry tuple."""
    if isinstance(rec, tuple):
        return rec
    return rec.block_m, rec.block_n, rec.block_k


def _largest_tile(dim: int, cap: int, mult: int = 1) -> int:
    """Largest t <= cap with t % mult == 0 and dim % t == 0."""
    t = min(cap, dim)
    while t > 1 and (dim % t or (mult > 1 and t % mult)):
        t -= mult if mult > 1 and t % mult == 0 else 1
    return max(t, 1)


def matmul(x: jax.Array, w: Weight, *,
           burst: int = 256,
           prefer_pallas: Optional[bool] = None,
           interpret: Optional[bool] = None,
           block_k: int = 256,
           tuner=None,
           tiling: Optional[Tuple[int, int, int]] = None) -> jax.Array:
    """y = x @ W^T for dense or Q8_0 weights, via the paper's mixed-execution
    split. x: (..., K); W: (N, K) array or QTensor. Returns (..., N) f32.

    prefer_pallas=None -> pallas on TPU, XLA elsewhere (dry-run lowers XLA).
    ``tiling`` pins the main-segment tile shapes to a trace-time plan
    entry's resolution (DESIGN.md §10.1) — with it this function is a pure
    function of its arguments, no cache lookups at execution. ``tuner``
    (a tuning.Autotuner) instead resolves tiles via cached winners at call
    time; ``burst``/``block_k`` remain the untuned fallbacks.
    """
    if prefer_pallas is None:
        prefer_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    x2d, lead = _flatten_leading(x)

    if isinstance(w, QTensor):
        if prefer_pallas:
            main = functools.partial(_pallas_q8_main, interpret=interpret,
                                     block_k=block_k, tuner=tuner,
                                     tiling=tiling)
            out = mixed_matmul_q8(x2d, w, burst, main)
        else:
            out = mixed_matmul_q8(x2d, w, burst, ref.q8_matmul_ref)
    else:
        if prefer_pallas:
            main = functools.partial(_pallas_bf16_main, interpret=interpret,
                                     block_k=block_k, tuner=tuner,
                                     tiling=tiling)
            out = mixed_matmul(x2d, w, burst, main)
        else:
            out = mixed_matmul(x2d, w, burst, ref.matmul_bf16_ref)
    return out.reshape(*lead, out.shape[-1])
