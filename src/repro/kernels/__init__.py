"""Pallas TPU kernels for the paper's compute hot-spots (the dot-product
kernels it offloads): q8_matmul, bf16_matmul, q8_matvec + jit wrappers (ops)
and pure-jnp oracles (ref)."""
from repro.kernels.ops import matmul  # noqa: F401
from repro.kernels.bf16_matmul import bf16_matmul  # noqa: F401
from repro.kernels.q8_matmul import q8_matmul, vmem_claim_bytes  # noqa: F401
from repro.kernels.q8_matvec import q8_matvec  # noqa: F401
