"""Trace and metrics exporters (DESIGN.md §16.4).

``trace_events`` renders a ``Tracer`` to the Chrome/Perfetto
``trace_event`` JSON object format: complete ``"X"`` events for closed
spans, ``"i"`` instants, ``"M"`` metadata naming the tracks (track 0 =
"engine", track 1+rid = "req<rid>"), all sorted by timestamp so the file
satisfies the monotonicity check in tools/check_trace.py. Still-open
spans (a live serve loop exporting mid-flight) are emitted as ``"B"``
begin events without a matching ``"E"`` — deliberately: the validator
flags them, which is exactly the closed-lifecycle gate CI wants to trip
on a scheduler that leaked a request.

Load the output at https://ui.perfetto.dev (or chrome://tracing) — the
README's "Observability" walkthrough shows what to expect.

``write_metrics`` drops a ``MetricsRegistry`` as Prometheus text
exposition; ``write_snapshot`` as JSON. All writers are atomic
(tmp + ``os.replace``), the same discipline as benchmarks/common.save.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List

from repro.obs.trace import ENGINE_TRACK, Tracer

PID = 1  # single-process serving loop: one pid, tracks are "threads"


def trace_events(tracer: Tracer) -> Dict[str, Any]:
    """The ``trace_event`` JSON object for ``tracer``'s recorded state."""
    events: List[Dict[str, Any]] = []
    tracks = {ENGINE_TRACK}
    for sp in tracer.spans:
        tracks.add(sp.track)
        events.append({"name": sp.name, "cat": sp.cat, "ph": "X",
                       "ts": round(sp.ts_us, 3),
                       "dur": round(sp.dur_us or 0.0, 3),
                       "pid": PID, "tid": sp.track, "args": sp.args})
    for sp in tracer.events:
        tracks.add(sp.track)
        events.append({"name": sp.name, "cat": sp.cat, "ph": "i",
                       "ts": round(sp.ts_us, 3), "s": "t",
                       "pid": PID, "tid": sp.track, "args": sp.args})
    for sp in tracer.open_phase_spans():
        # open phase: "B" with no "E" -- the validator flags it
        tracks.add(sp.track)
        events.append({"name": sp.name, "cat": sp.cat, "ph": "B",
                       "ts": round(sp.ts_us, 3),
                       "pid": PID, "tid": sp.track, "args": sp.args})
    events.sort(key=lambda e: e["ts"])
    meta = [{"name": "process_name", "ph": "M", "pid": PID, "tid": 0,
             "args": {"name": "repro-serve"}}]
    for track in sorted(tracks):
        label = "engine" if track == ENGINE_TRACK else f"req{track - 1}"
        meta.append({"name": "thread_name", "ph": "M", "pid": PID,
                     "tid": track, "args": {"name": label}})
        meta.append({"name": "thread_sort_index", "ph": "M", "pid": PID,
                     "tid": track, "args": {"sort_index": track}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def _atomic_write(path: str, text: str) -> str:
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".obs.", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def write_trace(tracer: Tracer, path: str) -> str:
    """Write the Perfetto trace JSON; returns ``path``."""
    return _atomic_write(path, json.dumps(trace_events(tracer), indent=1,
                                          default=str))


def write_metrics(registry, path: str) -> str:
    """Write Prometheus text exposition; returns ``path``."""
    return _atomic_write(path, registry.render_prometheus())


def write_snapshot(snapshot: Dict[str, Any], path: str) -> str:
    """Write a ``Telemetry.snapshot()`` dict as JSON; returns ``path``."""
    return _atomic_write(path, json.dumps(snapshot, indent=1, default=str))
