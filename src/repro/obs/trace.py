"""Lightweight span/event tracer for the serving path (DESIGN.md §16.1).

Records *where inside a request* the milliseconds (and, via ledger-delta
spans, the FLOPs feeding PDP/EDP) went, with strictly host-side
bookkeeping — nothing here is ever captured into a jitted program; every
record call happens between jitted steps or at trace time (DESIGN.md
§16.2's zero-overhead-on-the-jitted-path contract).

Two span families share one ``Span`` record:

  stack spans     ``Tracer.span(...)`` context manager — engine/scheduler
                  host work (``decode_step``, ``prefill``, ``replay``,
                  ``plan_build``). Properly nested per track by
                  construction (it is a with-block).
  phase spans     ``begin(rid, name)`` / ``end(rid, name)`` — the
                  per-request lifecycle (``queued`` -> ``prefill``/
                  ``attach`` -> ``decode``, re-entering ``queued`` on
                  preemption). Each request gets its own track, phases
                  are explicit open/close so any admit/evict/preempt
                  interleaving is recordable; ``open_phases()`` after a
                  drain must be empty — the closed-lifecycle invariant
                  benchmarks/telemetry_overhead.py gates.

Instant events (``instant``) mark the paged scheduler's decisions:
``submit``, ``prefix_hit``, ``cow_split``, ``preempt``, ``replay``,
``evict``.

Hot-path representation: record calls append flat tuples to a journal
and ``Span`` objects materialize lazily on first access to ``spans``/
``events`` (cached until the journal grows). The serving benchmarks time
individual ~0.5 ms decode steps, and benchmarks/telemetry_overhead.py
gates recording at ≤3% of one — a dataclass + args-dict + context-layer
construction per record costs several cold-cache µs each, so the hot
path is a clock read and a tuple append, nothing more.

Tracks map to Perfetto threads in the export (obs/export.py): track 0 is
the engine/scheduler host loop, track ``1 + rid`` is request ``rid``.
``check_nesting()`` verifies the containment discipline the validator
(tools/check_trace.py) re-checks on the exported JSON.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

#: track id of the engine/scheduler host loop; requests live at 1 + rid
ENGINE_TRACK = 0


def request_track(rid: int) -> int:
    return 1 + rid


@dataclass
class Span:
    """One recorded interval (or instant, when ``dur_us`` is None and
    ``instant`` is True). ``args`` lands verbatim in the trace_event
    ``args`` dict — ledger deltas (``flops``, ``calls``) live there."""
    name: str
    cat: str
    track: int
    ts_us: float
    dur_us: Optional[float] = None
    rid: Optional[int] = None
    instant: bool = False
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def closed(self) -> bool:
        return self.instant or self.dur_us is not None


class Tracer:
    """Append-only span/event recorder with a monotonic µs clock.

    ``clock`` is injectable (tests drive a virtual clock); timestamps are
    relative to construction so traces start near t=0. The recorder never
    drops or reorders: ``spans`` materializes in *close* order,
    ``events`` in emit order; the exporter sorts by ``ts_us`` (Perfetto
    wants non-decreasing timestamps, checked by tools/check_trace.py).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        # journal records: ("X", name, cat, track, rid, ts, dur, args)
        # for closed spans of either family (appended at close time, so
        # journal order is close order); ("i", name, cat, track, rid,
        # ts, args) for instants
        self._j: List[tuple] = []
        # open lifecycle phases: (rid, name) -> (ts_us, cat, args)
        self._open: Dict[Tuple[int, str], tuple] = {}
        self._depth = 0                      # open stack spans
        self.rids_opened: set = set()
        self.rids_closed: set = set()
        self._mat_n = -1                     # journal length at last mat.
        self._spans: List[Span] = []
        self._events: List[Span] = []

    # -- clock ----------------------------------------------------------
    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    # -- stack spans ----------------------------------------------------
    def span(self, name: str, cat: str = "host", track: int = ENGINE_TRACK,
             rid: Optional[int] = None,
             args: Optional[Dict[str, Any]] = None):
        """Context manager recording one closed interval on ``track``.
        The tracer takes ownership of ``args`` (no defensive copy — this
        is the per-decode-step hot path; pass a fresh dict)."""
        return _SpanCtx(self, name, cat, track, rid,
                        args if args is not None else {})

    # -- lifecycle phases ----------------------------------------------
    def begin(self, rid: int, name: str, cat: str = "lifecycle",
              **args: Any) -> None:
        """Open lifecycle phase ``name`` on request ``rid``'s track.
        Re-opening an already-open (rid, name) phase is a programming
        error — the interleaving property test drives this."""
        key = (rid, name)
        if key in self._open:
            raise RuntimeError(f"phase {name!r} already open for rid {rid}")
        self._open[key] = (self.now_us(), cat, args)
        self.rids_opened.add(rid)

    def end(self, rid: int, name: str, **args: Any) -> None:
        key = (rid, name)
        ent = self._open.pop(key, None)
        if ent is None:
            raise RuntimeError(f"phase {name!r} not open for rid {rid}")
        ts, cat, bargs = ent
        if args:
            bargs.update(args)
        self._j.append(("X", name, cat, 1 + rid, rid, ts,
                        self.now_us() - ts, bargs))
        if not any(k[0] == rid for k in self._open):
            self.rids_closed.add(rid)

    def phase_open(self, rid: int, name: str) -> bool:
        return (rid, name) in self._open

    def open_phases(self) -> List[Tuple[int, str]]:
        """Still-open lifecycle phases — empty after a full drain (the
        closed-lifecycle invariant, DESIGN.md §16.2)."""
        return sorted(self._open)

    def open_phase_spans(self) -> List[Span]:
        """The open phases as (unclosed) ``Span`` records, for the
        exporter's dangling-``"B"`` emission."""
        return [Span(name=name, cat=v[1], track=1 + rid, ts_us=v[0],
                     rid=rid, args=dict(v[2]))
                for (rid, name), v in sorted(self._open.items())]

    def open_stack_depth(self) -> int:
        return self._depth

    # -- instants -------------------------------------------------------
    def instant(self, name: str, cat: str = "sched",
                rid: Optional[int] = None, track: Optional[int] = None,
                **args: Any) -> None:
        if track is None:
            track = ENGINE_TRACK if rid is None else 1 + rid
        self._j.append(("i", name, cat, track, rid, self.now_us(), args))

    # -- lazy materialization ------------------------------------------
    def _materialize(self) -> None:
        if self._mat_n == len(self._j):
            return
        spans: List[Span] = []
        events: List[Span] = []
        for r in self._j:
            if r[0] == "X":
                spans.append(Span(name=r[1], cat=r[2], track=r[3],
                                  ts_us=r[5], dur_us=r[6], rid=r[4],
                                  args=r[7]))
            else:
                events.append(Span(name=r[1], cat=r[2], track=r[3],
                                   ts_us=r[5], rid=r[4], instant=True,
                                   args=r[6]))
        self._spans, self._events, self._mat_n = spans, events, len(self._j)

    @property
    def spans(self) -> List[Span]:
        """Closed spans (both families), in close order."""
        self._materialize()
        return self._spans

    @property
    def events(self) -> List[Span]:
        """Instant events, in emit order."""
        self._materialize()
        return self._events

    # -- invariants -----------------------------------------------------
    def all_closed(self) -> bool:
        return not self._open and self._depth == 0

    def check_nesting(self) -> List[str]:
        """Per-track containment check: any two closed spans on one track
        are either disjoint or one contains the other (the property the
        interleaving test asserts; tools/check_trace.py re-derives it on
        the exported JSON). Returns human-readable violations."""
        errors: List[str] = []
        by_track: Dict[int, List[Span]] = {}
        for sp in self.spans:
            by_track.setdefault(sp.track, []).append(sp)
        for track, spans in sorted(by_track.items()):
            spans = sorted(spans, key=lambda s: (s.ts_us, -(s.dur_us or 0)))
            stack: List[Span] = []
            for sp in spans:
                end = sp.ts_us + (sp.dur_us or 0.0)
                while stack and sp.ts_us >= _end(stack[-1]) - 1e-6:
                    stack.pop()
                if stack and end > _end(stack[-1]) + 1e-6:
                    errors.append(
                        f"track {track}: span {sp.name!r} "
                        f"[{sp.ts_us:.1f}, {end:.1f}] overlaps "
                        f"{stack[-1].name!r} ending {_end(stack[-1]):.1f}")
                stack.append(sp)
        return errors


def _end(sp: Span) -> float:
    return sp.ts_us + (sp.dur_us or 0.0)


class _SpanCtx:
    """The with-block behind ``Tracer.span`` — one clock read on enter,
    one clock read + one journal append on exit (recorded on exit, so a
    span is never left open by an exception either)."""
    __slots__ = ("_tracer", "_name", "_cat", "_track", "_rid", "_args",
                 "_ts")

    def __init__(self, tracer: Tracer, name: str, cat: str, track: int,
                 rid: Optional[int], args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._rid = rid
        self._args = args

    def __enter__(self) -> "_SpanCtx":
        tr = self._tracer
        tr._depth += 1
        self._ts = tr.now_us()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tracer
        tr._depth -= 1
        tr._j.append(("X", self._name, self._cat, self._track, self._rid,
                      self._ts, tr.now_us() - self._ts, self._args))
