"""Serving observability subsystem (DESIGN.md §16): per-request lifecycle
tracing, step-clock metrics, Perfetto/Prometheus export.

``Telemetry`` is the one nullable handle the serving path threads through
(DESIGN.md §16.2): ``ServeEngine(telemetry=Telemetry())`` instruments the
engine, both schedulers, the paged pool, and the launcher; ``None`` (the
default) keeps every instrumentation site a single ``is not None`` test —
no spans are allocated, no metrics touched, and the jitted path is
untouched either way because all recording happens between jitted steps
or at trace time (the §10/§11/§13/§15 zero-retrace guarantees cannot be
affected by a layer that never runs inside a traced function).

The handle bundles:
  ``tracer``   obs/trace.py — lifecycle + host spans, instant events
  ``metrics``  obs/metrics.py — the serving instrument registry
and binds the engine's ``OffloadLedger`` so *ledger spans* (``span(...,
ledger=True)``) capture the exact FLOP/call delta committed while they
were open. Ledger spans are non-nesting and tightly scope every commit
site (admission prefill, batch decode step, preemption replay, one-shot
prefill/decode), which makes the attribution invariant checkable:

    sum of span FLOP deltas == ledger totals delta   (DESIGN.md §16.2)

gated exactly (integer equality) by benchmarks/telemetry_overhead.py and
the paged_serving/continuous_batching telemetry runs.

``activate``/``active`` expose the process-global handle the backend
executor's trace-time dispatch counter consults (DESIGN.md §16.3) —
dispatch resolution happens inside ``jax.jit`` *tracing*, where no
object can thread a handle through, so a module global is the honest
scope; ``ServeEngine`` activates its telemetry on construction
(last-constructed wins, like ``REPRO_BACKEND`` forcing is process-wide).
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from typing import Any, Callable, Dict, Optional

from repro.obs import export as export  # noqa: F401  (re-export surface)
from repro.obs.metrics import (LATENCY_BUCKETS_S, Counter, Gauge, Histogram,
                               MetricsRegistry, percentile, serving_registry)
from repro.obs.trace import (ENGINE_TRACK, Span, Tracer, _SpanCtx,
                             request_track)

__all__ = [
    "Telemetry", "Tracer", "Span", "MetricsRegistry", "Histogram",
    "Counter", "Gauge", "percentile", "serving_registry",
    "LATENCY_BUCKETS_S", "ENGINE_TRACK", "request_track",
    "activate", "active", "export",
]

_ACTIVE: Optional["Telemetry"] = None


def activate(tele: Optional["Telemetry"]) -> None:
    """Install ``tele`` as the process-global handle trace-time hooks
    (backends/executor.py) consult. ``None`` deactivates."""
    global _ACTIVE
    _ACTIVE = tele


def active() -> Optional["Telemetry"]:
    return _ACTIVE


class Telemetry:
    """The nullable observability handle (DESIGN.md §16.2).

    Every method is safe on a fully-enabled handle; disabled serving uses
    ``telemetry=None`` and never constructs one — the "no spans
    allocated" guarantee is structural (tests/test_obs.py monkeypatches
    ``Telemetry``/``Tracer``/``Span`` construction to raise and drives a
    full disabled drain to prove it). ``clock`` is injectable for
    deterministic tests and virtual-clock replays.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.tracer = Tracer(clock=clock) if tracer is None else tracer
        self.metrics = serving_registry() if metrics is None else metrics
        self._ledger = None
        self._led0 = (0, 0)
        self.claimed_flops = 0
        self.claimed_calls = 0
        self._ledger_depth = 0

    # -- ledger binding (DESIGN.md §16.2) -------------------------------
    def bind_ledger(self, ledger) -> None:
        """Attach the engine's ``OffloadLedger``; the consistency window
        starts here — deltas before binding belong to nobody."""
        self._ledger = ledger
        self._led0 = self._ledger_now()

    def _ledger_now(self) -> tuple:
        if self._ledger is None:
            return (0, 0)
        s = self._ledger.totals
        return (s.offloaded_flops + s.fallback_flops + s.residual_flops,
                s.offloaded_calls + s.fallback_calls)

    def ledger_delta(self) -> tuple:
        """(flops, calls) committed to the bound ledger since binding."""
        now = self._ledger_now()
        return (now[0] - self._led0[0], now[1] - self._led0[1])

    def claim_eager(self, entry, times: int = 1) -> None:
        """Claim an *eager* (un-jitted) ``OffloadEngine.linear`` account:
        those commits happen outside any span, so without this hook they
        would break the §16.2 exact equality under mixed eager+planned
        usage. ``entry.flops`` covers the whole linear (main + residual
        when offloaded, fallback otherwise) — exactly what
        ``OffloadLedger.account`` adds to the totals per call."""
        self.claimed_flops += entry.flops * times
        self.claimed_calls += times

    def ledger_consistent(self) -> Dict[str, int]:
        """The §16.2 attribution invariant, as data: ``claimed`` (summed
        over ledger spans) must equal ``ledger`` (the bound ledger's
        delta) exactly — both are integers."""
        flops, calls = self.ledger_delta()
        return {"claimed_flops": self.claimed_flops, "ledger_flops": flops,
                "claimed_calls": self.claimed_calls, "ledger_calls": calls,
                "exact": (self.claimed_flops == flops
                          and self.claimed_calls == calls)}

    # -- spans ----------------------------------------------------------
    def span(self, name: str, cat: str = "host", track: int = ENGINE_TRACK,
             rid: Optional[int] = None, ledger: bool = False,
             args: Optional[Dict[str, Any]] = None):
        """Record one host-side interval. ``ledger=True`` snapshots the
        bound ledger around the block and attaches the exact FLOP/call
        delta as span args (claimed toward the §16.2 invariant); ledger
        spans must not nest — nesting would double-claim, so it raises.

        Class-based context managers, not ``@contextmanager``: this is
        the per-decode-step hot path and the generator protocol costs
        ~3x as much as ``__enter__``/``__exit__`` — the ≤3% budget
        benchmarks/telemetry_overhead.py gates is won or lost here."""
        if ledger:
            return _LedgerSpanCtx(self, name, cat, track, rid,
                                  args if args is not None else {})
        return _SpanCtx(self.tracer, name, cat, track, rid,
                        args if args is not None else {})

    # -- hot-path ledger span (open/close pair) -------------------------
    def ledger_open(self) -> tuple:
        """Open half of a non-nesting ledger span, as a plain tuple
        handle — the per-decode-step fast path. The with-form
        (``span(..., ledger=True)``) costs ~5 Python frames per record;
        this pair costs 2, and on a sub-millisecond serving step those
        frames are the difference between fitting the ≤3% budget
        (benchmarks/telemetry_overhead.py) and not. NOT exception-safe:
        a raise between open and close leaves the nesting guard held —
        use the with-form anywhere that isn't the measured hot loop."""
        if self._ledger_depth:
            raise RuntimeError("nested ledger spans would double-claim "
                               "the §16.2 attribution invariant")
        self._ledger_depth = 1
        led = self._ledger
        if led is None:
            return (0, 0, self.tracer.now_us())
        s = led.totals
        return (s.offloaded_flops + s.fallback_flops + s.residual_flops,
                s.offloaded_calls + s.fallback_calls,
                self.tracer.now_us())

    def ledger_close(self, h: tuple, name: str, cat: str = "step",
                     track: int = ENGINE_TRACK, rid: Optional[int] = None,
                     args: Optional[Dict[str, Any]] = None) -> None:
        """Close half of ``ledger_open``: claims the exact FLOP/call
        delta toward §16.2 and journals the span record (the journal
        append is the tracer's own close-time representation)."""
        f1, c1 = self._ledger_now()
        f0, c0, ts = h
        df, dc = f1 - f0, c1 - c0
        if args is None:
            args = {}
        args["flops"] = df
        args["calls"] = dc
        tr = self.tracer
        tr._j.append(("X", name, cat, track, rid, ts, tr.now_us() - ts,
                      args))
        self.claimed_flops += df
        self.claimed_calls += dc
        self._ledger_depth = 0

    # -- lifecycle + instants (thin tracer passthrough) -----------------
    def begin(self, rid: int, name: str, **args: Any) -> None:
        self.tracer.begin(rid, name, **args)

    def end(self, rid: int, name: str, **args: Any) -> None:
        self.tracer.end(rid, name, **args)

    def instant(self, name: str, rid: Optional[int] = None,
                **args: Any) -> None:
        self.tracer.instant(name, rid=rid, **args)

    # -- metrics (declare-or-lookup passthrough) ------------------------
    def inc(self, name: str, v: float = 1.0, **labels: Any) -> None:
        self.metrics.counter(name).inc(v, **labels)

    def observe(self, name: str, v: float) -> None:
        self.metrics.histogram(name).observe(v)

    def gauge(self, name: str, v: float, **labels: Any) -> None:
        self.metrics.gauge(name).set(v, **labels)

    # -- snapshot / export ----------------------------------------------
    def sync_ledger_metrics(self) -> None:
        """Copy the bound ledger's totals into the ledger-fed counters
        (DESIGN.md §16.3) — called at snapshot/export time; the ledger is
        the source of truth, the counters are its exposition."""
        if self._ledger is None:
            return
        s = self._ledger.totals
        flops = self.metrics.counter("repro_ledger_flops_total")
        flops.set_total(s.offloaded_flops, kind="offloaded")
        flops.set_total(s.fallback_flops, kind="fallback")
        flops.set_total(s.residual_flops, kind="residual")
        for dev, v in sorted(s.by_device.items()):
            flops.set_total(v, device=dev)
        # per-role split for multi-model (speculative) engines
        # (DESIGN.md §17.2) — sums to the kind= totals exactly
        for role, v in sorted(s.by_role.items()):
            flops.set_total(v, role=role)
        calls = self.metrics.counter("repro_ledger_calls_total")
        for backend, v in sorted(s.by_backend.items()):
            calls.set_total(v, backend=backend)

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-safe dict: metrics + trace shape + the §16.2
        consistency record — what ``launch/serve.py`` prints as the
        consolidated report and ``--metrics-out`` persists."""
        self.sync_ledger_metrics()
        return {
            "metrics": self.metrics.snapshot(),
            "trace": {"spans": len(self.tracer.spans),
                      "events": len(self.tracer.events),
                      "open_phases": self.tracer.open_phases(),
                      "requests_opened": len(self.tracer.rids_opened),
                      "requests_closed": len(self.tracer.rids_closed)},
            "ledger_consistency": self.ledger_consistent(),
        }

    def write_trace(self, path: str) -> str:
        return export.write_trace(self.tracer, path)

    def write_metrics(self, path: str) -> str:
        self.sync_ledger_metrics()
        return export.write_metrics(self.metrics, path)


class _LedgerSpanCtx(_SpanCtx):
    """``Telemetry.span(..., ledger=True)``: a tracer span that also
    claims the bound ledger's exact FLOP/call delta (DESIGN.md §16.2)."""
    __slots__ = ("_tele", "_f0", "_c0")

    def __init__(self, tele: Telemetry, name: str, cat: str, track: int,
                 rid: Optional[int], args: Dict[str, Any]):
        super().__init__(tele.tracer, name, cat, track, rid, args)
        self._tele = tele

    def __enter__(self) -> "_LedgerSpanCtx":
        tele = self._tele
        if tele._ledger_depth:
            raise RuntimeError(
                "nested ledger spans would double-claim the §16.2 "
                f"attribution invariant (opening {self._name!r})")
        tele._ledger_depth = 1
        self._f0, self._c0 = tele._ledger_now()
        return super().__enter__()

    def __exit__(self, *exc) -> None:
        # claim into the args dict BEFORE the journal append in
        # super().__exit__ snapshots it into the record
        tele = self._tele
        f1, c1 = tele._ledger_now()
        df, dc = f1 - self._f0, c1 - self._c0
        self._args["flops"] = df
        self._args["calls"] = dc
        tele.claimed_flops += df
        tele.claimed_calls += dc
        tele._ledger_depth = 0
        super().__exit__(*exc)


def maybe_span(tele: Optional[Telemetry], name: str, **kwargs):
    """``tele.span(...)`` or a free ``nullcontext`` — the pattern every
    instrumentation site uses so the disabled path allocates nothing."""
    if tele is None:
        return nullcontext()
    return tele.span(name, **kwargs)
