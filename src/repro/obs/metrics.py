"""Zero-dependency metrics registry: counters, gauges, fixed-bucket
histograms, Prometheus text exposition (DESIGN.md §16.3).

The serving stack needs live, structured numbers — queue wait, TTFT,
per-token latency, KV-arena occupancy, retrace and preemption counts,
per-backend/per-device FLOPs — without pulling a metrics client into the
runtime image (the container bakes in jax only). Everything here is plain
Python over dicts and lists:

  ``Counter``    monotonic, optionally labeled (``inc(v, backend="x")``).
                 Ledger-fed counters (DESIGN.md §16.3) are *set* to the
                 ``OffloadLedger`` totals at snapshot time rather than
                 incremented — the ledger is already the source of truth.
  ``Gauge``      last-write-wins, optionally labeled.
  ``Histogram``  fixed upper-bound buckets (+Inf implicit). Bucket counts
                 are cumulative in the exposition (Prometheus ``le``
                 convention) and raw per-bucket in snapshots; the
                 invariant ``sum(bucket_counts) == count`` is property-
                 tested (tests/test_obs.py).

One percentile implementation serves every consumer: ``percentile()``
(numpy-free linear interpolation, matching ``np.percentile``'s default) is
what ``Histogram.percentile`` uses over retained observations, and what it
falls back to bucket-midpoint interpolation *with* when observations are
not retained. The serving benchmarks (continuous_batching,
sharded_serving, paged_serving) all build their latency summaries through
``Histogram`` with the registry's ``LATENCY_BUCKETS_S`` — there is no
second or third ``_percentile`` copy to drift.

``MetricsRegistry.snapshot()`` returns one nested dict (JSON-safe);
``render_prometheus()`` emits the text exposition format, so
``launch/serve.py --metrics-out`` can drop a file any Prometheus scraper
or ``promtool check metrics`` ingests.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

#: default latency buckets (seconds): 4 per decade from 10 µs to 100 s —
#: wide enough for queue waits under bursty load, fine enough that a
#: bucket-only percentile stays within ~1.8x of exact (10^(1/4) spacing)
LATENCY_BUCKETS_S: Tuple[float, ...] = tuple(
    round(10.0 ** (exp / 4.0), 10) for exp in range(-20, 9))

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    if not labels:           # hot path: unlabeled per-step instruments
        return ()
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: _LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def percentile(values: Sequence[float], q: float) -> float:
    """The repo's one percentile implementation: linear interpolation
    between closest ranks (numpy's default 'linear' method), so swapping
    a benchmark's ``np.percentile`` call for this one changes no numbers.
    ``q`` is in [0, 100]; empty input returns 0.0."""
    xs = sorted(values)
    n = len(xs)
    if n == 0:
        return 0.0
    if n == 1:
        return float(xs[0])
    pos = (q / 100.0) * (n - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(xs[lo] + (xs[hi] - xs[lo]) * frac)


@dataclass
class Counter:
    name: str
    help: str = ""

    _values: Dict[_LabelKey, float] = field(default_factory=dict, repr=False)

    def inc(self, v: float = 1.0, **labels: Any) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + v

    def set_total(self, v: float, **labels: Any) -> None:
        """Overwrite a series total — the ledger-fed path (DESIGN.md
        §16.3): the ``OffloadLedger`` already holds exact monotonic
        totals, so snapshot-time sync copies them instead of diffing."""
        self._values[_label_key(labels)] = float(v)

    def value(self, **labels: Any) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self) -> Dict[str, float]:
        return {_label_str(k) or "": v for k, v in sorted(self._values.items())}


@dataclass
class Gauge:
    name: str
    help: str = ""

    _values: Dict[_LabelKey, float] = field(default_factory=dict, repr=False)

    def set(self, v: float, **labels: Any) -> None:
        self._values[_label_key(labels)] = float(v)

    def value(self, **labels: Any) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self) -> Dict[str, float]:
        return {_label_str(k) or "": v for k, v in sorted(self._values.items())}


class Histogram:
    """Fixed-bucket histogram with the shared percentile implementation.

    ``buckets`` are finite upper bounds (ascending); an implicit +Inf
    bucket catches the tail, so ``sum(bucket_counts) == count`` always
    (property-tested). ``track_values=True`` retains raw observations so
    ``percentile`` is exact — the benchmarks' mode (bounded workloads);
    the serving registry keeps ``track_values=False`` (bounded memory for
    unbounded serve loops) and interpolates within the bucket instead.
    """

    def __init__(self, name: str, buckets: Iterable[float] = LATENCY_BUCKETS_S,
                 help: str = "", track_values: bool = False):
        self.name = name
        self.help = help
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self.bucket_counts: List[int] = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self.track_values = track_values
        self._values: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.bucket_counts[bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v
        self._min = v if self._min is None else min(self._min, v)
        self._max = v if self._max is None else max(self._max, v)
        if self.track_values:
            self._values.append(v)

    def percentile(self, q: float) -> float:
        """q-th percentile: exact over retained values when tracking,
        else linear interpolation inside the covering bucket (lower edge
        = previous bound or the observed min; upper = bound or max)."""
        if self.count == 0:
            return 0.0
        if self.track_values:
            return percentile(self._values, q)
        # find the bucket holding the q-th rank, interpolate inside it
        rank = (q / 100.0) * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.bucket_counts):
            if c == 0:
                continue
            if cum + c > rank:
                lo = self.buckets[i - 1] if i > 0 else (self._min or 0.0)
                hi = (self.buckets[i] if i < len(self.buckets)
                      else (self._max if self._max is not None else lo))
                lo = max(lo, self._min if self._min is not None else lo)
                hi = min(hi, self._max if self._max is not None else hi)
                frac = (rank - cum) / c
                return float(lo + (hi - lo) * frac)
            cum += c
        return float(self._max or 0.0)

    def snapshot(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.sum,
                "min": self._min, "max": self._max,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "buckets": list(zip([*self.buckets, math.inf],
                                    self.bucket_counts))}


class MetricsRegistry:
    """Name -> instrument map with one-call declaration-or-lookup (so
    instrumentation sites never race a central declaration list)."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, help: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, help)
        return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, help)
        return g

    def histogram(self, name: str, buckets: Iterable[float] = LATENCY_BUCKETS_S,
                  help: str = "", track_values: bool = False) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                name, buckets, help, track_values=track_values)
        return h

    # -- export --------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """One nested JSON-safe dict of everything (DESIGN.md §16.3)."""
        return {
            "counters": {n: c.series() for n, c in
                         sorted(self._counters.items())},
            "gauges": {n: g.series() for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.snapshot() for n, h in
                           sorted(self._histograms.items())},
        }

    def render_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4: HELP/TYPE headers,
        cumulative ``le`` histogram buckets, ``+Inf`` terminal bucket."""
        lines: List[str] = []
        for name, c in sorted(self._counters.items()):
            if c.help:
                lines.append(f"# HELP {name} {c.help}")
            lines.append(f"# TYPE {name} counter")
            series = c.series() or {"": 0.0}
            for label, v in series.items():
                lines.append(f"{name}{label} {_fmt(v)}")
        for name, g in sorted(self._gauges.items()):
            if g.help:
                lines.append(f"# HELP {name} {g.help}")
            lines.append(f"# TYPE {name} gauge")
            series = g.series() or {"": 0.0}
            for label, v in series.items():
                lines.append(f"{name}{label} {_fmt(v)}")
        for name, h in sorted(self._histograms.items()):
            if h.help:
                lines.append(f"# HELP {name} {h.help}")
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for bound, cnt in zip([*h.buckets, math.inf], h.bucket_counts):
                cum += cnt
                le = "+Inf" if math.isinf(bound) else _fmt(bound)
                lines.append(f'{name}_bucket{{le="{le}"}} {cum}')
            lines.append(f"{name}_sum {_fmt(h.sum)}")
            lines.append(f"{name}_count {h.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def serving_registry() -> MetricsRegistry:
    """The serving stack's standard instrument set (DESIGN.md §16.3) —
    declared up front so snapshots and expositions are stable even before
    the first request touches a given path."""
    r = MetricsRegistry()
    r.histogram("repro_queue_wait_seconds",
                help="submit -> admission wait per request")
    r.histogram("repro_ttft_seconds",
                help="submit -> first streamed token per request")
    r.histogram("repro_step_seconds",
                help="one fixed-shape batch decode step (wall)")
    r.histogram("repro_token_seconds",
                help="per-token latency (step wall / active slots)")
    r.histogram("repro_prefill_seconds",
                help="batch-1 admission prefill (wall)")
    r.histogram("repro_replay_seconds",
                help="preempt-and-recompute replay (wall, DESIGN.md §15.5)")
    r.gauge("repro_queue_depth", help="requests waiting for a slot")
    r.gauge("repro_slots_active", help="slots holding a live request")
    r.gauge("repro_step_traces", help="decode step_fn trace count (1 = "
            "zero retraces after warmup)")
    r.gauge("repro_kv_pages_free", help="free self-KV pages (paged pool)")
    r.gauge("repro_kv_pages_used", help="allocated self-KV pages")
    r.gauge("repro_kv_pages_shared",
            help="pages with refcount > 1 (CoW/prefix sharing)")
    r.gauge("repro_kv_utilization", help="peak used/committed KV bytes")
    r.counter("repro_requests_submitted_total")
    r.counter("repro_requests_finished_total")
    r.counter("repro_tokens_total", help="tokens streamed")
    r.counter("repro_preemptions_total", help="DESIGN.md §15.5 preemptions")
    r.counter("repro_prefix_hits_total",
              help="admissions served from shared cross-KV pages")
    r.counter("repro_cow_splits_total",
              help="copy-on-write page splits (DESIGN.md §15.2)")
    r.counter("repro_evictions_total")
    r.counter("repro_replays_total")
    r.counter("repro_dispatch_total",
              help="backend-registry dispatch resolutions at trace time, "
                   "by segment and backend (DESIGN.md §12)")
    r.counter("repro_ledger_flops_total",
              help="ledger-fed FLOPs by kind/device/role (DESIGN.md §16.3)")
    # speculative decoding (DESIGN.md §17.3): drafted vs accepted token
    # counts and round count feed the acceptance-rate report
    r.counter("repro_spec_rounds_total",
              help="speculative draft+verify rounds (DESIGN.md §17)")
    r.counter("repro_spec_drafted_total",
              help="draft tokens proposed across active slots")
    r.counter("repro_spec_accepted_total",
              help="draft tokens accepted by the verifier")
    r.gauge("repro_spec_acceptance_rate",
            help="accepted/drafted over the engine lifetime")
    r.gauge("repro_spec_verify_traces", help="verify step_fn trace count "
            "(1 = zero retraces after warmup, DESIGN.md §17.3)")
    # round-boundary admission over speculative rounds (DESIGN.md §17.4)
    r.counter("repro_spec_admissions_total",
              help="requests admitted into speculative wave rows at round "
                   "boundaries (DESIGN.md §17.4)")
    r.counter("repro_spec_pages_trimmed_total",
              help="pages released by the post-round rejected-suffix trim "
                   "on the paged speculative scheduler (DESIGN.md §17.4)")
    r.counter("repro_ledger_calls_total",
              help="ledger-fed call counts by backend")
    return r
