"""Production mesh definitions.

A FUNCTION, not a module constant — importing this module never touches jax
device state, so tests/benches keep their 1-CPU view and only dryrun.py
(which sets XLA_FLAGS first) ever builds the 256/512-device meshes.

Mesh shapes (assignment):
  single-pod : (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh(devices=None):
    """Smallest nontrivial mesh for CPU tests (requires >=4 host devices,
    set via XLA_FLAGS in the test process)."""
    n = len(devices or jax.devices())
    if n >= 8:
        shape, axes = (2, 4), ("data", "model")
    elif n >= 4:
        shape, axes = (2, 2), ("data", "model")
    else:
        shape, axes = (1, 1), ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
