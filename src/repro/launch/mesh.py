"""Production mesh definitions.

A FUNCTION, not a module constant — importing this module never touches jax
device state, so tests/benches keep their 1-CPU view and only dryrun.py
(which sets XLA_FLAGS first) ever builds the 256/512-device meshes.

Mesh shapes (assignment):
  single-pod : (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # older toolchains: no explicit axis
    AxisType = None                    # types; make_mesh defaults are fine


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """AbstractMesh across jax versions: new-style (sizes, names) signature
    vs the old single shape_tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_smoke_mesh(devices=None):
    """Smallest nontrivial mesh for CPU tests (requires >=4 host devices,
    set via XLA_FLAGS in the test process)."""
    n = len(devices or jax.devices())
    if n >= 8:
        shape, axes = (2, 4), ("data", "model")
    elif n >= 4:
        shape, axes = (2, 2), ("data", "model")
    else:
        shape, axes = (1, 1), ("data", "model")
    return _make_mesh(shape, axes)
