"""Production mesh definitions.

A FUNCTION, not a module constant — importing this module never touches jax
device state, so tests/benches keep their 1-CPU view and only dryrun.py
(which sets XLA_FLAGS first) ever builds the 256/512-device meshes.

Mesh shapes (assignment):
  single-pod : (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # older toolchains: no explicit axis
    AxisType = None                    # types; make_mesh defaults are fine


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """AbstractMesh across jax versions: new-style (sizes, names) signature
    vs the old single shape_tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_serve_mesh(data: int = 0, model: int = 1):
    """Serving mesh (DESIGN.md §13): slot-DP over "data", optional TP over
    "model". ``data=0`` takes every available device onto the data axis —
    on the forced-host CI platform
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``) that is the
    4-way slot-DP mesh the sharded-serving parity gate runs on. A
    data-only mesh keeps per-row reduction order identical to the
    single-device program, which is what makes the token-exact parity
    contract of benchmarks/sharded_serving.py checkable."""
    n = len(jax.devices())
    if data <= 0:
        if n % model:
            raise ValueError(f"model={model} does not divide the "
                             f"{n}-device count; pass data= explicitly "
                             "to serve on a device subset")
        data = max(n // model, 1)
    if data * model > n:
        raise ValueError(f"mesh ({data}, {model}) needs {data * model} "
                         f"devices, have {n}")
    return _make_mesh((data, model), ("data", "model"))


def make_smoke_mesh(devices=None):
    """Smallest nontrivial mesh for CPU tests (requires >=4 host devices,
    set via XLA_FLAGS in the test process)."""
    n = len(devices or jax.devices())
    if n >= 8:
        shape, axes = (2, 4), ("data", "model")
    elif n >= 4:
        shape, axes = (2, 2), ("data", "model")
    else:
        shape, axes = (1, 1), ("data", "model")
    return _make_mesh(shape, axes)
