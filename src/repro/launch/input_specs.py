"""ShapeDtypeStruct stand-ins for every model input of every
(architecture x input-shape) cell — weak-type-correct, shardable, no device
allocation. The dry-run lowers against these.

Semantics per the assignment brief + DESIGN.md §4:
  train/prefill  — full-sequence batch (teacher-forced for whisper).
  decode/long    — ONE new token against a KV cache of ``seq_len`` (the
                   state structs come from ``abstract_serve_state``).
  [audio]/[vlm]  — modality frontends are stubs: mel frames / patch
                   embeddings arrive precomputed.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as model_lib

SDS = jax.ShapeDtypeStruct


def batch_specs_struct(cfg: ModelConfig, shape: ShapeConfig
                       ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Full-sequence batch structs (train / prefill kinds)."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
    }
    if cfg.family == "audio":
        out["mel"] = SDS((b, s, cfg.n_mels), jnp.float32)
    if cfg.family == "vlm" and cfg.vision_patches:
        p = min(cfg.vision_patches, s // 2)
        out["patches"] = SDS((b, p, cfg.vision_embed_dim), jnp.float32)
    return out


def token_struct(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return SDS((shape.global_batch, 1), jnp.int32)


def abstract_params(cfg: ModelConfig, shape: ShapeConfig, *,
                    quantize=None):
    """Abstract param pytree (eval_shape — nothing allocated)."""
    def build(key):
        p = model_lib.init_params(key, cfg, max_positions=shape.seq_len)
        if quantize is not None:
            p = quantize(p)
        return p
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def abstract_serve_state(cfg: ModelConfig, shape: ShapeConfig, params_struct):
    """Abstract decode state with a cache of length seq_len (the decode_*
    cells' premise: the cache is already full; we lower one new token)."""
    b, s = shape.global_batch, shape.seq_len

    def build(params):
        memory = None
        if cfg.family == "audio":
            dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
            memory = jnp.zeros((b, cfg.encoder_ctx, cfg.d_model), dt)
        return model_lib.init_serve_state(params, cfg, b, s, memory=memory,
                                          prefill_len=s - 1)

    return jax.eval_shape(build, params_struct)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                quantize=None) -> Dict[str, Any]:
    """Everything the dry-run needs for one cell, keyed by role."""
    out: Dict[str, Any] = {"params": abstract_params(cfg, shape,
                                                     quantize=quantize)}
    if shape.is_decode:
        out["token"] = token_struct(shape)
        out["state"] = abstract_serve_state(cfg, shape, out["params"])
    else:
        out["batch"] = batch_specs_struct(cfg, shape)
    return out
