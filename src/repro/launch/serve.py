"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the ServeEngine with the paper's Q8_0 offload path and runs a batch
of synthetic requests, reporting latency + PDP/EDP per request (the
paper's Table 5 / Fig 9 quantities under the TDP-normalized power model).

``--continuous`` serves the same requests through the slot-pool
continuous-batching scheduler instead (DESIGN.md §11): staggered
admission into a fixed-width slot batch, per-request eviction, streamed
tokens, and exact per-request ledger/PDP attribution. ``--mesh`` serves
sharded over every visible device (DESIGN.md §13): slot-DP over the
data axis, per-device FLOP attribution in the energy report.

``--speculative`` serves the batch through a two-model speculative
engine (DESIGN.md §17): a cheap draft arch (``--draft``, default
whisper-tiny) proposes ``-k`` tokens per round, the main arch verifies
the window in one forward, and the consolidated report gains the
acceptance rate plus the draft/verify PDP split from the shared ledger.

``--trace-out``/``--metrics-out`` attach the observability subsystem
(DESIGN.md §16): either flag enables telemetry, the run's lifecycle
trace lands as Perfetto ``trace_event`` JSON (open at
https://ui.perfetto.dev), the metrics as Prometheus text exposition, and
the launcher prints ONE consolidated JSON report — energy, per-request
attribution (PDP, queue wait, TTFT), and the telemetry snapshot with its
§16.2 ledger-consistency record — instead of scattered summary lines.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import obs
from repro.configs.registry import ALL_ARCHS, get_config, get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ALL_ARCHS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--quant", default="q8_0", choices=["none", "q8_0"])
    ap.add_argument("--offload", action="store_true",
                    help="route GEMMs through the offload dispatcher")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler (DESIGN.md §11) "
                         "instead of one static batch")
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-pool width for --continuous")
    ap.add_argument("--mesh", action="store_true",
                    help="serve sharded over all visible devices "
                         "(DESIGN.md §13; combine with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative decoding (DESIGN.md §17): draft with "
                         "a cheap ladder model, verify with --arch")
    ap.add_argument("--draft", default="whisper-tiny",
                    choices=sorted(ALL_ARCHS),
                    help="draft arch for --speculative")
    ap.add_argument("-k", type=int, default=6,
                    help="draft window size for --speculative")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's Perfetto trace_event JSON here "
                         "(enables telemetry, DESIGN.md §16)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus text exposition here "
                         "(enables telemetry, DESIGN.md §16)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.speculative and args.continuous:
        ap.error("--speculative uses its own wave batching "
                 "(DESIGN.md §17.4); drop --continuous")
    if args.speculative and args.mesh:
        ap.error("--speculative over a sharded mesh is not supported yet")

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    params = model_lib.init_params(jax.random.PRNGKey(args.seed), cfg,
                                   max_positions=512)
    offload = OffloadEngine(interpret=True, prefer_pallas=False) \
        if args.offload else None
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh()
        print(f"serving mesh: {dict(mesh.shape)} over "
              f"{len(jax.devices())} device(s)")
    telemetry = (obs.Telemetry()
                 if (args.trace_out or args.metrics_out) else None)
    engine = ServeEngine(cfg, params, max_len=args.max_new + 32,
                         quant=args.quant, offload=offload, mesh=mesh,
                         telemetry=telemetry)

    rng = np.random.default_rng(args.seed)
    if cfg.family == "audio":
        mel = rng.standard_normal(
            (args.requests, 64, cfg.n_mels)).astype(np.float32)
        payloads = [mel[i:i + 1] for i in range(args.requests)]
    else:
        prompts = rng.integers(
            0, cfg.vocab_size, (args.requests, 8)).astype(np.int32)
        payloads = [prompts[i:i + 1] for i in range(args.requests)]

    attribution = None
    if args.continuous:
        sched = engine.scheduler(n_slots=args.slots,
                                 n_frames=64 if cfg.family == "audio"
                                 else None)
        rids = [sched.submit(p, max_new=args.max_new) for p in payloads]
        streamed = {r: 0 for r in rids}

        def on_token(ev):
            streamed[ev.rid] += 1

        # drive the drain manually so attribution() sees the finished
        # (unclaimed) results — run() would claim them first
        while sched.n_queued or sched.n_active:
            sched.admit()
            for ev in sched.decode_step():
                on_token(ev)
        attribution = sched.attribution()
        got = sched.run(on_token=on_token)             # claims results
        results = [got[r] for r in rids]
        print(f"continuous batching: {args.slots} slots, "
              f"{sum(streamed.values())} tokens streamed, "
              f"{sched.step_traces} step trace(s)")
    elif args.speculative:
        if cfg.family != "audio":
            ap.error("--speculative serves the Whisper ladder "
                     "(audio archs, DESIGN.md §17)")
        dcfg = (get_config(args.draft) if args.full
                else get_smoke_config(args.draft))
        dparams = model_lib.init_params(jax.random.PRNGKey(args.seed + 1),
                                        dcfg, max_positions=512)
        spec = engine.speculative(dcfg, dparams, k=args.k)
        results = spec.transcribe(mel, max_new=args.max_new)
        print(f"speculative: draft={args.draft} k={args.k} "
              f"acceptance={spec.acceptance_rate():.2f} "
              f"rounds={spec.rounds} "
              f"verify_traces={spec.stats()['verify_traces']}")
    elif cfg.family == "audio":
        results = engine.transcribe(mel, max_new=args.max_new)
    else:
        results = engine.generate(prompts, max_new=args.max_new)

    for i, r in enumerate(results):
        print(f"req{i}: {r.steps} tokens in {r.total_s:.3f}s "
              f"(prefill {r.prefill_s:.3f}s) pdp={r.pdp_j():.1f}J "
              f"tokens={r.tokens[:8]}...")
    # ONE consolidated report (DESIGN.md §16): energy + per-request
    # attribution (PDP / queue wait / TTFT) + the telemetry snapshot,
    # instead of the scattered ledger/plan-cache summary lines
    report = {"energy": engine.energy_report(results)}
    if attribution is not None:
        report["attribution"] = attribution
    if args.speculative:
        # acceptance + the draft/verify FLOP split (DESIGN.md §17.3);
        # energy_report's dispatch.by_role carries the same split scaled
        # into the PDP attribution when --offload is on
        report["speculative"] = spec.stats()
    if telemetry is not None:
        report["telemetry"] = telemetry.snapshot()
        if args.trace_out:
            print("trace written:", telemetry.write_trace(args.trace_out))
        if args.metrics_out:
            print("metrics written:",
                  telemetry.write_metrics(args.metrics_out))
    print(json.dumps(report, indent=1, default=str, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
