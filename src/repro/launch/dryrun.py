import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and extract memory/cost/collective evidence.

  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Cells lower ``train_step`` (train_4k) or ``serve_step`` (decode_32k /
long_500k) or ``forward`` (prefill_32k). Results (memory analysis, cost
analysis, parsed collectives, roofline terms) are written as JSON under
experiments/dryrun/<mesh>/<arch>__<shape>[__variant].json; EXPERIMENTS.md's
tables are generated from those files.

Per-arch training overrides (microbatching / optimizer-moment dtype) keep
the big cells inside v5e HBM — they are part of the *system config*, not
hacks: every real deployment of a 480B MoE on 256 chips does exactly this.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    ModelConfig, OptimizerConfig, ShapeConfig, shape_applicable)
from repro.configs.registry import ALL_SHAPES, ASSIGNED, get_config, get_shape
from repro.core.qformats import quantize_tree
from repro.launch import input_specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.roofline.analysis import analyze_compiled
from repro.sharding import rules as shard_rules
from repro.sharding import ctx as shard_ctx
from repro.train.step import init_train_state, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# ---------------------------------------------------------------------------
# Per-arch training memory configs (documented in EXPERIMENTS.md §Dry-run).
# microbatches: gradient-accumulation splits of the global batch.
# state_dtype: optimizer-moment storage (q8_0 = the paper's block format).
# ---------------------------------------------------------------------------
TRAIN_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "arctic-480b":            {"microbatches": 16, "state_dtype": "q8_0",
                               "grad_accum_dtype": "bfloat16"},
    "qwen1.5-110b":           {"microbatches": 8, "state_dtype": "bfloat16"},
    "jamba-v0.1-52b":         {"microbatches": 8},
    "olmoe-1b-7b":            {"microbatches": 8},
    "llava-next-mistral-7b":  {"microbatches": 4},
    "internlm2-20b":          {"microbatches": 4},
    "qwen2.5-14b":            {"microbatches": 4},
    "phi3-mini-3.8b":         {"microbatches": 4},
    "mamba2-780m":            {"microbatches": 2},
    "whisper-tiny":           {"microbatches": 1},
}


def _mesh_name(multi_pod: bool) -> str:
    return "multipod_2x16x16" if multi_pod else "pod_16x16"


def _replicated(tree, mesh):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def _quantizer(cfg: ModelConfig):
    from repro.serve.engine import _keep_dense
    return lambda p: quantize_tree(p, _keep_dense)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------
def lower_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                     overrides: Optional[Dict[str, Any]] = None):
    ov = dict(TRAIN_OVERRIDES.get(cfg.name, {}))
    ov.update(overrides or {})
    micro = int(ov.get("microbatches", 1))
    opt_cfg = OptimizerConfig(state_dtype=ov.get("state_dtype", "float32"))
    accum = {"bfloat16": jnp.bfloat16,
             "float32": jnp.float32}[ov.get("grad_accum_dtype", "float32")]

    state_struct = jax.eval_shape(
        lambda key: init_train_state(key, cfg, opt_cfg,
                                     max_positions=shape.seq_len),
        jax.random.PRNGKey(0))
    batch_struct = specs_lib.batch_specs_struct(cfg, shape)

    state_specs = shard_rules.train_state_specs(state_struct, mesh)
    batch_specs = shard_rules.batch_specs(batch_struct, mesh)
    mb_constraint = None
    if micro > 1:
        mb_constraint = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, P(None, *s)), batch_specs,
            is_leaf=lambda x: isinstance(x, P))

    step = make_train_step(cfg, opt_cfg, microbatches=micro,
                           grad_accum_dtype=accum,
                           batch_sharding_constraint=mb_constraint)

    metrics_struct = jax.eval_shape(step, state_struct, batch_struct)[1]
    jitted = jax.jit(
        step,
        in_shardings=(shard_rules.named(mesh, state_specs),
                      shard_rules.named(mesh, batch_specs)),
        out_shardings=(shard_rules.named(mesh, state_specs),
                       _replicated(metrics_struct, mesh)),
        donate_argnums=(0,),
    )
    with mesh, shard_ctx.activation_sharding(mesh):
        return jitted.lower(state_struct, batch_struct), {"microbatches": micro,
                                                          **ov}


def lower_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                       quant: str = "none"):
    qz = _quantizer(cfg) if quant == "q8_0" else None
    params_struct = specs_lib.abstract_params(cfg, shape, quantize=qz)
    batch_struct = specs_lib.batch_specs_struct(cfg, shape)
    p_specs = shard_rules.param_specs(params_struct, mesh)
    b_specs = shard_rules.batch_specs(batch_struct, mesh)
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    logits_spec = P(baxes if len(baxes) > 1 else baxes[0], None,
                    "model" if cfg.vocab_size % mesh.shape["model"] == 0
                    else None)

    def fwd(params, batch):
        logits, _ = model_lib.forward(params, cfg, batch)
        return logits

    jitted = jax.jit(
        fwd,
        in_shardings=(shard_rules.named(mesh, p_specs),
                      shard_rules.named(mesh, b_specs)),
        out_shardings=NamedSharding(mesh, logits_spec),
    )
    with mesh, shard_ctx.activation_sharding(mesh):
        return jitted.lower(params_struct, batch_struct), {"quant": quant}


def lower_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                      quant: str = "none"):
    qz = _quantizer(cfg) if quant == "q8_0" else None
    params_struct = specs_lib.abstract_params(cfg, shape, quantize=qz)
    state_struct = specs_lib.abstract_serve_state(cfg, shape, params_struct)
    token_struct = specs_lib.token_struct(shape)

    p_specs = shard_rules.param_specs(params_struct, mesh)
    s_specs = shard_rules.cache_specs(state_struct, mesh,
                                      cfg.num_kv_heads, cfg.head_dim)
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    tok_spec = P(baxes if len(baxes) > 1 else baxes[0]) \
        if shape.global_batch % bsize == 0 and bsize > 1 else P()
    logits_spec = P(tok_spec[0] if len(tok_spec) else None, None,
                    "model" if cfg.vocab_size % mesh.shape["model"] == 0
                    else None)

    def step(params, token, state):
        return model_lib.serve_step(params, cfg, token, state)

    jitted = jax.jit(
        step,
        in_shardings=(shard_rules.named(mesh, p_specs),
                      NamedSharding(mesh, tok_spec),
                      shard_rules.named(mesh, s_specs)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       shard_rules.named(mesh, s_specs)),
        donate_argnums=(2,),
    )
    with mesh, shard_ctx.activation_sharding(mesh):
        return jitted.lower(params_struct, token_struct, state_struct), \
            {"quant": quant}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               quant: str = "none",
               overrides: Optional[Dict[str, Any]] = None,
               cfg_overrides: Optional[Dict[str, Any]] = None):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "train":
        return lower_train_cell(cfg, shape, mesh, overrides=overrides), mesh
    if shape.kind == "prefill":
        return lower_prefill_cell(cfg, shape, mesh, quant=quant), mesh
    return lower_decode_cell(cfg, shape, mesh, quant=quant), mesh


# ---------------------------------------------------------------------------
# Cell execution: lower -> compile -> analyze -> JSON
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             quant: str = "none", out_dir: str = OUT_DIR,
             variant: str = "", verbose: bool = True,
             overrides: Optional[Dict[str, Any]] = None,
             cfg_overrides: Optional[Dict[str, Any]] = None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = _mesh_name(multi_pod)
    ok, reason = shape_applicable(cfg, shape)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "quant": quant, "variant": variant, "status": "skip",
        "reason": reason,
    }
    tag = f"{arch}__{shape_name}" + (f"__{variant}" if variant else "")
    path = os.path.join(out_dir, mesh_name, tag + ".json")
    os.makedirs(os.path.dirname(path), exist_ok=True)

    if ok:
        try:
            t0 = time.time()
            (lowered, meta), mesh = lower_cell(
                arch, shape_name, multi_pod=multi_pod, quant=quant,
                overrides=overrides, cfg_overrides=cfg_overrides)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
            ma = compiled.memory_analysis()
            chips = mesh.devices.size
            report = analyze_compiled(
                compiled, arch=arch, shape_cfg=shape, cfg=cfg,
                mesh_name=mesh_name, chips=chips)
            result.update(
                status="ok", meta=meta,
                lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
                memory={
                    "argument_bytes": ma.argument_size_in_bytes,
                    "temp_bytes": ma.temp_size_in_bytes,
                    "output_bytes": ma.output_size_in_bytes,
                    "alias_bytes": ma.alias_size_in_bytes,
                    "peak_estimate_bytes": (ma.argument_size_in_bytes
                                            + ma.temp_size_in_bytes
                                            + ma.output_size_in_bytes
                                            - ma.alias_size_in_bytes),
                },
                roofline=report.to_dict(),
            )
        except Exception as e:  # lowering/compile failure = a bug to fix
            result.update(status="error", error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-4000:])
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    if verbose:
        _print_cell(result)
    return result


def _fmt_bytes(b) -> str:
    return f"{b / 2**30:.2f}GiB" if b > 2**28 else f"{b / 2**20:.1f}MiB"


def _print_cell(r: dict):
    tag = f"{r['arch']}x{r['shape']}[{r['mesh']}]" + \
        (f"({r['variant']})" if r.get("variant") else "")
    if r["status"] == "skip":
        print(f"SKIP {tag}: {r['reason']}")
    elif r["status"] == "error":
        print(f"FAIL {tag}: {r['error']}")
    else:
        m, rf = r["memory"], r["roofline"]
        print(f"OK   {tag} compile={r['compile_s']:.0f}s "
              f"mem(arg={_fmt_bytes(m['argument_bytes'])} "
              f"temp={_fmt_bytes(m['temp_bytes'])}) "
              f"terms(c={rf['compute_s']:.4f}s m={rf['memory_s']:.4f}s "
              f"coll={rf['collective_s']:.4f}s) "
              f"bound={rf['bottleneck']} "
              f"useful={rf['useful_flop_ratio']:.2f} "
              f"roofline={rf['roofline_fraction']:.3f}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=sorted(ASSIGNED))
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--quant", default="none", choices=["none", "q8_0"])
    ap.add_argument("--variant", default="", help="tag for ablation outputs")
    ap.add_argument("--attn-impl", default=None, choices=["chunked", "flash"])
    ap.add_argument("--kv-quant", default=None, choices=["none", "q8"])
    ap.add_argument("--remat", default=None, choices=["none", "full", "dots"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    archs = sorted(ASSIGNED) if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in ALL_SHAPES]
              if (args.all or not args.shape) else [args.shape])
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    cfg_ov = {}
    if args.attn_impl:
        cfg_ov["attn_impl"] = args.attn_impl
    if args.kv_quant:
        cfg_ov["kv_quant"] = args.kv_quant
    if args.remat:
        cfg_ov["remat"] = args.remat
    train_ov = ({"microbatches": args.microbatches}
                if args.microbatches else None)

    n_fail = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi_pod=multi_pod,
                             quant=args.quant, out_dir=args.out,
                             variant=args.variant, overrides=train_ov,
                             cfg_overrides=cfg_ov or None)
                n_fail += r["status"] == "error"
    print(f"done; {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
