"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the supervised training loop (Trainer) on whatever devices exist:
CPU smoke (reduced config) by default; ``--full`` uses the full config
(dry-run-scale — only sensible on a real pod). The supervision loop
restarts from the latest atomic checkpoint on retryable failures — the
single-node stand-in for the pod controller's restart policy.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.configs.registry import ALL_ARCHS, get_config, get_smoke_config
from repro.launch.mesh import make_smoke_mesh
from repro.sharding import ctx as shard_ctx
from repro.train.fault import RestartPolicy, run_with_restarts
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ALL_ARCHS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs real accelerators)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard over available host devices")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=5,
                                  total_steps=max(args.steps, 10),
                                  grad_compress=args.grad_compress),
        steps=args.steps, checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir, max_restarts=args.max_restarts)

    mesh = make_smoke_mesh() if args.mesh else None

    def make_attempt(attempt: int):
        def attempt_fn():
            trainer = Trainer(run, mesh=mesh, install_signal_handler=True,
                              vocab_cap=512)
            if mesh is not None:
                with shard_ctx.activation_sharding(mesh):
                    return trainer.train()
            return trainer.train()
        return attempt_fn

    metrics = run_with_restarts(
        make_attempt, RestartPolicy(max_restarts=args.max_restarts))
    print("final:", {k: round(v, 4) for k, v in metrics.items()})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
