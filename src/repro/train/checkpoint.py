"""Atomic, elastic checkpointing (DESIGN.md §7).

Layout: ``<dir>/step_<N>/`` containing
  manifest.json   — leaf paths, shapes, dtypes, step, cursor, user metadata
  data.npz        — uint8-viewed buffers keyed by sanitized leaf path

Guarantees:
  * **Atomic** — written to ``<dir>/.tmp_step_<N>`` then os.rename'd;
    a crash mid-save never corrupts the latest valid checkpoint.
  * **Bit-exact restore** — buffers round-trip via raw bytes (bfloat16 and
    int8 included); tests assert equality, not allclose.
  * **Elastic** — restore takes a *template* state (from eval_shape) plus an
    optional target-mesh sharding tree; a checkpoint written on mesh (16,16)
    restores onto (8,), (2,16,16), or a single CPU device by re-device_put.
    Leaves are keyed by tree path, not device layout.

On a real multi-host pod each host would write its addressable shards
(process-local npz) with the same manifest contract; the single-process
container writes the full array.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # bfloat16 numpy dtype
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    _BF16 = None

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _sanitize(s: str) -> str:
    return s.replace("/", "__")


def save_checkpoint(ckpt_dir: str, state, *, step: int,
                    cursor_step: int = 0, seed: int = 0,
                    metadata: Optional[Dict[str, Any]] = None) -> str:
    """Two-phase atomic save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    manifest = {"step": int(step),
                "cursor": {"step": int(cursor_step), "seed": int(seed)},
                "metadata": metadata or {}, "leaves": []}
    buffers = {}
    for path, leaf in flat:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append(
            {"path": key, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        buffers[_sanitize(key)] = np.frombuffer(
            arr.tobytes(), dtype=np.uint8)
    np.savez(os.path.join(tmp, "data.npz"), **buffers)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m:
            steps.append((int(m.group(1)), name))
    if not steps:
        return None
    return os.path.join(ckpt_dir, max(steps)[1])


def _np_dtype(name: str):
    if name == "bfloat16":
        if _BF16 is None:
            raise RuntimeError("bfloat16 checkpoint needs ml_dtypes")
        return _BF16
    return np.dtype(name)


def load_checkpoint(path: str, template, *,
                    shardings=None) -> Tuple[Any, Dict[str, Any]]:
    """Restore onto ``template``'s tree structure (e.g. from eval_shape).

    ``shardings``: optional pytree of jax.sharding.Sharding matching the
    template — this is the elastic-resharding hook: pass the *new* mesh's
    shardings and every leaf lands resharded.
    Returns (state, manifest).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "data.npz"))
    by_path = {l["path"]: l for l in manifest["leaves"]}

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        if shardings is not None else [None] * len(flat_t))
    if shardings is not None and len(shard_flat) != len(flat_t):
        raise ValueError("shardings tree does not match template")

    leaves = []
    for (tpath, tleaf), shard in zip(flat_t, shard_flat):
        key = _path_str(tpath)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        meta = by_path[key]
        want_shape = tuple(getattr(tleaf, "shape", ()) or ())
        got_shape = tuple(meta["shape"])
        if want_shape != got_shape:
            raise ValueError(f"shape mismatch for {key}: checkpoint "
                             f"{got_shape} vs template {want_shape}")
        raw = data[_sanitize(key)].tobytes()
        arr = np.frombuffer(raw, dtype=_np_dtype(meta["dtype"])
                            ).reshape(got_shape)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def remove_old_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    """Bounded disk usage: keep the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        (int(m.group(1)), name)
        for name in os.listdir(ckpt_dir)
        if (m := _STEP_RE.match(name)))
    for _, name in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
