from repro.train.step import TrainState, init_train_state, make_train_step  # noqa: F401
from repro.train.checkpoint import (  # noqa: F401
    latest_checkpoint, load_checkpoint, save_checkpoint,
)
from repro.train.trainer import Trainer  # noqa: F401
from repro.train.fault import StragglerMonitor, run_with_restarts  # noqa: F401
