"""The Trainer: wires data stream, train_step, checkpointing, straggler
monitoring, and preemption into one supervised loop.

Mesh-optional: on CPU smoke runs it plain-jits the step; under a mesh it
jits with the sharding rules from sharding/rules.py (params/opt sharded,
batch sharded on (pod, data), donated buffers for in-place update).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, OptimizerConfig, RunConfig, ShapeConfig
from repro.data.pipeline import make_stream
from repro.sharding import rules as shard_rules
from repro.train import checkpoint as ckpt_lib
from repro.train.fault import PreemptionHandler, StragglerMonitor
from repro.train.step import TrainState, init_train_state, make_train_step


@dataclass
class Trainer:
    run: RunConfig
    mesh: Optional[Any] = None              # jax.sharding.Mesh
    engine: Any = None                      # core.offload.OffloadEngine
    install_signal_handler: bool = False
    fault_hook: Optional[Callable[[int], None]] = None  # tests: raise at step N
    vocab_cap: Optional[int] = None         # smoke: cap synthetic vocab

    state: Optional[TrainState] = None
    history: List[Dict[str, float]] = field(default_factory=list)
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)

    def __post_init__(self):
        self.stream = make_stream(self.run.model, self.run.shape,
                                  seed=self.run.seed,
                                  vocab_cap=self.vocab_cap)
        self._step_fn = None
        self._preempt = PreemptionHandler(install=self.install_signal_handler)
        self._start_step = 0

    # ------------------------------------------------------------------
    def _build_step(self):
        step = make_train_step(self.run.model, self.run.optimizer,
                               engine=self.engine)
        if self.mesh is None:
            return jax.jit(step, donate_argnums=(0,))
        state_specs = shard_rules.train_state_specs(self.state, self.mesh)
        batch = self.stream.batch_at(0)
        batch_specs = shard_rules.batch_specs(batch, self.mesh)
        return jax.jit(
            step,
            in_shardings=(shard_rules.named(self.mesh, state_specs),
                          shard_rules.named(self.mesh, batch_specs)),
            donate_argnums=(0,),
        )

    def _init_or_restore(self):
        ckpt = ckpt_lib.latest_checkpoint(self.run.checkpoint_dir)
        key = jax.random.PRNGKey(self.run.seed)
        self.state = init_train_state(key, self.run.model, self.run.optimizer,
                                      max_positions=self.run.shape.seq_len)
        if ckpt is not None:
            shardings = None
            if self.mesh is not None:
                specs = shard_rules.train_state_specs(self.state, self.mesh)
                shardings = shard_rules.named(self.mesh, specs)
            self.state, manifest = ckpt_lib.load_checkpoint(
                ckpt, self.state, shardings=shardings)
            self._start_step = manifest["cursor"]["step"]
        else:
            self._start_step = 0

    # ------------------------------------------------------------------
    def train(self, steps: Optional[int] = None) -> Dict[str, float]:
        """Run (or resume) the loop. Returns final metrics."""
        if self.state is None:
            self._init_or_restore()
        if self._step_fn is None:
            self._step_fn = self._build_step()

        steps = steps if steps is not None else self.run.steps
        metrics: Dict[str, float] = {}
        for s in range(self._start_step, steps):
            if self.fault_hook is not None:
                self.fault_hook(s)
            t0 = time.perf_counter()
            batch = self.stream.batch_at(s)
            self.state, m = self._step_fn(self.state, batch)
            jax.block_until_ready(m["loss"])
            dt = time.perf_counter() - t0
            straggler = self.monitor.observe(s, dt)
            metrics = {k: float(np.asarray(v)) for k, v in m.items()}
            metrics.update(step=s, dt_s=dt, straggler=float(straggler))
            self.history.append(metrics)

            final_step = s == steps - 1
            want_ckpt = (self.run.checkpoint_every
                         and (s + 1) % self.run.checkpoint_every == 0)
            if want_ckpt or self._preempt.requested or final_step:
                self.save(step=s + 1)
            if self._preempt.requested:
                break
        self._start_step = len(self.history) and (self.history[-1]["step"] + 1)
        return metrics

    def save(self, step: int) -> str:
        path = ckpt_lib.save_checkpoint(
            self.run.checkpoint_dir, self.state, step=step, cursor_step=step,
            seed=self.run.seed,
            metadata={"model": self.run.model.name,
                      "shape": self.run.shape.name})
        ckpt_lib.remove_old_checkpoints(self.run.checkpoint_dir, keep=3)
        return path
