"""Train-step builder: loss -> grads -> (optional int8-EF compression)
-> AdamW, as one jit-able pure function over a TrainState pytree.

The same builder serves the CPU smoke tests (no mesh), the examples, and
the 512-device dry-run (jitted with in/out shardings by launch/dryrun.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.models import model as model_lib
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.compression import ef_compress_grads, ef_init


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any              # int8-EF accumulators ({} when compression is off)
    rng: jax.Array       # carried PRNG key (router jitter etc.)

    @property
    def step(self) -> jax.Array:
        return self.opt.count


def init_train_state(key, cfg: ModelConfig,
                     opt_cfg: Optional[OptimizerConfig] = None,
                     max_positions: int = 0) -> TrainState:
    opt_cfg = opt_cfg or OptimizerConfig()
    pkey, rkey = jax.random.split(key)
    params = model_lib.init_params(pkey, cfg, max_positions)
    return TrainState(
        params=params,
        opt=adamw_init(params, opt_cfg),
        ef=ef_init(params) if opt_cfg.grad_compress == "int8_ef" else {},
        rng=rkey,
    )


def make_train_step(cfg: ModelConfig,
                    opt_cfg: Optional[OptimizerConfig] = None,
                    *, engine=None, attn_chunk: int = 2048,
                    microbatches: int = 1,
                    grad_accum_dtype=jnp.float32,
                    batch_sharding_constraint=None):
    """Returns train_step(state, batch) -> (state', metrics). Pure; jit it
    with whatever shardings the caller's mesh requires.

    ``microbatches`` > 1 enables gradient accumulation: the global batch is
    split on dim 0 and scanned, so per-step activation (and MoE dispatch)
    memory scales 1/K — required to fit the large-model train_4k cells on a
    256-chip pod. ``batch_sharding_constraint`` (a PartitionSpec pytree for
    one microbatch) keeps the batch dim sharded through the reshape.
    """
    opt_cfg = opt_cfg or OptimizerConfig()
    compress = opt_cfg.grad_compress == "int8_ef"

    def loss_of(params, mb):
        return model_lib.loss_fn(params, cfg, mb, engine=engine,
                                 attn_chunk=attn_chunk)

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
            return loss, aux, grads

        k = microbatches
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

        def micro(carry, mbatch):
            if batch_sharding_constraint is not None:
                mbatch = jax.lax.with_sharding_constraint(
                    mbatch, batch_sharding_constraint)
            gacc, lacc, aacc = carry
            (loss, aux), g = jax.value_and_grad(loss_of, has_aux=True)(
                params, mbatch)
            gacc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(grad_accum_dtype), gacc, g)
            return (gacc, lacc + loss, aacc + aux["moe_aux"]), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, grad_accum_dtype), params)
        (gacc, lsum, asum), _ = jax.lax.scan(
            micro, (zeros, jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree_util.tree_map(lambda g: g / k, gacc)
        aux = {"ce": lsum / k - asum / k, "moe_aux": asum / k,
               "ntok": jnp.zeros((), jnp.float32)}
        return lsum / k, aux, grads

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        loss, aux, grads = grads_of(state.params, batch)
        new_ef = state.ef
        if compress:
            grads, new_ef, _ = ef_compress_grads(grads, state.ef)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg)
        rng, _ = jax.random.split(state.rng)
        metrics = {"loss": loss.astype(jnp.float32), **aux, **opt_metrics}
        return TrainState(new_params, new_opt, new_ef, rng), metrics

    return train_step
