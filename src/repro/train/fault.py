"""Fault tolerance: supervised restarts, straggler detection, preemption.

At 1000+-node scale, node failure is routine (MTBF of a big pod is hours).
The contract here:

  * ``run_with_restarts`` — the launcher supervision loop: bounded restarts
    with exponential backoff; each restart resumes from the latest atomic
    checkpoint. Any exception type can be marked retryable; programming
    errors (TypeError etc.) re-raise immediately.
  * ``StragglerMonitor`` — per-step wall-time EWMA + variance tracker; a
    step slower than mean + k*sigma (and a minimum ratio above the mean)
    flags a straggler event. On a real pod this feeds the controller that
    re-slices the mesh / evicts the slow host; here events are recorded and
    surfaced in metrics (tests inject synthetic delays).
  * ``PreemptionHandler`` — SIGTERM -> request a final checkpoint at the
    next step boundary (cloud TPU preemption contract).
"""
from __future__ import annotations

import math
import signal
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple, Type


class Preempted(Exception):
    """Raised (or recorded) when a SIGTERM-initiated shutdown is requested."""


@dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.1
    backoff_factor: float = 2.0
    retryable: Tuple[Type[BaseException], ...] = (RuntimeError, OSError)


def run_with_restarts(make_fn: Callable[[int], Callable[[], object]],
                      policy: Optional[RestartPolicy] = None,
                      sleep=time.sleep):
    """Run ``make_fn(attempt)()`` under the restart policy.

    ``make_fn`` builds a fresh closure per attempt (so it can re-read the
    latest checkpoint). Returns the function's result. Raises the last
    error after exhausting restarts.
    """
    policy = policy or RestartPolicy()
    delay = policy.backoff_s
    last: Optional[BaseException] = None
    for attempt in range(policy.max_restarts + 1):
        try:
            return make_fn(attempt)()
        except policy.retryable as e:  # noqa: PERF203
            last = e
            if attempt == policy.max_restarts:
                break
            sleep(delay)
            delay *= policy.backoff_factor
    assert last is not None
    raise last


@dataclass
class StragglerMonitor:
    """EWMA mean/variance of step time; flags outlier steps."""
    alpha: float = 0.1
    k_sigma: float = 3.0
    min_ratio: float = 1.5       # must also be 1.5x the mean
    warmup_steps: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: List[dict] = field(default_factory=list)

    def observe(self, step: int, dt_s: float) -> bool:
        """Record one step duration; True if flagged as a straggler."""
        self.n += 1
        if self.n <= self.warmup_steps:
            # seed the statistics before judging
            if self.n == 1:
                self.mean = dt_s
            else:
                self.mean += (dt_s - self.mean) / self.n
                self.var += ((dt_s - self.mean) ** 2 - self.var) / self.n
            return False
        sigma = math.sqrt(max(self.var, 1e-12))
        is_straggler = (dt_s > self.mean + self.k_sigma * sigma
                        and dt_s > self.min_ratio * self.mean)
        if is_straggler:
            self.events.append({"step": step, "dt_s": dt_s,
                                "mean_s": self.mean, "sigma_s": sigma})
        else:
            # EWMA update only on healthy steps so stragglers don't poison it
            d = dt_s - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


class PreemptionHandler:
    """SIGTERM -> graceful final checkpoint at the next step boundary."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = None
        if install:
            try:
                self._prev = signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:  # non-main thread (tests)
                self._prev = None

    def _on_sigterm(self, signum, frame):
        self.requested = True

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)
