"""Int8 error-feedback gradient compression for the DP all-reduce
(beyond-paper: the paper's Q8_0 idea applied to the *collective* roofline
term; DESIGN.md §7).

Scheme (1-bit-Adam-family, 8-bit variant):
  1. e += g                       (fold the carried error into this step)
  2. q  = Q8_0(e)                 (blockwise int8 + fp16 scale — 4x fewer
                                   bytes on the gradient all-reduce wire)
  3. e  = e - deq(q)              (keep the quantization residual local)
  4. transmit q; the all-reduce averages dequantized blocks

On real pods step 4 is a reduce-scatter + all-gather of int8 payloads; under
GSPMD the compression is applied at the gradient boundary of train_step so
the numerics (and the convergence contract) are identical. Convergence vs
uncompressed is tested in tests/test_optim.py; the collective-bytes saving
is evaluated in the §Perf hillclimb.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.qformats import QBLOCK, dequantize_q8_0, quantize_q8_0


def _compressible(g) -> bool:
    return g.ndim >= 2 and g.shape[-1] % QBLOCK == 0


def ef_init(params) -> dict:
    """Error-feedback accumulators (f32 zeros for compressible leaves,
    None markers elsewhere — stored as zeros-like to stay a uniform tree)."""
    return jax.tree_util.tree_map(
        lambda p: (jnp.zeros(p.shape, jnp.float32) if _compressible(p)
                   else jnp.zeros((), jnp.float32)),
        params)


def ef_compress_grads(grads, ef: dict) -> Tuple[dict, dict, dict]:
    """Apply int8-EF compression to every compressible gradient leaf.

    Returns (compressed_grads, new_ef, stats). Incompressible leaves
    (1D norms/biases — a negligible byte fraction) pass through.
    """
    bytes_raw = [0]
    bytes_wire = [0]

    def leaf(g, e):
        g = g.astype(jnp.float32)
        if not _compressible(g):
            return g, e
        acc = g + e
        q = quantize_q8_0(acc)
        deq = dequantize_q8_0(q)
        bytes_raw[0] += g.size * 4
        bytes_wire[0] += q.nbytes()
        return deq, acc - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    stats = {"wire_bytes": bytes_wire[0], "raw_bytes": bytes_raw[0],
             "ratio": bytes_wire[0] / max(bytes_raw[0], 1)}
    return new_g, new_e, stats
