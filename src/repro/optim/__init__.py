from repro.optim.adamw import (  # noqa: F401
    AdamWState, adamw_init, adamw_update, global_norm, lr_schedule,
)
from repro.optim.compression import (  # noqa: F401
    ef_compress_grads, ef_init,
)
