"""AdamW from scratch on pytrees, with warmup+cosine schedule, global-norm
clipping, and selectable moment storage (f32 / bf16 / Q8_0 blocks).

The Q8_0 moment option is the paper's block-quantization format applied to
optimizer state (8-bit-Adam style): moments are stored as int8 blocks of 32
with an fp16 scale, dequantized for the update and requantized after. This
reuses ``core.qformats`` verbatim — the paper's technique as a *training*
memory feature, beyond its serving role.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.qformats import QBLOCK, QTensor, dequantize_q8_0, quantize_q8_0


class AdamWState(NamedTuple):
    mu: dict       # first moment, dtype per cfg.state_dtype
    nu: dict       # second moment
    count: jax.Array  # scalar int32 step counter


def _quantizable(leaf) -> bool:
    return (leaf.ndim >= 2 and leaf.shape[-1] % QBLOCK == 0
            and jnp.issubdtype(leaf.dtype, jnp.floating))


def _store(x: jax.Array, like, state_dtype: str):
    if state_dtype == "q8_0" and _quantizable(like):
        return quantize_q8_0(x)
    if state_dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _load(x) -> jax.Array:
    if isinstance(x, QTensor):
        return dequantize_q8_0(x)
    return x.astype(jnp.float32)


def _is_moment_leaf(x) -> bool:
    return isinstance(x, QTensor)


def adamw_init(params, cfg: Optional[OptimizerConfig] = None) -> AdamWState:
    cfg = cfg or OptimizerConfig()

    def zero(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _store(z, p, cfg.state_dtype)

    return AdamWState(
        mu=jax.tree_util.tree_map(zero, params),
        nu=jax.tree_util.tree_map(zero, params),
        count=jnp.zeros((), jnp.int32),
    )


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.lr * (0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, state: AdamWState, params,
                 cfg: OptimizerConfig) -> Tuple[dict, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics).
    Weight decay is decoupled and skipped for 1D leaves (norms, biases)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    t = count.astype(jnp.float32)
    lr = lr_schedule(cfg, count)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, mu_s, nu_s):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * _load(mu_s) + (1.0 - cfg.b1) * g
        nu = cfg.b2 * _load(nu_s) + (1.0 - cfg.b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, _store(mu, p, cfg.state_dtype), _store(nu, p, cfg.state_dtype)

    is_q = lambda x: isinstance(x, QTensor)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = jax.tree_util.tree_leaves(state.mu, is_leaf=is_q)
    flat_nu = jax.tree_util.tree_leaves(state.nu, is_leaf=is_q)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, AdamWState(new_mu, new_nu, count), metrics
