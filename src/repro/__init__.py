"""repro: Whisper dot-product kernel offloading (CGLA paper) re-targeted as a
multi-pod JAX/Pallas TPU framework. See DESIGN.md."""
__version__ = "0.1.0"
