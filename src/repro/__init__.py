"""repro: Whisper dot-product kernel offloading (CGLA paper) re-targeted as a
multi-pod JAX/Pallas TPU framework. The IMAX -> TPU concept map is
DESIGN.md §1; each subpackage docstring cites its own section."""
__version__ = "0.1.0"
