from repro.configs.base import (  # noqa: F401
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES_BY_NAME, TRAIN_4K,
    ModelConfig, MoEConfig, OptimizerConfig, RunConfig, ShapeConfig, SSMConfig,
    reduced, shape_applicable,
)
from repro.configs.registry import (  # noqa: F401
    ALL_ARCHS, ASSIGNED, EXTRA, dryrun_cells, get_config, get_shape,
    get_smoke_config,
)
