"""whisper-small.en — paper's scaling study (§4.3/§5). Not an assigned cell;
used by the coverage/PDP scaling benchmarks (Table 6, Fig 9/11)."""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    num_encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    vocab_pad=7,              # -> %16==0 so the readout shards on the model axis
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    pos_embedding="learned",
    tie_embeddings=True,
    is_encoder_decoder=True,
    encoder_ctx=1500,
    n_mels=80,
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
