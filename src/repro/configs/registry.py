"""Architecture registry: ``--arch <id>`` -> (CONFIG, SMOKE).

The 10 assigned archs form the 40-cell dry-run matrix; whisper-base/small are
extra (the paper's own scaling study) and are exercised by benchmarks only.
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import (
    ALL_SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeConfig, shape_applicable,
)

# assigned id -> module name
ASSIGNED: Dict[str, str] = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mamba2-780m": "mamba2_780m",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "internlm2-20b": "internlm2_20b",
    "qwen2.5-14b": "qwen2_5_14b",
    "whisper-tiny": "whisper_tiny",
    "arctic-480b": "arctic_480b",
    "olmoe-1b-7b": "olmoe_1b_7b",
}

EXTRA: Dict[str, str] = {
    "whisper-base": "whisper_base",
    "whisper-small": "whisper_small",
}

ALL_ARCHS: Dict[str, str] = {**ASSIGNED, **EXTRA}


def _load(module_name: str):
    return importlib.import_module(f"repro.configs.{module_name}")


def get_config(arch: str) -> ModelConfig:
    if arch not in ALL_ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALL_ARCHS)}")
    return _load(ALL_ARCHS[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in ALL_ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALL_ARCHS)}")
    return _load(ALL_ARCHS[arch]).SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def dryrun_cells():
    """Yield every (arch, shape, applicable, reason) cell of the matrix."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            yield arch, shape.name, ok, reason
