"""internlm2-20b [arXiv:2403.17297; hf]

[dense] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544 — GQA.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=92_544,
    norm="rmsnorm",
    act="swiglu",
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
