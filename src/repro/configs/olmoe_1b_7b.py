"""olmoe-1b-7b [arXiv:2409.02060; hf]

[moe] 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64e top-8 — 64 experts top-8, no shared expert.
"""
from repro.configs.base import ModelConfig, MoEConfig, reduced

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,                   # per-expert width (no dense branch)
    vocab_size=50_304,
    norm="rmsnorm",
    act="swiglu",
    moe=MoEConfig(
        num_experts=64,
        experts_per_token=8,
        d_ff=1024,
    ),
    moe_every=1,
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
