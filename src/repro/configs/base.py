"""Config system: frozen dataclasses describing models, shapes, and runs.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (full-size, dry-run only) and ``SMOKE`` (reduced, runs on CPU).
``registry.py`` wires them into ``--arch <id>`` selection.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
AUDIO = "audio"   # encoder-decoder with stubbed conv frontend
VLM = "vlm"       # decoder-only LM backbone with stubbed vision frontend

FAMILIES = (DENSE, MOE, SSM, HYBRID, AUDIO, VLM)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block parameters."""
    num_experts: int
    experts_per_token: int
    d_ff: int                    # per-expert hidden dim
    dense_residual_d_ff: int = 0 # arctic: dense MLP running in parallel
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    capacity_factor: float = 1.25  # used by dropping EP dispatch path
    # GShard-style dispatch group: tokens are routed within groups of this
    # size, so the dispatch one-hot is (G, Tg, E, Cg) with Cg ~ Tg*k*cf/E —
    # linear in total tokens. Without grouping the dispatch einsum is
    # O(T^2) and dominates the expert GEMMs at train_4k scale.
    dispatch_group: int = 512


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""
    d_state: int                 # N (ssm state per head channel)
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model
    head_dim: int = 64           # P; n_heads = d_inner // head_dim
    n_groups: int = 1
    chunk: int = 64              # SSD chunk length for the blocked scan

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. All full-size configs are dry-run-only."""
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # Megatron-style embedding pad: table/readout built at vocab_size +
    # vocab_pad so the vocab dim shards on the model axis; pad columns are
    # masked out of CE and argmax. Model is mathematically unchanged.
    vocab_pad: int = 0

    # --- block options ---
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    pos_embedding: str = "rope"  # rope | learned | sinusoidal

    # --- MoE ---
    moe: Optional[MoEConfig] = None
    moe_every: int = 1           # apply MoE FFN to layers where (i % moe_every == moe_offset)
    moe_offset: int = 0

    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1          # hybrid: attention at layers where (i % attn_every == attn_offset)
    attn_offset: int = 0         # others use SSM mixer. attn_every==1 -> all attention.

    # --- encoder-decoder (audio) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_ctx: int = 1500      # whisper n_audio_ctx (frames after conv stride 2)
    n_mels: int = 80

    # --- VLM frontend stub ---
    vision_patches: int = 0      # patches prepended as precomputed embeddings
    vision_embed_dim: int = 0    # raw patch embedding dim before projector

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- paper technique knobs (first-class feature) ---
    quant: str = "none"          # none | q8_0  (weights for serving path)
    vmem_budget_kb: int = 32_768 # VMEM budget claimed by offloaded tiles (KB). 32 MiB? no:
                                 # v5e VMEM is ~16 MiB/core -> soft budget in KB, see core/coverage.
    burst: int = 256             # lane-granularity analog of paper burst length

    # --- training ---
    remat: str = "full"          # none | full | dots  (activation checkpoint policy)
    scan_layers: bool = True     # lax.scan over the layer stack
    # attention implementation: "chunked" (q-chunked full-row softmax — the
    # baseline) | "flash" (k-blocked online softmax — beyond-paper §Perf
    # optimization of the memory roofline term)
    attn_impl: str = "chunked"
    # decode KV-cache storage: "none" (model dtype) | "q8" (int8 + per-head
    # scale — the paper's quantization applied to decode's dominant bytes)
    kv_quant: str = "none"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        assert self.family in FAMILIES, self.family
        if self.family in (MOE,):
            assert self.moe is not None
        if self.family in (SSM, HYBRID):
            assert self.ssm is not None
        if self.family == AUDIO:
            assert self.is_encoder_decoder

    @property
    def padded_vocab(self) -> int:
        return self.vocab_size + self.vocab_pad

    # ----- derived quantities used by coverage / roofline -----
    @property
    def attention_layers(self) -> Tuple[int, ...]:
        if self.family == SSM:
            return ()
        if self.family == HYBRID:
            return tuple(i for i in range(self.num_layers)
                         if i % self.attn_every == self.attn_offset)
        return tuple(range(self.num_layers))

    @property
    def moe_layers(self) -> Tuple[int, ...]:
        if self.moe is None:
            return ()
        return tuple(i for i in range(self.num_layers)
                     if i % self.moe_every == self.moe_offset)

    @property
    def uses_full_attention(self) -> bool:
        """True when every token attends over the whole sequence in all mixer
        layers -> long_500k is inapplicable per the brief."""
        return self.family not in (SSM, HYBRID)

    def n_params(self) -> int:
        """Total parameter count (embedding included once)."""
        return sum(int(p) for p in self._param_terms().values())

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: top-k experts only)."""
        terms = self._param_terms()
        total = sum(int(v) for v in terms.values())
        if self.moe is not None:
            total -= int(terms["moe_experts"])
            frac = self.moe.experts_per_token / self.moe.num_experts
            total += int(terms["moe_experts"] * frac)
        return int(total)

    def _param_terms(self) -> dict:
        d, dff, V = self.d_model, self.d_ff, self.vocab_size
        hq, hkv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * (hq * hd) + 2 * d * (hkv * hd) + (hq * hd) * d
        ffn_mults = 3 if self.act == "swiglu" else 2
        dense_ffn = ffn_mults * d * dff
        terms = {"embed": V * d, "head": 0 if self.tie_embeddings else V * d}
        n_attn = len(self.attention_layers)
        n_layers = self.num_layers + (self.num_encoder_layers if self.is_encoder_decoder else 0)
        if self.is_encoder_decoder:
            # decoder cross-attention adds another attn block per decoder layer
            terms["attn"] = attn * (self.num_encoder_layers + 2 * self.num_layers)
            terms["ffn"] = dense_ffn * n_layers
        else:
            terms["attn"] = attn * n_attn
            moe_l = set(self.moe_layers)
            dense_l = [i for i in range(self.num_layers) if i not in moe_l]
            terms["ffn"] = dense_ffn * len(dense_l)
            if self.moe is not None:
                e_ffn = ffn_mults * d * self.moe.d_ff
                terms["moe_experts"] = e_ffn * self.moe.num_experts * len(moe_l)
                terms["router"] = d * self.moe.num_experts * len(moe_l)
                if self.moe.dense_residual_d_ff:
                    terms["ffn"] += ffn_mults * d * self.moe.dense_residual_d_ff * len(moe_l)
            if self.ssm is not None:
                di = self.ssm.d_inner(d)
                nh = self.ssm.n_heads(d)
                ssm_l = self.num_layers - n_attn if self.family == HYBRID else self.num_layers
                # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
                per = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh) \
                    + di * d + self.ssm.d_conv * (di + 2 * self.ssm.n_groups * self.ssm.d_state) \
                    + 2 * nh
                terms["ssm"] = per * ssm_l
        terms["norms"] = 2 * d * n_layers + d
        return terms


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch gets all four; skips per DESIGN.md §4
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Return (applicable, reason-if-not) per the assignment rules."""
    if shape.name == "long_500k" and model.uses_full_attention:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{model.name} is pure full-attention (skip per brief)")
    return True, ""


# ---------------------------------------------------------------------------
# Run config (training hyperparams; used by trainer and examples)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    grad_compress: str = "none"  # none | int8_ef
    # Optimizer-moment storage: float32 | bfloat16 | q8_0. q8_0 reuses the
    # paper's block format for an 8-bit-Adam-style 4x moment-memory cut —
    # required to fit arctic-480b training on a 256-chip v5e pod.
    state_dtype: str = "float32"


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    max_restarts: int = 3


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Family-preserving reduction for smoke tests: tiny layers/width/experts."""
    d_model = min(cfg.d_model, 64)
    if cfg.num_heads == 0:       # attention-free (SSM)
        num_heads = num_kv = 0
    else:
        num_heads = min(cfg.num_heads, 4)
        num_kv = max(1, min(cfg.num_kv_heads, num_heads))
        # keep the GQA-vs-MHA character: preserve ratio when possible
        if cfg.num_kv_heads < cfg.num_heads:
            num_kv = max(1, num_heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    base = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        num_layers=min(cfg.num_layers, 2),
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=d_model // num_heads if num_heads else 16,
        d_ff=min(cfg.d_ff, 128) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        norm=cfg.norm, act=cfg.act, qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta, tie_embeddings=cfg.tie_embeddings,
        pos_embedding=cfg.pos_embedding,
        moe_every=cfg.moe_every, moe_offset=cfg.moe_offset,
        attn_every=min(cfg.attn_every, 2), attn_offset=min(cfg.attn_offset, 1),
        is_encoder_decoder=cfg.is_encoder_decoder,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_ctx=min(cfg.encoder_ctx, 32),
        n_mels=min(cfg.n_mels, 8),
        vision_patches=min(cfg.vision_patches, 8),
        vision_embed_dim=min(cfg.vision_embed_dim, 32),
        dtype="float32", param_dtype="float32",
        quant=cfg.quant, burst=128,
        remat="none", scan_layers=False,
    )
    if cfg.moe is not None:
        base["moe"] = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 4),
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            d_ff=min(cfg.moe.d_ff, 64),
            dense_residual_d_ff=min(cfg.moe.dense_residual_d_ff, 64)
            if cfg.moe.dense_residual_d_ff else 0,
        )
    if cfg.ssm is not None:
        base["ssm"] = SSMConfig(
            d_state=min(cfg.ssm.d_state, 16), d_conv=cfg.ssm.d_conv,
            expand=2, head_dim=16, n_groups=1, chunk=8,
        )
    base.update(overrides)
    return ModelConfig(**base)
