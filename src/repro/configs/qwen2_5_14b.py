"""qwen2.5-14b [hf:Qwen/Qwen2.5-0.5B; hf]

[dense] 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA, QKV bias.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13_824,
    vocab_size=152_064,
    norm="rmsnorm",
    act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
