"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

[vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 — anyres tiling.
Vision tower is a STUB: input_specs() provides precomputed patch embeddings
(anyres tiling fixed at a 576-patch base grid + one 576-patch tile, projected
by a learned 2-layer MLP projector inside the model).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,     # mistral-7b-v0.2 long-context base
    vision_patches=1152,        # 576 base + 576 anyres tile (stub)
    vision_embed_dim=1024,      # CLIP-L patch dim before projector
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
