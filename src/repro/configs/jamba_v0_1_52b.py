"""jamba-v0.1-52b [arXiv:2403.19887; hf]

[hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2 — Mamba+attention 1:7 interleave (1 attention layer per 8,
at offset 4 within each block of 8), MoE every other layer (offset 1).
Per DESIGN.md §6 the SSM mixer is the SSD (Mamba-2) recurrence with
jamba's d_state=16.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, reduced

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    norm="rmsnorm",
    act="swiglu",
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_ff=14_336),
    moe_every=2,
    moe_offset=1,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    attn_every=8,
    attn_offset=4,               # jamba: attention at layer 4 of each 8-block
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
