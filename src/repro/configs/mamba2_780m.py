"""mamba2-780m [arXiv:2405.21060; unverified]

[ssm] 48L d_model=1536 (attn-free) d_ff=0 vocab=50280, ssm_state=128 —
SSD (state-space duality) blocked scan. d_inner = 2*1536 = 3072,
head_dim=64 -> 48 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,                      # no FFN; mixer IS the block
    vocab_size=50_280,
    vocab_pad=8,              # -> %16==0 so the readout shards on the model axis
    norm="rmsnorm",
    act="swiglu",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
