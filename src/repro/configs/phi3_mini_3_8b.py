"""phi3-mini-3.8b [arXiv:2404.14219; unverified]

[dense] 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064 — RoPE SwiGLU.
kv=32 == heads -> effectively MHA.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    norm="rmsnorm",
    act="swiglu",
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
