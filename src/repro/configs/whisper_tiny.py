"""whisper-tiny — the paper's primary workload. [arXiv:2212.04356; unverified]

Assigned spec: [audio] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865,
encoder-decoder with conv frontend STUB (input_specs() provides precomputed
80-mel frame embeddings after the conv stride-2 frontend).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,               # decoder layers
    num_encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    vocab_pad=7,              # -> %16==0 so the readout shards on the model axis
    norm="layernorm",
    act="gelu",
    qkv_bias=True,              # whisper uses biases (k_proj bias absent; modeled uniform)
    pos_embedding="learned",
    tie_embeddings=True,
    is_encoder_decoder=True,
    encoder_ctx=1500,
    n_mels=80,
    quant="q8_0",               # the paper's Q8_0 serving path is first-class here
)

SMOKE = reduced(CONFIG)
