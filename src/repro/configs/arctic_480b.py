"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf]

[moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 — 128 experts top-2 PLUS a dense residual MLP in parallel
(dense-MoE hybrid: every layer has dense d_ff=4864 residual + routed experts).
"""
from repro.configs.base import ModelConfig, MoEConfig, reduced

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                    # dense residual branch width
    vocab_size=32_000,
    norm="rmsnorm",
    act="swiglu",
    moe=MoEConfig(
        num_experts=128,
        experts_per_token=2,
        d_ff=4864,
        dense_residual_d_ff=4864,  # arctic's dense-residual design
    ),
    moe_every=1,                   # MoE in every layer
    quant="q8_0",
)

SMOKE = reduced(CONFIG)
