"""Amdahl's-Law analysis of the dot-product bottleneck (paper §1, Fig 4).

The paper profiles Whisper-tiny.en on a Cortex-A72: the dot-product kernel is
90.6 % (FP16) / 87.1 % (Q8_0) of CPU time, bounding single-kernel offload at
10.6x / 7.8x. ``profile_shares`` measures the same split for our JAX whisper
implementation on this container's CPU by timing the model with the GEMM path
ablated (matmuls replaced by O(1) stand-ins) versus intact.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

# Paper's measured FP16/Q8_0 dot-product shares (Fig 4)
PAPER_SHARE = {"fp16": 0.906, "q8_0": 0.871}


def amdahl_speedup(offload_fraction: float, kernel_speedup: float) -> float:
    """System speedup when ``offload_fraction`` of time runs kernel_speedup x
    faster."""
    if not 0.0 <= offload_fraction <= 1.0:
        raise ValueError("fraction must be in [0,1]")
    if kernel_speedup <= 0:
        raise ValueError("speedup must be positive")
    return 1.0 / ((1.0 - offload_fraction) + offload_fraction / kernel_speedup)


def amdahl_bound(offload_fraction: float) -> float:
    """Theoretical maximum (kernel_speedup -> inf): 1/(1-f).
    f=0.906 -> 10.6x (FP16); f=0.871 -> 7.8x (Q8_0) — paper §1."""
    if offload_fraction >= 1.0:
        return float("inf")
    return 1.0 / (1.0 - offload_fraction)


def timeit_median(fn: Callable[[], object], iters: int = 5,
                  warmup: int = 2) -> float:
    """Median wall-clock seconds of fn() with warmup (blocks on jax arrays)."""
    import jax
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or isinstance(
            r, (list, tuple, dict)) else None
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def profile_shares(full_fn: Callable[[], object],
                   nogemm_fn: Callable[[], object],
                   iters: int = 5) -> Dict[str, float]:
    """Dot-product share = (T_full - T_nogemm)/T_full. The ablation keeps
    softmax/norms/elementwise ops and removes only mul_mat work, mirroring
    the paper's per-op profile."""
    t_full = timeit_median(full_fn, iters)
    t_rest = timeit_median(nogemm_fn, iters)
    share = max(0.0, min(1.0, (t_full - t_rest) / t_full))
    return {
        "t_full_s": t_full,
        "t_rest_s": t_rest,
        "dot_share": share,
        "amdahl_bound": amdahl_bound(share),
    }
