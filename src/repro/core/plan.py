"""Trace-time dispatch planning — the *plan* half of the plan/ledger split
(DESIGN.md §10).

The paper (and its CGLA companions) resolve per-``ggml_mul_mat`` routing as
a **static, shape-keyed decision** fixed before execution: a kernel either
fits the local-memory budget or it does not, and the burst/tiling operating
point is chosen offline. This module is that idea restated for a traced
JAX program: every routing input — the offload decision, the burst split,
the tuned tiling — is a pure function of *static shapes* plus engine
configuration, so it can be resolved once at trace time and recorded as a
``PlanEntry``. Execution (``core/offload.py OffloadEngine.linear``) then
consumes the entry without any Python-side mutation, which is what lets
the serving decode step sit inside ``jax.jit`` with an engine attached
(DESIGN.md §10.1).

Accounting moves to the other half of the split: a ``DispatchPlan`` knows
the per-execution cost of the traced program (its entries), and the
host-side ``OffloadLedger`` (core/offload.py) multiplies that by how many
times the compiled program actually ran (DESIGN.md §10.2). The in-trace
counter mutation this replaces both broke jit purity and silently
under-counted under any compilation cache.

Plan construction is deterministic: ``plan_linear`` twice with the same
shapes, budget and tuner cache state yields equal entries
(tests/test_plan.py), mirroring §9.2's deterministic analytic cost model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

from repro.backends import MAIN, KernelRequest, REGISTRY
from repro.core.coverage import MulMat, fits
from repro.core.mixed_exec import select_burst, split_aligned
from repro.sharding.rules import mesh_signature
from repro.tuning import kernel_for, padded_m


@dataclass(frozen=True)
class PlanEntry:
    """Routing record for one linear call site at one static shape.

    Everything the execution path needs (and everything the ledger
    accounts) is here: the ``(name, m, k, n, dtype)`` identity, the
    offload decision, the burst split, the tuned tiling for the main
    segment (``None`` when untuned — execution then falls back to the
    module-level default tiles, exactly as before the refactor), and the
    resolved execution ``backend`` (DESIGN.md §12.3) — a recorded plan
    pins its backend, so the ledger attributes work per backend and
    execution never re-decides what planning decided.
    """
    name: str
    m: int
    k: int
    n: int
    dtype: str                 # "q8_0" | "bf16"
    offload: bool
    burst: int
    tuned: bool
    kernel: str                # kernel the main segment dispatches to
    tiling: Optional[Tuple[int, int, int]]   # (block_m, block_n, block_k)
    k_main: int
    k_res: int
    backend: str = "xla_ref"   # registry backend pinned for the main segment
    # mesh signature the program was planned under (DESIGN.md §13) — None
    # for unsharded programs, so sharded/unsharded entries (and therefore
    # plan signatures) can never compare equal at the same shapes, and the
    # ledger can split per-device attribution exactly
    mesh: Optional[Tuple[Tuple[str, int], ...]] = None

    @property
    def flops(self) -> int:
        return 2 * self.m * self.k * self.n

    @property
    def offloaded_flops(self) -> int:
        """FLOPs on the accelerator kernel (main segment) if offloaded."""
        return self.flops * self.k_main // max(self.k, 1) if self.offload else 0

    @property
    def residual_flops(self) -> int:
        return self.flops * self.k_res // max(self.k, 1) if self.offload else 0

    @property
    def fallback_flops(self) -> int:
        return 0 if self.offload else self.flops


def plan_linear(name: str, m: int, k: int, n: int, *, quantized: bool,
                vmem_budget_kb: int, default_burst: int,
                tuner=None, backend: Optional[str] = None,
                mesh_sig=None) -> PlanEntry:
    """Resolve one linear's routing from static shapes — pure apart from
    tuner-cache warming (a miss runs one search whose winner is cached, so
    repeat calls are deterministic dict hits; see §9.3).

    This is the single source of truth for dispatch: ``OffloadEngine``
    calls it both when recording a plan (trace time) and when executing
    eagerly, so plan and execution can never disagree. ``backend``
    optionally pins the main-segment backend (the engine's legacy
    ``prefer_pallas`` translation); the *resolved* registry backend —
    after ``REPRO_BACKEND`` forcing and capability resolution
    (DESIGN.md §12.2) — is recorded in the entry.
    """
    dtype = "q8_0" if quantized else "bf16"
    kern = kernel_for(m, quantized)
    mp = padded_m(m)
    burst = default_burst
    tuned = False
    if tuner is not None:
        b = select_burst(k, tuner, kernel=kern, m=mp, n=n, dtype=dtype,
                         default=0)
        if b:
            burst, tuned = b, True
    k_main, k_res = split_aligned(k, burst)
    offload = fits(MulMat(name, m=m, k=k, n=n), vmem_budget_kb,
                   optimized=True, agg_units=1)
    tiling = None
    if tuner is not None and offload and k_main:
        # the main segment is what the kernel sees (the executor slices x
        # to k_main before dispatch), so the tiling key uses k_main, not k
        rec = tuner.best_tiling(kern, mp, n, k_main, dtype)
        if rec is not None:
            tiling = (rec.block_m, rec.block_n, rec.block_k)
    # resolve the main-segment backend at plan time (DESIGN.md §12.3): a
    # fallback entry runs the always-available reference path (the old
    # prefer_pallas=False branch of OffloadEngine.execute) — a structural
    # decision (forceable=False), so REPRO_BACKEND cannot push work the
    # coverage model kept off the accelerator back onto it
    if k_main:
        req = KernelRequest(kernel=kern, m=m, n=n, k=k_main, dtype=dtype,
                            segment=MAIN, tiling=tiling, forceable=offload)
        resolved = REGISTRY.resolve(req,
                                    pin=backend if offload else "xla_ref").name
    else:
        # k < burst: there is no main segment — the whole linear runs on
        # the host residual arm, so that is what the entry (and the
        # ledger's by_backend attribution) must name
        resolved = "host_residual"
    return PlanEntry(name=name, m=m, k=k, n=n, dtype=dtype, offload=offload,
                     burst=burst, tuned=tuned, kernel=kern, tiling=tiling,
                     k_main=k_main, k_res=k_res, backend=resolved,
                     mesh=mesh_sig)


@dataclass
class DispatchPlan:
    """The routing of one traced program: ``PlanEntry`` per linear call, in
    trace order. One plan describes ONE execution of the compiled program;
    the ledger multiplies by the run count (DESIGN.md §10.2)."""
    key: Hashable = None
    entries: List[PlanEntry] = field(default_factory=list)

    def add(self, entry: PlanEntry) -> None:
        self.entries.append(entry)

    def __iter__(self):
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    def signature(self) -> Tuple[PlanEntry, ...]:
        """Hashable identity — equal signatures mean identical routing
        (the determinism contract of tests/test_plan.py)."""
        return tuple(self.entries)

    def summary(self) -> Dict[str, Any]:
        off = [e for e in self.entries if e.offload]
        return {
            "calls": len(self.entries),
            "offloaded": len(off),
            "tuned": sum(1 for e in off if e.tuned),
            "offloaded_flops": sum(e.offloaded_flops for e in self.entries),
            "fallback_flops": sum(e.fallback_flops for e in self.entries),
            "residual_flops": sum(e.residual_flops for e in self.entries),
        }


def plan_key(phase: str, quant: Optional[str], batch: int,
             *extra: Hashable, mesh=None,
             pages: Optional[Tuple[Hashable, ...]] = None,
             role: Optional[str] = None,
             k: Optional[int] = None) -> Tuple[Hashable, ...]:
    """Canonical plan-cache key: ``(phase, quant, batch, *extra)``.

    One key family serves both serving modes (DESIGN.md §11.3): a
    slot-batched continuous-batching step at pool width ``B`` and frame
    capacity ``F`` is the *same* traced program as a static-batch decode
    step at ``(B, F)`` — routing depends only on static shapes — so the
    scheduler (serve/scheduler.py) and the one-shot ``transcribe``/
    ``generate`` paths build identical keys and share ``PlanCache``
    entries instead of re-recording.

    ``mesh`` (a ``Mesh``/``AbstractMesh``, or an already-built
    ``mesh_signature`` tuple) appends the sharding signature
    (DESIGN.md §13): the sharded decode step at ``(B, F)`` is a
    *different* compiled program from its unsharded twin — different
    layouts, different collectives — so they must never share a cache
    entry. ``mesh=None`` leaves pre-mesh keys byte-identical.

    ``pages`` appends the paged-pool geometry (DESIGN.md §15): a paged
    decode step gathers its KV through block tables — a different traced
    program from the contiguous step at the same (batch, frames) — so
    paged and contiguous programs must never share a ``PlanCache`` entry.
    ``pages=None`` leaves contiguous keys byte-identical.

    ``role``/``k`` append the speculative-decoding identity
    (DESIGN.md §17.2): a two-model engine runs a *draft* program and a
    *verify* program whose ``k``-position window makes it a different
    traced program (m = B·(k+1) per linear) from the plain step at the
    same batch — draft, verify and greedy plans must never share a
    ``PlanCache`` entry, and the role tag is what the ledger's
    per-role FLOP attribution keys commits by. ``role=None``/``k=None``
    leave single-model keys byte-identical.

    The qualifiers compose (DESIGN.md §17.4): a paged speculative verify
    window keys ``(..., ("pages", geom), ("role", "verify"), ("k", k))``
    — paged x role x k programs all land in disjoint entries, so the
    round-boundary schedulers (serve/speculative.py) never reuse a
    contiguous or plain-greedy plan for a paged window."""
    base = (phase, quant, batch, *extra)
    sig = mesh_signature(mesh) if hasattr(mesh, "axis_names") else mesh
    if sig is not None:
        base = (*base, ("mesh", sig))
    if pages is not None:
        base = (*base, ("pages", tuple(pages)))
    if role is not None:
        base = (*base, ("role", role))
    if k is not None:
        base = (*base, ("k", k))
    return base


@dataclass
class PlanCache:
    """Plans keyed by ``plan_key``-built ``(phase, quant, batch, ...)``
    tuples so steady-state serving resolves routing with one dict hit and
    zero re-tracing (DESIGN.md §10.3)."""
    plans: Dict[Hashable, DispatchPlan] = field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    def get_or_build(self, key: Hashable,
                     build: Callable[[], DispatchPlan]) -> DispatchPlan:
        plan = self.plans.get(key)
        if plan is not None:
            self.hits += 1
            return plan
        self.misses += 1
        plan = build()
        plan.key = key
        self.plans[key] = plan
        return plan

    def __len__(self) -> int:
        return len(self.plans)


def record_plan(engine, fn, *args, key: Hashable = None) -> DispatchPlan:
    """Build the ``DispatchPlan`` of ``fn(*args)`` by abstractly tracing it
    (``jax.eval_shape`` — shapes only, nothing executes) with the engine in
    recording mode. The recorded entries are exactly what a ``jax.jit`` of
    the same function resolves at its own trace time, because both go
    through ``plan_linear``; planning also warms the tuner cache so the
    real compile's lookups are pure dict hits."""
    import jax

    plan = DispatchPlan(key=key)
    with engine.recording(plan):
        # a fresh wrapper per recording: jax.eval_shape is backed by the
        # jit tracing cache, and a cache hit would skip the trace (and with
        # it the recording side channel) for a repeated (fn, shapes) pair
        jax.eval_shape(lambda *a: fn(*a), *args)
    return plan
