"""Local-memory kernel coverage (paper Table 2 / Table 6, §3.3, §5.1).

The paper's central co-design axis: a dot-product invocation is *offloadable*
iff its working set fits the local memory budget; everything else falls back
to the host. Coverage(budget) = fraction of invocations that fit.

Footprint model (documented per DESIGN.md §6.1 — the paper does not fully
specify its accounting):

* An invocation is one ``ggml_mul_mat(src0=W[N,K], src1=X[M,K])`` call.
* **Optimized** (padding stripped, dense DMA packing, weights streamed in
  double-buffered bursts and never resident): the LMM set must hold the dense
  activation operand, ``M*K*2`` bytes (fp16), spread across the lane's active
  PE LMMs -> fits iff ``M*K*2 <= budget_kb * 1024 * AGG_UNITS``.
* **Baseline** (whisper.cpp layout with alignment padding, whole-operand DMA
  with scratch duplication): M and K round up to 32 elements and the staging
  buffer is duplicated: ``2 * pad32(M) * pad32(K) * 2`` bytes.

``AGG_UNITS = 46`` — the Q8_0 kernel's active PEs per lane (paper §3.2); the
FP16 kernel's 2-lane total (2x22=44) is treated identically, matching the
paper's identical FP16/Q8_0 optimized coverage columns.

With this model the paper's cliff structure reproduces: whisper-tiny's
encoder activations (1500x384 fp16 = 1.125 MB) fit 46x32 KB = 1.47 MB but not
46x16 KB; base/small (K=512/768) need 64 KB — exactly Table 6's 32->64 KB
turning point (§5.4).

The same enumerator drives the TPU offload dispatcher: budgets become VMEM
tile budgets and AGG_UNITS=1 (one core's VMEM), see ``core/offload.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

from repro.configs.base import ModelConfig

AGG_UNITS = 46            # active PE LMMs aggregated per offloaded invocation
FP16_BYTES = 2
PAD = 32                  # baseline alignment padding, elements

LMM_SIZES_KB = (8, 16, 32, 64, 128, 256)


@dataclass(frozen=True)
class MulMat:
    """One ggml_mul_mat invocation class: W[N,K] x X[M,K] -> [M,N]."""
    name: str
    m: int
    k: int
    n: int
    count: int = 1          # invocations of this class over the workload
    phase: str = "decode"   # encode | prefill | decode

    @property
    def flops(self) -> int:
        return 2 * self.m * self.k * self.n * self.count

    @property
    def dots(self) -> int:
        """Row dot-products (the paper counts 477k/645k/1.9M for t/b/s)."""
        return self.m * self.n * self.count

    def act_bytes_dense(self) -> int:
        return self.m * self.k * FP16_BYTES

    def act_bytes_padded(self) -> int:
        mp = -(-self.m // PAD) * PAD
        kp = -(-self.k // PAD) * PAD
        return 2 * mp * kp * FP16_BYTES   # x2: staging-scratch duplication


def _pad_to(v: int, p: int) -> int:
    return -(-v // p) * p


# ---------------------------------------------------------------------------
# Workload enumerators
# ---------------------------------------------------------------------------
def enumerate_whisper(cfg: ModelConfig, n_frames: int = 1500,
                      n_tokens: int = 27) -> List[MulMat]:
    """All mul_mat invocations of one whisper.cpp inference (paper workload:
    jfk.wav ~10 s, padded to 30 s -> 1500 encoder frames, ~27 decoded tokens).
    """
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, hd = cfg.num_heads, cfg.head_dim
    el, dl = cfg.num_encoder_layers, cfg.num_layers
    F, T = n_frames, n_tokens
    ms: List[MulMat] = []
    a = ms.append
    # --- encoder (per layer) ---
    a(MulMat("enc.attn.qkv", F, d, 3 * d, el, "encode"))
    a(MulMat("enc.attn.out", F, d, d, el, "encode"))
    a(MulMat("enc.attn.scores", F, hd, F, el * h, "encode"))
    a(MulMat("enc.attn.av", F, F, hd, el * h, "encode"))
    a(MulMat("enc.ffn.up", F, d, dff, el, "encode"))
    a(MulMat("enc.ffn.down", F, dff, d, el, "encode"))
    # --- decoder cross K/V projection: once per utterance per layer ---
    a(MulMat("dec.cross.kv", F, d, 2 * d, dl, "encode"))
    # --- decoder (per token per layer); self-attn KV length grows ~T/2 avg ---
    t_avg = max(T // 2, 1)
    a(MulMat("dec.self.qkv", 1, d, 3 * d, dl * T, "decode"))
    a(MulMat("dec.self.out", 1, d, d, dl * T, "decode"))
    a(MulMat("dec.self.scores", 1, hd, t_avg, dl * T * h, "decode"))
    a(MulMat("dec.self.av", 1, t_avg, hd, dl * T * h, "decode"))
    a(MulMat("dec.cross.q", 1, d, d, dl * T, "decode"))
    a(MulMat("dec.cross.out", 1, d, d, dl * T, "decode"))
    a(MulMat("dec.cross.scores", 1, hd, F, dl * T * h, "decode"))
    a(MulMat("dec.cross.av", 1, F, hd, dl * T * h, "decode"))
    a(MulMat("dec.ffn.up", 1, d, dff, dl * T, "decode"))
    a(MulMat("dec.ffn.down", 1, dff, d, dl * T, "decode"))
    a(MulMat("dec.vocab", 1, d, v, T, "decode"))
    return ms


def enumerate_lm(cfg: ModelConfig, seq: int, new_tokens: int = 0,
                 batch: int = 1) -> List[MulMat]:
    """Decoder-only LM: prefill over ``seq`` + ``new_tokens`` decode steps.
    Used to extend the paper's coverage analysis to the assigned archs."""
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ms: List[MulMat] = []
    a = ms.append
    n_attn = len(cfg.attention_layers)
    moe_layers = len(cfg.moe_layers)
    dense_layers = cfg.num_layers - moe_layers
    ffn_mult = 3 if cfg.act == "swiglu" else 2
    if seq and n_attn:
        a(MulMat("attn.qkv", seq * batch, d, (hq + 2 * hkv) * hd, n_attn, "prefill"))
        a(MulMat("attn.out", seq * batch, hq * hd, d, n_attn, "prefill"))
        a(MulMat("attn.scores", seq, hd, seq, n_attn * hq * batch, "prefill"))
        a(MulMat("attn.av", seq, seq, hd, n_attn * hq * batch, "prefill"))
    if seq and dense_layers and dff:
        a(MulMat("ffn", seq * batch, d, ffn_mult * dff, dense_layers, "prefill"))
    if seq and moe_layers and cfg.moe is not None:
        tok_per_e = max(1, seq * batch * cfg.moe.experts_per_token // cfg.moe.num_experts)
        a(MulMat("moe.expert", tok_per_e, d, ffn_mult * cfg.moe.d_ff,
                 moe_layers * cfg.moe.num_experts, "prefill"))
    if cfg.ssm is not None and seq:
        ssm_layers = cfg.num_layers - n_attn if cfg.family == "hybrid" else cfg.num_layers
        di = cfg.ssm.d_inner(d)
        a(MulMat("ssm.in_proj", seq * batch, d,
                 2 * di + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + cfg.ssm.n_heads(d),
                 ssm_layers, "prefill"))
        a(MulMat("ssm.out_proj", seq * batch, di, d, ssm_layers, "prefill"))
    if seq:
        a(MulMat("vocab", seq * batch, d, v, 1, "prefill"))
    for t in range(new_tokens):
        kvlen = seq + t
        if n_attn:
            a(MulMat("dec.attn.qkv", batch, d, (hq + 2 * hkv) * hd, n_attn, "decode"))
            a(MulMat("dec.attn.out", batch, hq * hd, d, n_attn, "decode"))
            a(MulMat("dec.attn.scores", 1, hd, kvlen, n_attn * hq * batch, "decode"))
            a(MulMat("dec.attn.av", 1, kvlen, hd, n_attn * hq * batch, "decode"))
        if dense_layers and dff:
            a(MulMat("dec.ffn", batch, d, ffn_mult * dff, dense_layers, "decode"))
        a(MulMat("dec.vocab", batch, d, v, 1, "decode"))
    return ms


# ---------------------------------------------------------------------------
# Coverage computation
# ---------------------------------------------------------------------------
def fits(mm: MulMat, budget_kb: int, optimized: bool = True,
         agg_units: int = AGG_UNITS) -> bool:
    cap = budget_kb * 1024 * agg_units
    b = mm.act_bytes_dense() if optimized else mm.act_bytes_padded()
    return b <= cap


def coverage(mulmats: Sequence[MulMat], budget_kb: int, *,
             optimized: bool = True, weight: str = "dots",
             agg_units: int = AGG_UNITS) -> float:
    """weight='dots' (row dot-products) reproduces the paper's Table 2/6
    columns to within ~2 points — the paper's 'cumulative percentage' counts
    dot-product operations, matching its 477k/645k/1.9M invocation figures
    being dot-granular (§5.4). Coverage in [0,1]; weight: calls|dots|flops."""
    def w(mm: MulMat) -> float:
        if weight == "calls":
            return mm.count
        if weight == "dots":
            return mm.dots
        if weight == "flops":
            return mm.flops
        raise ValueError(weight)
    total = sum(w(m) for m in mulmats)
    if total == 0:
        return 0.0
    hit = sum(w(m) for m in mulmats if fits(m, budget_kb, optimized, agg_units))
    return hit / total


def coverage_cdf(mulmats: Sequence[MulMat], *,
                 sizes_kb: Iterable[int] = LMM_SIZES_KB,
                 weight: str = "dots") -> List[Tuple[int, float, float]]:
    """[(size_kb, baseline_cov, optimized_cov)] — the Table 2 structure."""
    return [(s,
             coverage(mulmats, s, optimized=False, weight=weight),
             coverage(mulmats, s, optimized=True, weight=weight))
            for s in sizes_kb]


def fallback_time_fraction(mulmats: Sequence[MulMat], budget_kb: int,
                           accel_speedup: float = 8.0) -> float:
    """Latency model of §5.1: covered kernels run accel_speedup x faster;
    uncovered kernels run at host speed. Returns T(budget)/T(host-only),
    FLOP-weighted — reproduces Fig 11's monotone latency-vs-LMM trend."""
    total = sum(m.flops for m in mulmats)
    if total == 0:
        return 1.0
    cov = sum(m.flops for m in mulmats if fits(m, budget_kb))
    return (total - cov) / total + (cov / total) / accel_speedup
