"""The paper's primary contribution as a composable feature set: Q8_0
quantization (qformats), local-memory coverage co-design (coverage),
burst/tile granularity selection (bursts), mixed aligned/residual execution
(mixed_exec), the offload dispatcher (offload), the PDP/EDP energy model
(energy) and the Amdahl profiling analysis (amdahl)."""
from repro.core.qformats import (  # noqa: F401
    QBLOCK, QTensor, dequantize_q8_0, dequantize_tree, quantize_q8_0,
    quantize_tree, reconstruction_error,
)
