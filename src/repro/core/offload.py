"""Offload dispatcher — the paper's co-design loop as a runtime feature.

Given a linear layer's shapes and the configured VMEM budget + burst, decide
per-invocation (like IMAX's per-``ggml_mul_mat`` decision) whether the main
segment runs on the accelerator kernel or falls back to the host/XLA path,
and account the PDP consequences. This is the glue between:

  coverage.py  (does the working set fit the local-memory budget?)
  bursts.py    (which granularity minimizes the PDP proxy?)
  mixed_exec   (aligned main + residual split)
  kernels.ops  (the actual compute paths)
  energy.py    (PDP/EDP accounting per step)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.coverage import MulMat, fits
from repro.core.mixed_exec import split_aligned
from repro.core.qformats import QTensor
from repro.kernels import ops


@dataclass
class OffloadStats:
    """Per-run accounting (feeds the Fig 12 exec-breakdown benchmark)."""
    offloaded_calls: int = 0
    fallback_calls: int = 0
    offloaded_flops: int = 0
    fallback_flops: int = 0
    residual_flops: int = 0
    by_kernel: Dict[str, int] = field(default_factory=dict)

    def offload_rate(self) -> float:
        t = self.offloaded_calls + self.fallback_calls
        return self.offloaded_calls / t if t else 0.0

    def offload_flop_rate(self) -> float:
        t = self.offloaded_flops + self.fallback_flops
        return self.offloaded_flops / t if t else 0.0


@dataclass
class OffloadEngine:
    """The dispatcher. ``vmem_budget_kb`` is the LMM-size analog (per-core
    VMEM claim allowed for one invocation's working set; agg_units=1 on TPU);
    ``burst`` is the lane granularity from the burst sweep."""
    vmem_budget_kb: int = 8 * 1024      # half of v5e's ~16 MiB VMEM
    burst: int = 256
    prefer_pallas: Optional[bool] = None
    interpret: Optional[bool] = None
    stats: OffloadStats = field(default_factory=OffloadStats)

    def should_offload(self, m: int, k: int, n: int, name: str = "linear") -> bool:
        mm = MulMat(name, m=m, k=k, n=n)
        return fits(mm, self.vmem_budget_kb, optimized=True, agg_units=1)

    def linear(self, x: jax.Array, w, name: str = "linear") -> jax.Array:
        """y = x @ W^T with per-invocation offload decision + accounting."""
        k = x.shape[-1]
        n = w.shape[0] if not isinstance(w, QTensor) else w.shape[0]
        m = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
        flops = 2 * m * k * n
        k_main, k_res = split_aligned(k, self.burst)
        offload = self.should_offload(m, k, n, name)
        if offload:
            self.stats.offloaded_calls += 1
            self.stats.offloaded_flops += flops * k_main // max(k, 1)
            self.stats.residual_flops += flops * k_res // max(k, 1)
            y = ops.matmul(x, w, burst=self.burst,
                           prefer_pallas=self.prefer_pallas,
                           interpret=self.interpret)
        else:
            self.stats.fallback_calls += 1
            self.stats.fallback_flops += flops
            y = ops.matmul(x, w, burst=self.burst, prefer_pallas=False)
        self.stats.by_kernel[name] = self.stats.by_kernel.get(name, 0) + 1
        return y
