"""Offload dispatcher — the paper's co-design loop as a runtime feature.

Given a linear layer's shapes and the configured VMEM budget + burst, decide
per-invocation (like IMAX's per-``ggml_mul_mat`` decision) whether the main
segment runs on the accelerator kernel or falls back to the host/XLA path,
and account the PDP consequences. This is the glue between:

  coverage.py  (does the working set fit the local-memory budget?)
  bursts.py    (which granularity minimizes the PDP proxy?)
  backends/    (the execution-backend registry + mixed-split executor —
                the actual compute paths, DESIGN.md §12)
  energy.py    (PDP/EDP accounting per step)
  plan.py      (trace-time routing resolution — DESIGN.md §10)

Plan/ledger split (DESIGN.md §10): ``linear`` is a pure function of its
arguments — routing comes from ``core.plan.plan_linear`` (static shapes
only) and no counters mutate inside a traced call, so the whole decode
step jits with an engine attached. Accounting lives in the host-side
``OffloadLedger``: eager (concrete-input) calls account directly, traced
programs are accounted by committing their recorded ``DispatchPlan``
multiplied by the number of executions (serve/engine.py does this per
request).
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import numpy as np

from repro.backends import executor, pin_for_prefer
from repro.core.coverage import MulMat, fits
from repro.core.plan import DispatchPlan, PlanEntry, plan_linear
from repro.core.qformats import QTensor
from repro.tuning import Autotuner


@dataclass
class OffloadStats:
    """Aggregated accounting (feeds the Fig 12 exec-breakdown benchmark).
    Totals container of the ``OffloadLedger`` — populated from committed
    plans and eager calls, never from inside a traced function."""
    offloaded_calls: int = 0
    fallback_calls: int = 0
    offloaded_flops: int = 0
    fallback_flops: int = 0
    residual_flops: int = 0
    tuned_calls: int = 0        # offloads that ran on a tuned burst
    by_kernel: Dict[str, int] = field(default_factory=dict)
    by_backend: Dict[str, int] = field(default_factory=dict)  # DESIGN.md §12.3
    # per-device FLOP attribution under sharded serving (DESIGN.md §13):
    # slot-DP splits every linear's batch rows evenly across the mesh, so
    # each device's share is flops/n_devices (remainder bookkept to dev0);
    # unsharded entries attribute everything to dev0. The invariant —
    # sum(by_device) == offloaded + fallback + residual flops — is what
    # keeps PDP accounting exact under sharding (gated by
    # benchmarks/sharded_serving.py).
    by_device: Dict[str, int] = field(default_factory=dict)
    # per-role FLOP attribution for multi-model engines (DESIGN.md §17.2):
    # a speculative engine's draft and verifier commit into ONE ledger,
    # tagged "draft"/"verify"; single-model commits (and eager calls)
    # default to "main". Invariant, same shape as by_device:
    # sum(by_role) == offloaded + fallback + residual flops — gated by
    # benchmarks/speculative.py next to the §16.2 span exactness.
    by_role: Dict[str, int] = field(default_factory=dict)

    def offload_rate(self) -> float:
        t = self.offloaded_calls + self.fallback_calls
        return self.offloaded_calls / t if t else 0.0

    def offload_flop_rate(self) -> float:
        t = self.offloaded_flops + self.fallback_flops
        return self.offloaded_flops / t if t else 0.0


@dataclass
class OffloadLedger:
    """Host-side accounting — the *ledger* half of the plan/ledger split
    (DESIGN.md §10.2). One entry-accounting path serves both modes: eager
    calls account their entry once; jitted programs commit their recorded
    ``DispatchPlan`` times the number of executions, which reproduces
    exactly the totals the old in-trace counters produced when every call
    ran un-jitted (tests/test_plan.py asserts this equivalence)."""
    totals: OffloadStats = field(default_factory=OffloadStats)
    commits: int = 0            # plans committed (not executions)

    def account(self, entry: PlanEntry, times: int = 1,
                role: str = "main") -> None:
        s = self.totals
        if entry.offload:
            s.offloaded_calls += times
            if entry.tuned:
                s.tuned_calls += times
            s.offloaded_flops += entry.offloaded_flops * times
            s.residual_flops += entry.residual_flops * times
        else:
            s.fallback_calls += times
            s.fallback_flops += entry.fallback_flops * times
        s.by_kernel[entry.name] = s.by_kernel.get(entry.name, 0) + times
        s.by_backend[entry.backend] = (s.by_backend.get(entry.backend, 0)
                                       + times)
        # per-device split (DESIGN.md §13): entry.flops covers the whole
        # linear (main + residual when offloaded, fallback otherwise), so
        # the even split keeps sum(by_device) equal to the flop totals
        n_dev = 1
        for _, size in (entry.mesh or ()):
            n_dev *= int(size)
        share, rem = divmod(entry.flops * times, n_dev)
        for i in range(n_dev):
            dev = f"dev{i}"
            s.by_device[dev] = (s.by_device.get(dev, 0) + share
                                + (rem if i == 0 else 0))
        # per-role split (DESIGN.md §17.2): whole-linear flops, so
        # sum(by_role) stays equal to the flop totals like by_device
        s.by_role[role] = s.by_role.get(role, 0) + entry.flops * times

    def commit(self, plan: Optional[DispatchPlan], times: int = 1,
               role: str = "main") -> None:
        """Account ``times`` executions of a traced program's plan.
        ``role`` tags the commit for multi-model attribution
        (DESIGN.md §17.2) — "draft"/"verify" from a speculative engine,
        "main" everywhere else."""
        if plan is None or times <= 0:
            return
        for entry in plan:
            self.account(entry, times, role=role)
        self.commits += 1


@dataclass
class OffloadEngine:
    """The dispatcher. ``vmem_budget_kb`` is the LMM-size analog (per-core
    VMEM claim allowed for one invocation's working set; agg_units=1 on TPU);
    ``burst`` is the lane granularity from the burst sweep — the *untuned*
    fallback when no ``tuner`` is attached. With a ``tuner``
    (tuning.Autotuner), both the split granularity and the kernel tile
    shapes come from the persistent tuning cache (DESIGN.md §9.4): a cache
    hit is a dict lookup, so steady-state dispatch stays cheap — and with
    the plan/ledger split (DESIGN.md §10) even that lookup happens only at
    trace time; compiled steady-state dispatch is zero Python."""
    vmem_budget_kb: int = 8 * 1024      # half of v5e's ~16 MiB VMEM
    burst: int = 256
    prefer_pallas: Optional[bool] = None
    interpret: Optional[bool] = None
    tuner: Optional[Autotuner] = None
    ledger: OffloadLedger = field(default_factory=OffloadLedger)
    # mesh signature of the serving mesh this engine dispatches under
    # (DESIGN.md §13) — set by ServeEngine when a mesh is attached; stamped
    # into every PlanEntry so sharded plans never compare equal to
    # unsharded ones and the ledger can attribute work per device
    mesh_sig: Optional[tuple] = None
    _recording: Optional[DispatchPlan] = field(default=None, repr=False)

    @property
    def stats(self) -> OffloadStats:
        """Ledger totals — same read API as the pre-§10 in-trace counters."""
        return self.ledger.totals

    def should_offload(self, m: int, k: int, n: int, name: str = "linear") -> bool:
        mm = MulMat(name, m=m, k=k, n=n)
        return fits(mm, self.vmem_budget_kb, optimized=True, agg_units=1)

    # -- planning ---------------------------------------------------------
    def plan_entry(self, m: int, k: int, n: int, *, quantized: bool,
                   name: str = "linear") -> PlanEntry:
        """Resolve routing for one static shape (pure; DESIGN.md §10.1).
        The entry pins the registry backend (DESIGN.md §12.3), translated
        from this engine's legacy ``prefer_pallas`` tri-state."""
        return plan_linear(name, m, k, n, quantized=quantized,
                           vmem_budget_kb=self.vmem_budget_kb,
                           default_burst=self.burst, tuner=self.tuner,
                           backend=pin_for_prefer(self.prefer_pallas),
                           mesh_sig=self.mesh_sig)

    @contextmanager
    def recording(self, plan: DispatchPlan):
        """While active, every ``linear`` call appends its ``PlanEntry`` to
        ``plan`` instead of accounting to the ledger — used under abstract
        tracing (``plan.record_plan``) to capture a program's routing."""
        prev, self._recording = self._recording, plan
        try:
            yield plan
        finally:
            self._recording = prev

    # -- execution --------------------------------------------------------
    def linear(self, x: jax.Array, w, name: str = "linear") -> jax.Array:
        """y = x @ W^T, routed per the trace-time plan entry for this
        shape. Pure under tracing: the entry derives from static shapes,
        the kernel call is functional, and accounting only happens on
        concrete (eager) inputs or into an explicit recording plan —
        never as a side effect inside someone else's ``jax.jit`` trace."""
        k = x.shape[-1]
        n = w.shape[0]
        m = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
        entry = self.plan_entry(m, k, n, quantized=isinstance(w, QTensor),
                                name=name)
        y = self.execute(x, w, entry)
        if self._recording is not None:
            self._recording.add(entry)
        elif not isinstance(x, jax.core.Tracer):
            self.ledger.account(entry)
            # eager accounts land outside any ledger span; claiming them
            # on the active telemetry keeps the DESIGN.md §16.2 exact
            # span-FLOP == ledger-delta invariant under mixed usage
            from repro import obs
            tele = obs.active()
            if tele is not None and tele._ledger is self.ledger:
                tele.claim_eager(entry)
        return y

    def execute(self, x: jax.Array, w, entry: PlanEntry) -> jax.Array:
        """Run one linear per a resolved ``PlanEntry`` — a pure function of
        ``(x, w, entry)`` plus engine path config (DESIGN.md §10.1). The
        entry pins burst, tiling AND backend; ``registry.dispatch`` (via
        the executor) is the only place a kernel implementation is
        selected — no backend conditionals here (DESIGN.md §12.3)."""
        return executor.matmul(x, w, burst=entry.burst,
                               backend=entry.backend, tiling=entry.tiling,
                               interpret=self.interpret,
                               forceable=entry.offload)
