"""Offload dispatcher — the paper's co-design loop as a runtime feature.

Given a linear layer's shapes and the configured VMEM budget + burst, decide
per-invocation (like IMAX's per-``ggml_mul_mat`` decision) whether the main
segment runs on the accelerator kernel or falls back to the host/XLA path,
and account the PDP consequences. This is the glue between:

  coverage.py  (does the working set fit the local-memory budget?)
  bursts.py    (which granularity minimizes the PDP proxy?)
  mixed_exec   (aligned main + residual split)
  kernels.ops  (the actual compute paths)
  energy.py    (PDP/EDP accounting per step)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.coverage import MulMat, fits
from repro.core.mixed_exec import select_burst, split_aligned
from repro.core.qformats import QTensor
from repro.kernels import ops
from repro.tuning import Autotuner, kernel_for, padded_m


@dataclass
class OffloadStats:
    """Per-run accounting (feeds the Fig 12 exec-breakdown benchmark)."""
    offloaded_calls: int = 0
    fallback_calls: int = 0
    offloaded_flops: int = 0
    fallback_flops: int = 0
    residual_flops: int = 0
    tuned_calls: int = 0        # offloads that ran on a tuned tiling
    by_kernel: Dict[str, int] = field(default_factory=dict)

    def offload_rate(self) -> float:
        t = self.offloaded_calls + self.fallback_calls
        return self.offloaded_calls / t if t else 0.0

    def offload_flop_rate(self) -> float:
        t = self.offloaded_flops + self.fallback_flops
        return self.offloaded_flops / t if t else 0.0


@dataclass
class OffloadEngine:
    """The dispatcher. ``vmem_budget_kb`` is the LMM-size analog (per-core
    VMEM claim allowed for one invocation's working set; agg_units=1 on TPU);
    ``burst`` is the lane granularity from the burst sweep — the *untuned*
    fallback when no ``tuner`` is attached. With a ``tuner``
    (tuning.Autotuner), both the split granularity and the kernel tile
    shapes come from the persistent tuning cache (DESIGN.md §9.4): a cache
    hit is a dict lookup, so steady-state dispatch stays cheap."""
    vmem_budget_kb: int = 8 * 1024      # half of v5e's ~16 MiB VMEM
    burst: int = 256
    prefer_pallas: Optional[bool] = None
    interpret: Optional[bool] = None
    tuner: Optional[Autotuner] = None
    stats: OffloadStats = field(default_factory=OffloadStats)

    def should_offload(self, m: int, k: int, n: int, name: str = "linear") -> bool:
        mm = MulMat(name, m=m, k=k, n=n)
        return fits(mm, self.vmem_budget_kb, optimized=True, agg_units=1)

    def _select_burst(self, m: int, k: int, n: int, quantized: bool):
        """(burst, tuned?) for this invocation class; engine default when
        untuned or nothing admissible under the tuner's VMEM budget."""
        if self.tuner is None:
            return self.burst, False
        kern = kernel_for(m, quantized)
        dtype = "q8_0" if quantized else "bf16"
        burst = select_burst(k, self.tuner, kernel=kern, m=padded_m(m), n=n,
                             dtype=dtype, default=0)
        return (burst, True) if burst else (self.burst, False)

    def linear(self, x: jax.Array, w, name: str = "linear") -> jax.Array:
        """y = x @ W^T with per-invocation offload decision + accounting."""
        k = x.shape[-1]
        n = w.shape[0] if not isinstance(w, QTensor) else w.shape[0]
        m = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
        flops = 2 * m * k * n
        quantized = isinstance(w, QTensor)
        burst, tuned = self._select_burst(m, k, n, quantized)
        k_main, k_res = split_aligned(k, burst)
        offload = self.should_offload(m, k, n, name)
        if offload:
            self.stats.offloaded_calls += 1
            if tuned:
                self.stats.tuned_calls += 1
            self.stats.offloaded_flops += flops * k_main // max(k, 1)
            self.stats.residual_flops += flops * k_res // max(k, 1)
            y = ops.matmul(x, w, burst=burst,
                           prefer_pallas=self.prefer_pallas,
                           interpret=self.interpret,
                           tuner=self.tuner)
        else:
            self.stats.fallback_calls += 1
            self.stats.fallback_flops += flops
            y = ops.matmul(x, w, burst=burst, prefer_pallas=False)
        self.stats.by_kernel[name] = self.stats.by_kernel.get(name, 0) + 1
        return y
