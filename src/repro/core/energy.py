"""PDP/EDP energy model (paper Eq. 1-3, Table 3, Fig 7/9/10).

PDP = execution time x power; EDP = PDP x time. GPU platforms use nominal
TDP (the paper's §4.1 methodology); IMAX powers come from the paper's 28 nm
Synopsys DC synthesis; the TPU-v5e projection (beyond-paper) uses the
roofline-derived step time x a TDP-class chip power.

All constants below are the paper's own measurements — they make the
cross-platform tables (Fig 8/9), the burst sweep (Fig 10), and the LMM power
curve (Fig 7) reproducible as analytical experiments on this CPU-only host.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

# ---------------------------------------------------------------------------
# Platform power constants (paper Table 3)
# ---------------------------------------------------------------------------
P_ARM_A72_W = 0.6485          # 2-core Cortex-A72 active
P_ARM_IDLE_W = 0.2487         # derived from §4.4 system powers (2xP_lane + idle)
P_JETSON_W = 15.0             # AGX Orin lowest-power mode (TDP)
P_RTX4090_W = 450.0           # nominal TDP
P_IMAX_FPGA_W = 180.0         # VPK180 board
TPU_V5E_W = 170.0             # TDP-class per-chip power assumption (DESIGN.md §6.2)

# IMAX 28 nm per-lane synthesized power by kernel path (Fig 7 / §4.1, 32 KB LMM)
P_IMAX_LANE_FP16_W = 0.647
P_IMAX_LANE_Q8_W = 1.32

# Per-LMM-size per-lane FP16 power (Fig 7; 16->32 KB adds only 10 mW)
LMM_POWER_FP16_W: Dict[int, float] = {
    8: 0.630, 16: 0.637, 32: 0.647, 64: 0.699, 128: 0.803, 256: 1.011,
}
# Q8_0 path: same LMM scaling, offset by the wider integer datapath
_Q8_OFFSET = P_IMAX_LANE_Q8_W - P_IMAX_LANE_FP16_W
LMM_POWER_Q8_W: Dict[int, float] = {k: v + _Q8_OFFSET for k, v in LMM_POWER_FP16_W.items()}

# Burst-length dependent per-lane power (§4.4): 14/22/38 active PEs
BURST_POWER_LANE_W: Dict[int, float] = {8: 0.424, 16: 0.647, 32: 1.09}
BURST_ACTIVE_PES: Dict[int, int] = {8: 14, 16: 22, 32: 38}

# Paper-measured burst-sweep times for Whisper-tiny.en FP16, 32 KB LMM,
# 2 lanes + 2 host threads (§4.4: T_MAIN wall-clock; T_active derived
# from prompt_eval + token_gen lane timings).
BURST_T_MAIN_S: Dict[int, float] = {8: 48.3, 16: 35.8, 32: 34.7}

# Projected 28 nm E2E latencies (§5.6) and paper PDP results (Fig 9), used
# as validation targets by benchmarks/EXPERIMENTS.md.
PAPER_LATENCY_28NM_S = {
    ("tiny", "fp16"): 15.39, ("tiny", "q8_0"): 10.71,
}
PAPER_PDP_J = {
    ("tiny", "fp16", "imax"): 12.65, ("tiny", "q8_0", "imax"): 11.58,
    ("tiny", "fp16", "jetson"): 22.59, ("tiny", "q8_0", "jetson"): 27.16,
    ("tiny", "q8_0", "rtx4090"): 121.38,
    ("base", "fp16", "imax"): 29.43, ("base", "q8_0", "imax"): 22.16,
    ("base", "fp16", "jetson"): 25.98, ("base", "q8_0", "jetson"): 26.09,
    ("small", "fp16", "imax"): 103.84, ("small", "q8_0", "imax"): 125.31,
    ("small", "fp16", "jetson"): 52.41, ("small", "q8_0", "jetson"): 51.57,
}


# ---------------------------------------------------------------------------
# Metrics (Eq. 1-3)
# ---------------------------------------------------------------------------
def pdp(time_s: float, power_w: float) -> float:
    """Eq. 1: PDP = execution time x power consumption [J]."""
    return time_s * power_w


def edp(time_s: float, power_w: float) -> float:
    """EDP = PDP x time [J*s]."""
    return pdp(time_s, power_w) * time_s


def pdp_mixed(t_active_s: float, t_main_s: float,
              p_accel_w: float, p_host_w: float = P_ARM_A72_W) -> float:
    """Eq. 2: accelerator-active phase at P_accel, remainder at P_host."""
    if t_active_s > t_main_s:
        raise ValueError("t_active exceeds t_main")
    return t_active_s * p_accel_w + (t_main_s - t_active_s) * p_host_w


def edp_mixed(t_active_s: float, t_main_s: float,
              p_accel_w: float, p_host_w: float = P_ARM_A72_W) -> float:
    """Eq. 3: EDP_burst = PDP_burst x T_MAIN."""
    return pdp_mixed(t_active_s, t_main_s, p_accel_w, p_host_w) * t_main_s


def system_power_burst(burst: int, lanes: int = 2) -> float:
    """§4.4 system power: lanes x P_lane(burst) + ARM idle."""
    return lanes * BURST_POWER_LANE_W[burst] + P_ARM_IDLE_W


def lmm_power(size_kb: int, path: str = "fp16", lanes: int = 1) -> float:
    """Fig 7: synthesized per-lane power as a function of LMM size."""
    table = LMM_POWER_FP16_W if path == "fp16" else LMM_POWER_Q8_W
    if size_kb not in table:
        raise KeyError(f"no synthesis point for {size_kb} KB")
    return lanes * table[size_kb]


# ---------------------------------------------------------------------------
# TPU projection (beyond-paper): roofline time -> PDP/EDP
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class EnergyReport:
    platform: str
    time_s: float
    power_w: float

    @property
    def pdp_j(self) -> float:
        return pdp(self.time_s, self.power_w)

    @property
    def edp_js(self) -> float:
        return edp(self.time_s, self.power_w)


def tpu_projection(step_time_s: float, chips: int = 1,
                   chip_power_w: float = TPU_V5E_W) -> EnergyReport:
    """PDP of one step on a TPU slice under the TDP-normalized model —
    the same methodology the paper applies to Jetson/RTX."""
    return EnergyReport("tpu_v5e", step_time_s, chips * chip_power_w)
