"""Burst-length (execution-granularity) selection (paper §3.2, §4.4, Fig 10).

Two layers:

1. **Paper reproduction** — `paper_burst_sweep()` recomputes PDP/EDP for
   bursts {8,16,32} from the paper's measured T_MAIN and synthesized powers
   via Eq. 2/3, confirming burst 16 is PDP- and EDP-optimal (42.2 J /
   1511 J*s).

2. **TPU analog** — `tile_sweep_report()` evaluates the lane-granularity
   analog {128,256,512} for our Pallas kernels: residual fraction from the
   workload's vector-length distribution (the alignment term), VMEM claim
   per tile (the LMM term), and a grid-overhead model (the per-burst
   invocation overhead term). `core/offload.py` consumes the chosen point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.core import energy
from repro.core.coverage import MulMat
from repro.core.mixed_exec import residual_fraction

PAPER_BURSTS = (8, 16, 32)
TPU_TILE_BURSTS = (128, 256, 512)   # MXU-lane analog of 8/16/32 (DESIGN.md §6.4)


@dataclass(frozen=True)
class BurstPoint:
    burst: int
    t_main_s: float
    t_active_s: float
    power_w: float
    pdp_j: float
    edp_js: float


def _t_active(burst: int, t_main: float) -> float:
    """Derive the accelerator-active time from the calibration in §4.4:
    the measured burst-16 point gives T_active = 21.2 s out of 35.8 s; the
    active fraction scales with the per-burst execution efficiency."""
    # Active work is the offloaded GEMM; its time scales ~ (1 + c/burst)
    # against the burst-16 anchor (per-invocation overhead amortization).
    t16_active = 21.2
    c = 8.0  # overhead constant fit to the 8->16 latency drop
    rel = (1.0 + c / burst) / (1.0 + c / 16.0)
    return min(t16_active * rel, t_main)


def paper_burst_sweep(lanes: int = 2) -> List[BurstPoint]:
    """Fig 10 reproduction from the paper's measured times + powers."""
    out = []
    for b in PAPER_BURSTS:
        tm = energy.BURST_T_MAIN_S[b]
        ta = _t_active(b, tm)
        p_sys = energy.system_power_burst(b, lanes)
        out.append(BurstPoint(
            burst=b, t_main_s=tm, t_active_s=ta, power_w=p_sys,
            pdp_j=energy.pdp_mixed(ta, tm, p_sys),
            edp_js=energy.edp_mixed(ta, tm, p_sys),
        ))
    return out


def optimal_burst(points: Sequence[BurstPoint], metric: str = "pdp") -> BurstPoint:
    key = (lambda p: p.pdp_j) if metric == "pdp" else (lambda p: p.edp_js)
    return min(points, key=key)


# ---------------------------------------------------------------------------
# TPU tile-granularity analog
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TilePoint:
    burst: int                 # lane tile (block_k)
    residual_flop_frac: float  # work stuck on the VPU/jnp path
    vmem_claim_bytes: int      # per-tile VMEM working set (x, w, out, acc)
    grid_overhead: float       # relative per-invocation overhead ~ 1 + c/b
    score: float               # lower is better (PDP-proxy)


def tile_sweep_report(mulmats: Sequence[MulMat],
                      block_m: int = 128, block_n: int = 256,
                      bursts: Sequence[int] = TPU_TILE_BURSTS,
                      dtype_bytes: int = 1) -> List[TilePoint]:
    """Score each candidate lane granularity on the workload's vector-length
    distribution. Mirrors the paper's three-way trade-off: bigger bursts
    amortize overhead but strand more residual work and claim more VMEM.
    ``dtype_bytes=1`` for the Q8_0 weight path."""
    total_flops = sum(m.flops for m in mulmats) or 1
    out = []
    for b in bursts:
        resid = sum(m.flops * residual_fraction(m.k, b) for m in mulmats) / total_flops
        # VMEM claim per grid step: x tile (bm x bk, bf16) + w tile (bn x bk, q8)
        # + scales + f32 accumulator + out tile.
        vmem = (block_m * b * 2 + block_n * b * dtype_bytes +
                block_n * (b // 32) * 4 + block_m * block_n * 4 * 2)
        over = 1.0 + 128.0 / b
        # PDP proxy: host-residual work costs ~8x the accel path (Amdahl
        # kernel speedup), overhead multiplies accel time, VMEM claim is a
        # constraint (hard-penalize > 75% of 16 MiB v5e VMEM).
        accel = (1.0 - resid) * over
        host = resid * 8.0
        penalty = 1e6 if vmem > 0.75 * 16 * 2**20 else 0.0
        out.append(TilePoint(b, resid, vmem, over, accel + host + penalty))
    return out


def select_tile_burst(mulmats: Sequence[MulMat], **kw) -> int:
    return min(tile_sweep_report(mulmats, **kw), key=lambda p: p.score).burst
