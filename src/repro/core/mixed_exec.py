"""Mixed execution (paper §3.2): burst-aligned main segment on the
accelerator, residual on the host — the accelerator never sees a partial
burst.

Paper: each vector of length L splits into a main segment of ⌊L/b⌋·b
(offloaded to IMAX) and a residual of L mod b (run concurrently on the ARM
host). On TPU the same split removes tile padding: the main segment feeds the
Pallas/MXU kernel with exactly-full tiles; the residual is a skinny jnp
contraction on the VPU. The two partial sums add — bit-compatible with the
monolithic oracle in f32.

For Whisper's static dims (384, 1536, 64 — all multiples of 16/128 after the
lane re-scaling of DESIGN.md §2) the residual is empty, which is exactly the
paper's zero-residual claim for the principal kernels.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax.numpy as jnp

from repro.core.qformats import QBLOCK, QTensor


def split_point(length: int, burst: int) -> int:
    """⌊L/b⌋·b — the aligned main-segment length."""
    if burst <= 0:
        raise ValueError("burst must be positive")
    return (length // burst) * burst


def split_aligned(length: int, burst: int) -> Tuple[int, int]:
    """(main_len, residual_len) with main_len % burst == 0."""
    m = split_point(length, burst)
    return m, length - m


def mixed_matmul(x: jnp.ndarray,
                 w: jnp.ndarray,
                 burst: int,
                 main_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]):
    """y = x @ w.T with the K-contraction split at the burst boundary.

    x: (..., K); w: (N, K).  ``main_fn`` runs the aligned segment (the
    accelerator path); the residual always runs as a plain jnp contraction
    (the host path). Returns f32.
    """
    k = x.shape[-1]
    k_main, k_res = split_aligned(k, burst)
    parts = []
    if k_main:
        parts.append(main_fn(x[..., :k_main], w[:, :k_main]))
    if k_res:
        parts.append(jnp.einsum("...k,nk->...n",
                                x[..., k_main:].astype(jnp.float32),
                                w[:, k_main:].astype(jnp.float32)))
    if not parts:
        return jnp.zeros((*x.shape[:-1], w.shape[0]), jnp.float32)
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


def mixed_matmul_q8(x: jnp.ndarray,
                    wq: QTensor,
                    burst: int,
                    main_fn) -> jnp.ndarray:
    """Quantized variant. ``burst`` must be a multiple of the Q8_0 block (32)
    so the main segment covers whole quantization blocks (the paper's bursts
    of 16 elements hold whole 8-bit packed words for the same reason)."""
    if burst % QBLOCK != 0:
        raise ValueError(f"burst {burst} must be a multiple of QBLOCK={QBLOCK}")
    k = x.shape[-1]
    k_main, k_res = split_aligned(k, burst)
    nb = k_main // QBLOCK
    parts = []
    if k_main:
        main_q = QTensor(qs=wq.qs[..., :nb, :], scales=wq.scales[..., :nb])
        parts.append(main_fn(x[..., :k_main], main_q))
    if k_res:
        # residual weights dequantized on the host path
        tail_q = QTensor(qs=wq.qs[..., nb:, :], scales=wq.scales[..., nb:])
        w_tail = tail_q.qs.astype(jnp.float32) * tail_q.scales[..., None]
        w_tail = w_tail.reshape(*w_tail.shape[:-2], k_res)
        parts.append(jnp.einsum("...k,nk->...n",
                                x[..., k_main:].astype(jnp.float32), w_tail))
    if not parts:
        return jnp.zeros((*x.shape[:-1], wq.shape[0]), jnp.float32)
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


def select_burst(k: int, tuner=None, *, kernel: str = "q8_matmul",
                 m: int = 1, n: int = 1, dtype: str = "q8_0",
                 default: int = 256) -> int:
    """Pick the split granularity for a (M,K)x(N,K) invocation: the tuned
    ``block_k`` (the burst-length analog, DESIGN.md §9.4) when an autotuner
    is attached and an admissible tiling exists for the full-K problem, else
    ``default``. The tuned value always satisfies the whole-Q8_0-block rule
    because the candidate space enforces it. Pure apart from tuner-cache
    warming, so trace-time planning (``core/plan.py``, DESIGN.md §10.1)
    calls it to resolve each entry's burst from static shapes."""
    if tuner is None:
        return default
    rec = tuner.best_tiling(kernel, m, n, k, dtype)
    return rec.block_k if rec else default


def residual_fraction(length: int, burst: int) -> float:
    """Fraction of work left on the host path (paper §3.2's three-way
    trade-off: larger bursts raise this for non-aligned lengths)."""
    if length == 0:
        return 0.0
    return (length % burst) / length
