"""Mixed execution (paper §3.2): burst-aligned main segment on the
accelerator, residual on the host — the accelerator never sees a partial
burst.

Paper: each vector of length L splits into a main segment of ⌊L/b⌋·b
(offloaded to IMAX) and a residual of L mod b (run concurrently on the ARM
host). On TPU the same split removes tile padding: the main segment feeds the
Pallas/MXU kernel with exactly-full tiles; the residual is a skinny jnp
contraction on the VPU. The two partial sums add — bit-compatible with the
monolithic oracle in f32.

For Whisper's static dims (384, 1536, 64 — all multiples of 16/128 after the
lane re-scaling of DESIGN.md §2) the residual is empty, which is exactly the
paper's zero-residual claim for the principal kernels.

The split *arithmetic* (``split_point``/``split_aligned``/
``residual_fraction``) is canonical here; the split *execution* moved to
``repro.backends.executor`` (DESIGN.md §12), which dispatches each segment
through the backend registry. ``mixed_matmul``/``mixed_matmul_q8`` remain
as deprecation-documented shims so existing callers and tests stay green.
"""
from __future__ import annotations

from typing import Tuple

from repro.core.qformats import QTensor


def split_point(length: int, burst: int) -> int:
    """⌊L/b⌋·b — the aligned main-segment length."""
    if burst <= 0:
        raise ValueError("burst must be positive")
    return (length // burst) * burst


def split_aligned(length: int, burst: int) -> Tuple[int, int]:
    """(main_len, residual_len) with main_len % burst == 0."""
    m = split_point(length, burst)
    return m, length - m


def mixed_matmul(x, w, burst: int, main_fn):
    """y = x @ w.T with the K-contraction split at the burst boundary.

    .. deprecated:: shim over ``backends.executor.split_matmul``
       (DESIGN.md §12.3). ``main_fn`` still runs the aligned segment (the
       legacy accelerator-path override); the residual now dispatches
       through the registry, resolving to the host_residual backend — the
       same f32 jnp contraction that used to be inline here. Returns f32.
    """
    from repro.backends import executor
    return executor.split_matmul(x, w, burst, main_fn=main_fn)


def mixed_matmul_q8(x, wq: QTensor, burst: int, main_fn):
    """Quantized variant of ``mixed_matmul``. ``burst`` must be a multiple
    of the Q8_0 block (32) so the main segment covers whole quantization
    blocks (the paper's bursts of 16 elements hold whole 8-bit packed words
    for the same reason).

    .. deprecated:: shim over ``backends.executor.split_matmul``
       (DESIGN.md §12.3) — the executor slices the QTensor per segment and
       the host_residual backend dequantizes the tail, exactly the code
       that used to live inline here.
    """
    from repro.backends import executor
    return executor.split_matmul(x, wq, burst, main_fn=main_fn)


def select_burst(k: int, tuner=None, *, kernel: str = "q8_matmul",
                 m: int = 1, n: int = 1, dtype: str = "q8_0",
                 default: int = 256) -> int:
    """Pick the split granularity for a (M,K)x(N,K) invocation: the tuned
    ``block_k`` (the burst-length analog, DESIGN.md §9.4) when an autotuner
    is attached and an admissible tiling exists for the full-K problem, else
    ``default``. The tuned value always satisfies the whole-Q8_0-block rule
    because the candidate space enforces it. Pure apart from tuner-cache
    warming, so trace-time planning (``core/plan.py``, DESIGN.md §10.1)
    calls it to resolve each entry's burst from static shapes."""
    if tuner is None:
        return default
    rec = tuner.best_tiling(kernel, m, n, k, dtype)
    return rec.block_k if rec else default


def residual_fraction(length: int, burst: int) -> float:
    """Fraction of work left on the host path (paper §3.2's three-way
    trade-off: larger bursts raise this for non-aligned lengths)."""
    if length == 0:
        return 0.0
    return (length % burst) / length
