"""GGML-compatible Q8_0 block quantization (paper §3.2/§4.2; DESIGN.md §3).

Q8_0: blocks of 32 values; per-block scale d = amax/127 stored in fp16;
quantized values q = round(x/d) in int8. The paper consumes whisper.cpp's
Q8_0 data unmodified; we implement the same format so the reconstruction
error figures of §4.2 (MAE 1.39e-4, RMSE 2.09e-4, max 3.41e-3 and relative
L2 8.31e-3 on Whisper-tiny.en FP16 weights) are directly checkable.

Storage convention for a weight matrix W[N, K] (out_features, in_features):
  qs:     int8  [N, K//32, 32]   (kernels consume the flattened [N, K] view)
  scales: f32   [N, K//32]       (values round-trip through fp16, as GGML)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 32  # GGML Q8_0 block size


class QTensor(NamedTuple):
    """A Q8_0-quantized tensor. Leading dims arbitrary; last dim blocked."""
    qs: jax.Array        # int8, shape (..., K//QBLOCK, QBLOCK)
    scales: jax.Array    # f32 (fp16-valued), shape (..., K//QBLOCK)

    @property
    def k(self) -> int:
        return self.qs.shape[-2] * self.qs.shape[-1]

    @property
    def shape(self):
        return (*self.qs.shape[:-2], self.k)

    def flat_qs(self) -> jax.Array:
        """int8 view with blocks flattened back into K: shape (..., K)."""
        return self.qs.reshape(*self.qs.shape[:-2], self.k)

    def nbytes(self) -> int:
        # int8 payload + fp16 scale per block (GGML block_q8_0 = 34 bytes/32)
        return int(np.prod(self.qs.shape)) + 2 * int(np.prod(self.scales.shape))


def quantize_q8_0(w: jax.Array) -> QTensor:
    """Quantize along the last axis in blocks of 32. K must divide by 32."""
    *lead, k = w.shape
    if k % QBLOCK != 0:
        raise ValueError(f"K={k} not a multiple of {QBLOCK}; pad or use "
                         "mixed_exec.split_aligned for the residual")
    blocks = w.astype(jnp.float32).reshape(*lead, k // QBLOCK, QBLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    d = (amax / 127.0).astype(jnp.float16).astype(jnp.float32)  # GGML stores fp16
    inv = jnp.where(d > 0, 1.0 / d, 0.0)
    # GGML roundf() is round-half-away-from-zero
    q = blocks * inv[..., None]
    q = jnp.sign(q) * jnp.floor(jnp.abs(q) + 0.5)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return QTensor(qs=q, scales=d)


def dequantize_q8_0(t: QTensor) -> jax.Array:
    """Exact inverse map (float32)."""
    w = t.qs.astype(jnp.float32) * t.scales[..., None]
    return w.reshape(t.shape)


def reconstruction_error(w: jax.Array, t: QTensor) -> dict:
    """The §4.2 error metrics for a single tensor (or a flattened stack)."""
    w = w.astype(jnp.float32)
    wh = dequantize_q8_0(t)
    err = wh - w
    mae = jnp.mean(jnp.abs(err))
    rmse = jnp.sqrt(jnp.mean(err ** 2))
    mx = jnp.max(jnp.abs(err))
    rel_l2 = jnp.linalg.norm(err.reshape(-1)) / (jnp.linalg.norm(w.reshape(-1)) + 1e-30)
    return {"mae": float(mae), "rmse": float(rmse),
            "max_abs": float(mx), "rel_l2": float(rel_l2),
            "n_values": int(np.prod(w.shape))}


def quantize_tree(params, predicate=None):
    """Quantize every >=2D float leaf whose last dim divides QBLOCK.

    ``predicate(path, leaf) -> bool`` can veto quantization (e.g. keep norms,
    embeddings in fp16 — mirroring whisper.cpp, which keeps 1D tensors fp32).
    Returns a pytree where quantized leaves become QTensor.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)

    def decide(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)):
            return leaf
        if leaf.ndim < 2 or leaf.shape[-1] % QBLOCK != 0:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if predicate is not None and not predicate(path, leaf):
            return leaf
        return quantize_q8_0(leaf)

    leaves = [decide(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def dequantize_tree(params):
    """Inverse of quantize_tree (QTensor leaves -> f32 arrays)."""
    return jax.tree_util.tree_map(
        lambda x: dequantize_q8_0(x) if isinstance(x, QTensor) else x,
        params, is_leaf=lambda x: isinstance(x, QTensor))
