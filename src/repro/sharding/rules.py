"""Sharding rule engine: param/batch/cache pytrees -> PartitionSpecs.

Strategy (DESIGN.md §7; MaxText-style 2D sharding on a fixed mesh):

  * "model" axis (16)           — tensor parallelism: attention heads, d_ff,
                                  vocab, MoE experts (EP), SSD inner dim.
  * "data" axis (16)            — batch DP + FSDP weight sharding (ZeRO-3
                                  within a pod): the *other* matrix dim of
                                  every big weight shards here, so per-device
                                  param bytes scale 1/(data*model).
  * "pod" axis (2, multi-pod)   — pure DP across pods: params replicated
                                  pod-wise (cheap intra-pod all-gathers stay
                                  on-pod; only gradient all-reduce crosses).

Every rule is divisibility-checked against the actual mesh: a dim that does
not divide falls back down the candidate list (e.g. whisper's vocab 51865 on
a 16-way model axis -> replicated). This keeps one rule set valid for all 10
architectures x 3 meshes, which is what makes the 40-cell dry-run tractable.

Rules are keyed on path regexes over the param tree ('attn/q/w', 'moe/w_up',
...). Q8_0 QTensor leaves ('.../w/qs', '.../w/scales') inherit the dense w's
out-dim sharding, so the serving path shards identically to training.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Candidate tokens: each dim gets a list of candidates, first divisible wins.
#   "model"  -> the model axis
#   "fsdp"   -> the data axis (weight sharding within a pod)
#   "batch"  -> (pod, data) combined (activations' batch dim)
#   "expert" -> the model axis (EP), kept distinct for readability
#   None     -> replicated
MODEL, FSDP, BATCH, EXPERT = "model", "fsdp", "batch", "expert"

# (regex over '/'-joined path, trailing-dims candidates, innermost last)
_RULES: Sequence[Tuple[str, Tuple[Tuple[Optional[str], ...], ...]]] = (
    # --- embeddings / readout ---
    (r"embed/table$",        ((MODEL,), (FSDP,))),
    (r"lm_head/w$",          ((MODEL,), (FSDP,))),
    (r"(enc_pos|dec_pos)/table$", ((), (FSDP,))),
    (r"projector/w$",        ((FSDP,), ())),
    (r"frontend/w$",         ((FSDP,), ())),
    # --- attention (w stored (out, in)) ---
    (r"attn/q/w$",           ((MODEL,), (FSDP,))),
    (r"attn/[kv]/w$",        ((MODEL,), (FSDP,))),
    (r"attn/o/w$",           ((FSDP,), (MODEL,))),
    (r"attn/[qkvo]/b$",      ((MODEL,),)),
    # --- dense FFN ---
    (r"(up|gate)/w$",        ((MODEL,), (FSDP,))),
    (r"down/w$",             ((FSDP,), (MODEL,))),
    (r"(up|gate|down)/b$",   ((MODEL,),)),
    # --- MoE (expert-stacked (E, in, out)) ---
    (r"moe/router/w$",       ((), (FSDP,))),
    (r"moe/w_(up|gate)$",    ((EXPERT,), (FSDP,), ())),
    (r"moe/w_down$",         ((EXPERT,), (), (FSDP,))),
    # --- SSD mixer ---
    (r"ssm/in_proj/w$",      ((MODEL,), (FSDP,))),
    (r"ssm/out_proj/w$",     ((FSDP,), (MODEL,))),
    (r"ssm/conv_[wb]$",      None),        # tiny; replicate
    (r"ssm/(A_log|D|dt_bias)$", None),
    # --- norms and everything 1D ---
    (r"norm", None),
)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _resolve(token: Optional[str], mesh: Mesh):
    """Token -> (mesh axes tuple, total size)."""
    if token is None:
        return None, 1
    if token in (MODEL, EXPERT):
        return ("model",), _axis_size(mesh, "model")
    if token == FSDP:
        return ("data",), _axis_size(mesh, "data")
    if token == BATCH:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        size = int(np.prod([_axis_size(mesh, a) for a in axes])) if axes else 1
        return axes or None, size
    raise ValueError(token)


def _dim_entry(candidates, dim: int, mesh: Mesh):
    """First divisible candidate for one dim. candidates: tuple of tokens."""
    for tok in candidates:
        axes, size = _resolve(tok, mesh)
        if axes is None:
            return None
        if size > 1 and dim % size == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def _spec_from_template(template, shape, mesh: Mesh) -> P:
    """Right-align the trailing-dim template against ``shape`` (leading
    stacked-layer dims replicate) and divisibility-check each entry."""
    if template is None:
        return P()
    ndim = len(shape)
    t = len(template)
    entries = [None] * (ndim - t) if ndim >= t else []
    tpl = template[-ndim:] if t > ndim else template
    for cand, dim in zip(tpl, shape[ndim - len(tpl):]):
        entries.append(_dim_entry(cand, dim, mesh))
    # a mesh axis may appear at most once per spec: first dim wins
    seen = set()
    for i, e in enumerate(entries):
        axes = e if isinstance(e, tuple) else ((e,) if e else ())
        if any(a in seen for a in axes):
            entries[i] = None
        seen.update(axes)
    # strip trailing Nones for tidier specs
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


_FALLBACK_2D = ((MODEL,), (FSDP,))


def spec_for_path(path_str: str, shape, mesh: Mesh) -> P:
    """The rule lookup for one leaf. QTensor legs map onto the dense rule."""
    # Q8_0 leaves: '<w-path>/qs' (N, K/32, 32) and '<w-path>/scales' (N, K/32)
    q_m = re.search(r"(.*)/(qs|scales)$", path_str)
    lookup = q_m.group(1) if q_m else path_str
    template = _FALLBACK_2D if len(shape) >= 2 else None
    for pattern, tpl in _RULES:
        if re.search(pattern, lookup):
            template = tpl
            break
    if q_m and template is not None:
        # Quantized legs mirror the dense rule. qs = W with its last dim
        # split (..., K) -> (..., K/32, 32): append a replicated intra-block
        # entry so every leading rule stays aligned (right-alignment then
        # puts the dense K rule on the K/32 dim). scales = W with K -> K/32:
        # the dense template applies unchanged. Divisibility checks and the
        # duplicate-axis guard handle the rest.
        if q_m.group(2) == "qs":
            template = (*template, ())
    return _spec_from_template(template, shape, mesh)


def param_specs(params, mesh: Mesh):
    """PartitionSpec pytree matching ``params`` (works for opt-state pytrees
    too — they mirror param paths)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: (P() if not getattr(l, "shape", ())
                      else spec_for_path(_path_str(p), l.shape, mesh)),
        params)


# ---------------------------------------------------------------------------
# Batch / activation / cache specs
# ---------------------------------------------------------------------------
def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(batch: dict, mesh: Mesh):
    """Shard every batch leaf's dim 0 over (pod, data) when divisible;
    otherwise (long_500k's B=1) shard the sequence dim over data."""
    axes = _batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def leaf(path, x):
        shape = x.shape
        if not shape:
            return P()
        if shape[0] % bsize == 0 and bsize > 1:
            return P(axes if len(axes) > 1 else axes[0])
        if len(shape) >= 2 and shape[1] % _axis_size(mesh, "data") == 0:
            return P(None, "data")
        return P()

    return jax.tree_util.tree_map_with_path(leaf, batch)


def cache_specs(state, mesh: Mesh, kv_heads: int, head_dim: int):
    """Decode-state specs.

    KV caches are stacked (R, B, S, Hkv, hd): batch shards over (pod, data)
    when divisible; the model axis lands on Hkv when it divides, otherwise
    on S (flash-decode sequence parallelism — each model shard owns a cache
    slice; models/attention.py places the matching constraint). For B=1
    long-context cells S takes every available axis.
    SSM states (R, B, H, P, N) shard H over model; conv states shard their
    channel dim over model.
    """
    axes = _batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    baxis = axes if len(axes) > 1 else (axes[0] if axes else None)
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")

    def leaf(path, x):
        shape = x.shape
        ps = _path_str(path).lower()
        if len(shape) <= 1:
            return P()
        entries = [None] * len(shape)
        bdim = 1  # leading dim is the stacked layer dim R
        batch_ok = shape[bdim] % bsize == 0 and bsize > 1
        if batch_ok:
            entries[bdim] = baxis
        leaf_name = ps.rsplit("/", 1)[-1]
        if "conv" in ps:  # (R, B, K, conv_dim)
            if len(shape) >= 4 and shape[-1] % msize == 0:
                entries[-1] = "model"
        elif leaf_name in ("k_scale", "v_scale") and len(shape) == 4:
            # int8-KV scales (R, B, S, Hkv): mirror the payload's S policy
            if shape[3] % msize == 0:
                entries[3] = "model"
            elif batch_ok and shape[2] % msize == 0:
                entries[2] = "model"
            elif not batch_ok:
                s_axes = tuple(a for a, sz in (("data", dsize),
                                               ("model", msize)) if sz > 1)
                sz = int(np.prod([mesh.shape[a] for a in s_axes])) or 1
                if s_axes and shape[2] % sz == 0:
                    entries[2] = s_axes if len(s_axes) > 1 else s_axes[0]
        elif len(shape) == 5:
            is_kv = leaf_name in ("k", "v", "k_qs", "v_qs") or "kv" in ps
            if is_kv:  # (R, B, S, Hkv, hd)
                if shape[3] % msize == 0:
                    entries[3] = "model"
                    if not batch_ok and shape[2] % dsize == 0 and dsize > 1:
                        entries[2] = "data"
                else:
                    # S-sharding; B=1 cells put (data, model) both on S
                    if batch_ok:
                        s_axes = ("model",)
                    else:
                        s_axes = tuple(
                            a for a, sz in (("data", dsize), ("model", msize))
                            if sz > 1)
                    sz = int(np.prod([mesh.shape[a] for a in s_axes])) or 1
                    if s_axes and shape[2] % sz == 0:
                        entries[2] = s_axes if len(s_axes) > 1 else s_axes[0]
            else:      # ssd state (R, B, H, P, N)
                if shape[2] % msize == 0:
                    entries[2] = "model"
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf, state)


def train_state_specs(train_state, mesh: Mesh):
    """TrainState {params, opt_state{mu,nu}, step, rng} -> specs. Optimizer
    moments mirror their parameter's spec (path suffix matches)."""
    return param_specs(train_state, mesh)


# ---------------------------------------------------------------------------
# Serving specs (DESIGN.md §13)
# ---------------------------------------------------------------------------
def _strip_axes(spec: P, drop=("data",)) -> P:
    entries = []
    for e in spec:
        axes = e if isinstance(e, tuple) else ((e,) if e is not None else ())
        kept = tuple(a for a in axes if a not in drop)
        entries.append(kept if len(kept) > 1 else
                       (kept[0] if kept else None))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def serve_param_specs(params, mesh: Mesh):
    """Serving-weight specs: the training rules with the FSDP ("data") axis
    stripped, so weights are tensor-parallel over "model" where divisible
    and **replicated** over the slot-DP data axis (DESIGN.md §13). FSDP
    weight sharding is the wrong trade for decode — it turns every layer's
    weight read into a per-step all-gather on the latency path, while the
    slot pool's batch axis is what actually scales with traffic."""
    return jax.tree_util.tree_map(
        _strip_axes, param_specs(params, mesh),
        is_leaf=lambda x: isinstance(x, P))


def paged_state_specs(state, mesh: Mesh):
    """Specs for a paged serve state (DESIGN.md §15.3): the page arenas
    shard their *page* axis over the slot-DP "data" axis (pages are the
    unit of KV memory, so the arena — not the slot axis — is what must
    scale with the mesh), while the per-slot block tables and counters
    shard the slot axis exactly like ``model.slot_state_specs``. The rule
    is structural by leaf name, divisibility-checked per leaf so one call
    site stays valid on any mesh (the rules.py contract)."""
    dsize = _axis_size(mesh, "data")

    def leaf(path, x):
        name = _path_str(path).rsplit("/", 1)[-1]
        if dsize <= 1:
            return P()
        if name in ("self_k", "self_v", "cross_k", "cross_v"):
            # (R, P, page, Hkv, hd): shard the physical-page axis
            return P(None, "data") if x.shape[1] % dsize == 0 else P()
        if name in ("block_table", "cross_table"):
            # (n_slots, max_pages): shard slots
            return P("data") if x.shape[0] % dsize == 0 else P()
        if name == "length":
            # (R, n_slots)
            return P(None, "data") if x.shape[1] % dsize == 0 else P()
        if name == "step":
            # (n_slots,)
            return P("data") if x.shape[0] % dsize == 0 else P()
        return P()

    return jax.tree_util.tree_map_with_path(leaf, state)


def mesh_signature(mesh) -> Optional[Tuple[Tuple[str, int], ...]]:
    """Hashable identity of a mesh's (axis, size) layout — the sharding
    component of plan keys and ``PlanEntry.mesh`` (DESIGN.md §13): a
    sharded program and its unsharded twin at the same shapes must never
    share a plan-cache entry. ``None`` for ``mesh=None`` (unsharded), so
    pre-mesh keys are unchanged. Works on ``Mesh`` and ``AbstractMesh``."""
    if mesh is None:
        return None
    return tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names)


def named(mesh: Mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
