from repro.sharding.rules import (  # noqa: F401
    batch_specs, cache_specs, named, param_specs, spec_for_path,
    train_state_specs,
)
