"""Sharding layer: PartitionSpec rules (DESIGN.md §7), the activation-
constraint context (``ctx``), and the serving-mesh helpers that shard the
slot pool's batch axis over the data axis (DESIGN.md §13)."""
from repro.sharding.rules import (  # noqa: F401
    batch_specs, cache_specs, mesh_signature, named, param_specs,
    serve_param_specs, spec_for_path, train_state_specs,
)
