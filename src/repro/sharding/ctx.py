"""Activation-sharding context: lets model code emit GSPMD sharding
constraints without threading a mesh through every call signature.

``activation_sharding(mesh)`` activates constraints; ``constrain(x, ...)``
is a no-op when no mesh is active (CPU smoke tests) and otherwise applies
``with_sharding_constraint`` with divisibility-checked axes:

    constrain(x, "batch", None, "model", None)

tokens: "batch" -> (pod, data) merged, "model" -> the model axis, "data" ->
the data axis, None -> unconstrained. A token whose axis size does not
divide the dim falls back to None (e.g. whisper's 6 heads on a 16-way model
axis), keeping one call site valid for all architectures.

Why this exists: GSPMD propagation alone loses the batch sharding inside
scanned/checkpointed attention chunks (observed: unsharded f32
[256,...,2048,4096] attention-logit buffers in the whisper train_4k
dry-run). Pinning batch/heads on the handful of big activation tensors
keeps every temp 1/(data*model)-sized without constraining the compiler
elsewhere.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextmanager
def activation_sharding(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def _resolve(token, dim: int, mesh: Mesh):
    if token is None:
        return None
    if token == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if size > 1 and dim % size == 0:
            return axes if len(axes) > 1 else axes[0]
        # fall back to the data axis alone (e.g. batch 8 on a 32-way pod+data)
        if "data" in mesh.axis_names and dim % mesh.shape["data"] == 0 \
                and mesh.shape["data"] > 1:
            return "data"
        return None
    if token == "seq":
        # long-context S dim: absorb every non-pod axis that divides
        axes = tuple(a for a in ("data", "model")
                     if a in mesh.axis_names and mesh.shape[a] > 1)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and dim % size == 0:
            return axes if len(axes) > 1 else axes[0]
        if "model" in mesh.axis_names and dim % mesh.shape["model"] == 0:
            return "model"
        return None
    if token == "model_force":
        # uneven sharding: GSPMD pads the dim to the axis size internally
        # (Megatron-style head padding, e.g. 40 heads -> 16x3). Use when
        # the padding waste beats the alternative's collectives.
        return "model" if "model" in mesh.axis_names else None
    if token in mesh.axis_names:
        return token if dim % mesh.shape[token] == 0 else None
    return None


def batch_shard_size(mesh: Mesh) -> int:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def constrain(x: jax.Array, *tokens) -> jax.Array:
    """Pin ONLY the dims we resolve; everything else stays UNCONSTRAINED.

    Forcing replication on unresolved dims is actively harmful: e.g.
    qwen2.5's 40 heads don't divide the 16-way model axis, and a
    (batch, None, None, None) constraint on its attention logits forced
    GSPMD to all-gather 1.9 TiB of f32 per step that it would otherwise
    have kept partially sharded. UNCONSTRAINED keeps the batch anchor
    (which propagation loses inside scanned remat bodies) without
    overriding the compiler elsewhere.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(tokens) != x.ndim:
        raise ValueError(f"{len(tokens)} tokens for rank-{x.ndim} tensor")
    entries = []
    any_pinned = False
    for t, d in zip(tokens, x.shape):
        r = _resolve(t, d, mesh)
        if r is None:
            entries.append(P.UNCONSTRAINED)
        else:
            entries.append(r)
            any_pinned = True
    if not any_pinned:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
