from repro.data.pipeline import (  # noqa: F401
    DataCursor, SyntheticLMStream, SyntheticMelStream, make_stream,
)
