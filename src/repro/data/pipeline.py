"""Deterministic, resumable, sharded synthetic data pipeline.

Design requirements for 1000+-node training (DESIGN.md §7):

  * **Stateless batch map** — batch(step) is a pure function of
    (seed, step, host_id), so the only checkpointable pipeline state is the
    step cursor. Any host can resume at any step with no replayed I/O.
  * **Host-sharded** — each host materializes only its 1/num_hosts slice of
    the global batch; the slice boundaries match the batch PartitionSpec so
    device_put performs no resharding.
  * **Structured synthetic text** — tokens follow a seeded Markov-ish map
    (token_{t+1} depends on token_t), so a model can actually *learn* it;
    loss decreasing over examples/train_lm.py is a real convergence signal,
    not noise fitting.

The same interface would wrap a real tokenized corpus: ``batch_at(step)``
is the contract the trainer sees.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataCursor:
    """The pipeline's entire mutable state — checkpointed alongside params."""
    step: int = 0
    seed: int = 0

    def advance(self, n: int = 1) -> "DataCursor":
        return dataclasses.replace(self, step=self.step + n)


class SyntheticLMStream:
    """Next-token-predictable synthetic token stream.

    Sequence construction: x_0 ~ U(vocab); x_{t+1} = (a * x_t + b) % vocab
    with per-sequence (a, b) drawn from the seeded stream. Labels are the
    next-token shift of the input; mask -1 marks the final position.
    """

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 seed: int = 0, num_hosts: int = 1, host_id: int = 0,
                 vocab_cap: Optional[int] = None):
        if shape.global_batch % num_hosts:
            raise ValueError("global_batch must divide num_hosts")
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.num_hosts = num_hosts
        self.host_id = host_id
        self.local_batch = shape.global_batch // num_hosts
        self.vocab = min(cfg.vocab_size, vocab_cap or cfg.vocab_size)

    def _rng(self, step: int) -> np.random.Generator:
        # independent, reconstructible stream per (seed, step, host)
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        rng = self._rng(step)
        b, s, v = self.local_batch, self.shape.seq_len, self.vocab
        x0 = rng.integers(0, v, (b, 1), dtype=np.int64)
        a = rng.integers(1, 8, (b, 1), dtype=np.int64) * 2 + 1  # odd multiplier
        c = rng.integers(0, v, (b, 1), dtype=np.int64)
        t = np.arange(s, dtype=np.int64)[None, :]
        # closed form of the affine recurrence mod v (v need not be prime;
        # determinism is what matters, learnability comes from low-order a)
        toks = x0
        seq = np.empty((b, s), dtype=np.int64)
        seq[:, 0] = toks[:, 0]
        for i in range(1, s):
            toks = (a * toks + c) % v
            seq[:, i] = toks[:, 0]
        del t
        tokens = seq.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if self.cfg.family == "vlm" and self.cfg.vision_patches:
            p = min(self.cfg.vision_patches, s // 2)
            out["patches"] = jnp.asarray(rng.standard_normal(
                (b, p, self.cfg.vision_embed_dim), dtype=np.float32))
        return out


class SyntheticMelStream(SyntheticLMStream):
    """Whisper variant: mel frames + teacher-forced decoder tokens.
    Mel frames are a seeded projection of the token sequence so the
    transcription task is learnable (mel determines tokens)."""

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        base = super().batch_at(step)
        rng = self._rng(step ^ 0x5EED)
        b, s = self.local_batch, self.shape.seq_len
        tok = np.asarray(base["tokens"])
        # per-token mel signature: fixed random embedding of the token id
        proj = np.random.default_rng(
            np.random.SeedSequence([self.seed, 7])).standard_normal(
            (self.vocab if self.vocab < 4096 else 4096, self.cfg.n_mels))
        mel = proj[tok % proj.shape[0]] + 0.1 * rng.standard_normal(
            (b, s, self.cfg.n_mels))
        return {"mel": jnp.asarray(mel, jnp.float32),
                "tokens": base["tokens"], "labels": base["labels"]}


def make_stream(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                num_hosts: int = 1, host_id: int = 0,
                vocab_cap: Optional[int] = None):
    cls = SyntheticMelStream if cfg.family == "audio" else SyntheticLMStream
    return cls(cfg, shape, seed=seed, num_hosts=num_hosts, host_id=host_id,
               vocab_cap=vocab_cap)
