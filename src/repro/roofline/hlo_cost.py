"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for a
scan-over-layers model (or microbatched train step) that undercounts flops,
bytes, and collectives by the trip count (80x for qwen1.5-110b). This module
re-derives the three roofline inputs directly from the post-SPMD HLO text:

  * flops        — 2 x |result| x |contracting dims| per dot (incl. dots
                   nested in fusions), scaled by enclosing while trip counts.
                   Elementwise flops are counted as 1/element of each fusion
                   root (second-order; dots dominate every assigned cell).
  * bytes        — per-instruction operand + result bytes at top scope of
                   each computation (fusion internals are free — matching
                   XLA's own convention), scaled by trip counts. This is an
                   HBM-traffic proxy: weights re-read per loop iteration.
  * collectives  — result-shape bytes per collective site x trip count,
                   plus a ring-model "wire bytes" variant.

Trip counts come from each while's condition computation (the loop bound
constant), cross-checkable against the model's known layer/microbatch
structure. KNOWN INFLATION (documented in EXPERIMENTS.md): the CPU backend
upcasts bf16 dot operands to f32 before gathers/dots, so byte terms are up
to 2x a real TPU lowering — treated as a conservative upper bound.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
# result group is lazy up to the first "opcode(" token — tuple results may
# contain /*index=N*/ comments, so a greedy/char-class match misparses
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
}
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|[\w]+\[[0-9,]*\](?:\{[^}]*\})?)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(text: str) -> Tuple[int, int]:
    """(total bytes, total elements) over every dtype[dims] in ``text``."""
    nbytes = nelem = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
        nelem += n
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes, nelem


@dataclass
class Instr:
    name: str
    opcode: str
    result_text: str
    args_text: str
    result_bytes: int
    result_elems: int
    operands: List[str]
    called: Dict[str, str]         # role -> computation name


@dataclass
class Computation:
    name: str
    params: Dict[str, str]         # param name -> shape text
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_raw: float = 0.0
    coll_wire: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    coll_count: float = 0.0
    while_trips: Dict[str, int] = field(default_factory=dict)
    largest_collectives: List[Tuple[float, str]] = field(default_factory=list)

    def add_coll(self, op: str, nbytes: float, group: int, mult: float,
                 desc: str):
        n = max(group, 2)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif op in ("all-gather", "all-to-all"):
            wire = (n - 1) / n * nbytes
        elif op == "reduce-scatter":
            wire = (n - 1) * nbytes
        else:
            wire = nbytes
        self.coll_raw += nbytes * mult
        self.coll_wire += wire * mult
        self.coll_count += mult
        self.coll_by_op[op] = self.coll_by_op.get(op, 0.0) + nbytes * mult
        self.largest_collectives.append((nbytes * mult, desc))
        self.largest_collectives.sort(reverse=True)
        del self.largest_collectives[10:]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_marker = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                params = dict(_PARAM_RE.findall(m.group(3)))
                cur = Computation(name=name, params=params)
                if m.group(1):
                    entry_marker = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_text, opcode, rest = m.groups()
        rb, re_ = _shape_info(result_text)
        # split args from attrs at the matching close paren (approximate:
        # attrs of interest are searchable anywhere in ``rest``)
        called = {}
        for role, rx in _ATTR_COMP_RE.items():
            cm = rx.search(rest)
            if cm:
                called[role] = cm.group(1)
        instr = Instr(name=name, opcode=opcode, result_text=result_text,
                      args_text=rest, result_bytes=rb, result_elems=re_,
                      operands=_OPERAND_RE.findall(rest.split(" metadata=")[0]),
                      called=called)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    if entry_marker:
        comps["__entry__"] = comps[entry_marker]
    return comps


_SLICE_OPS = ("dynamic-slice", "gather")
_UPDATE_OPS = ("dynamic-update-slice", "scatter")


_PASSTHROUGH = ("convert", "bitcast", "copy", "reshape", "transpose",
                "broadcast")


def _param_slice_bytes(called: Computation, comps) -> Dict[int, int]:
    """For a fused computation: parameters whose every (transitive, through
    unary pass-through ops) consumer is a slice-family op touch slice-sized
    bytes, not their full buffer (the scan-xs pattern: stacked (R, ...)
    tensors sliced once per iteration; fusions often interpose a convert
    before the dynamic-update-slice). Returns {param_index: effective
    bytes}."""
    out: Dict[int, int] = {}
    params = {}
    for instr in called.instrs:
        if instr.opcode == "parameter":
            m = re.match(r"\s*(\d+)\s*\)", instr.args_text)
            if m:
                params[instr.name] = int(m.group(1))

    def final_consumers(name, depth=0):
        """Consumers of ``name``, looking through pass-through ops."""
        result = []
        for c in called.instrs:
            if name not in c.operands:
                continue
            if c.opcode in _PASSTHROUGH and depth < 4:
                result.extend(final_consumers(c.name, depth + 1))
            else:
                result.append((c, name))
        return result

    for pname, pidx in params.items():
        fc = final_consumers(pname)
        if fc and all(c.opcode in _SLICE_OPS or
                      (c.opcode in _UPDATE_OPS and c.operands
                       and (c.operands[0] == via or c.operands[0] == pname))
                      for c, via in fc):
            eff = 0
            for c, _via in fc:
                if c.opcode in _SLICE_OPS:
                    eff += c.result_bytes
                else:  # update: the written region = update operand size
                    upd = c.operands[1] if len(c.operands) > 1 else None
                    if upd and upd in called.by_name:
                        eff += called.by_name[upd].result_bytes
                    else:
                        eff += c.result_bytes // 8
            out[pidx] = eff
    return out


def _operand_bytes(comp: Computation, instr: Instr,
                   comps: Dict[str, Computation]) -> int:
    """HBM bytes read by one instruction. Slice-family ops (and fusions
    whose params feed only slice ops) count the slice, not the buffer —
    matching XLA's utilization-aware accounting; without this, scanned
    stacked tensors count R x full-buffer per step."""
    if instr.opcode in _SLICE_OPS:
        return instr.result_bytes  # read = slice size (indices negligible)
    if instr.opcode in _UPDATE_OPS:
        upd = instr.operands[1] if len(instr.operands) > 1 else None
        if upd and upd in comp.by_name:
            return comp.by_name[upd].result_bytes
        return instr.result_bytes

    slice_adjust: Dict[int, int] = {}
    if instr.opcode in ("fusion", "call"):
        tgt = comps.get(instr.called.get("calls", ""))
        if tgt is not None:
            slice_adjust = _param_slice_bytes(tgt, comps)

    total = 0
    for i, op in enumerate(instr.operands):
        if i in slice_adjust:
            total += slice_adjust[i]
        elif op in comp.by_name:
            total += comp.by_name[op].result_bytes
        elif op in comp.params:
            total += _shape_info(comp.params[op])[0]
    return total


def _operand_shape_elems(comp: Computation, op_name: str,
                         dim_filter=None) -> Optional[List[int]]:
    """Dims of an operand's (single) result shape."""
    text = None
    if op_name in comp.by_name:
        text = comp.by_name[op_name].result_text
    elif op_name in comp.params:
        text = comp.params[op_name]
    if text is None:
        return None
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation: the largest int constant
    compared against the induction variable."""
    best = 1
    for instr in cond.instrs:
        if instr.opcode == "constant":
            # args_text holds everything after "constant(" -> "80), ..."
            cm = re.match(r"\s*(-?\d+)\s*\)", instr.args_text)
            if cm:
                best = max(best, int(cm.group(1)))
    return best


def _dot_flops(comp: Computation, instr: Instr) -> float:
    m = _CONTRACT_RE.search(instr.args_text)
    contract_elems = 1
    if m and instr.operands:
        dims = _operand_shape_elems(comp, instr.operands[0])
        if dims:
            for di in m.group(1).split(","):
                if di != "" and int(di) < len(dims):
                    contract_elems *= dims[int(di)]
    return 2.0 * instr.result_elems * contract_elems


def _group_size(args_text: str) -> int:
    g = re.search(r"replica_groups=\[(\d+),(\d+)\]", args_text)
    if g:
        return int(g.group(2))
    g = re.search(r"replica_groups=\{\{([0-9, ]+)\}", args_text)
    if g:
        return len(g.group(1).split(","))
    return 2


def _flops_of_computation(comp: Computation, comps, memo) -> float:
    """Dot flops (recursing into fusions/calls), elementwise ~1/elem."""
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = 0.0  # cycle guard
    total = 0.0
    for instr in comp.instrs:
        if instr.opcode == "dot":
            total += _dot_flops(comp, instr)
        elif instr.opcode in ("fusion", "call"):
            tgt = instr.called.get("calls")
            if tgt and tgt in comps:
                total += _flops_of_computation(comps[tgt], comps, memo)
        elif instr.opcode == "while":
            body = comps.get(instr.called.get("body", ""))
            cond = comps.get(instr.called.get("condition", ""))
            trip = _trip_count(cond) if cond else 1
            if body:
                total += trip * _flops_of_computation(body, comps, {})
        elif instr.opcode == "conditional":
            for tgt in _OPERAND_RE.findall(instr.args_text):
                if tgt in comps:
                    total += _flops_of_computation(comps[tgt], comps, memo)
        elif instr.opcode not in ("parameter", "constant", "tuple",
                                  "get-tuple-element", "bitcast", "copy"):
            total += instr.result_elems  # elementwise estimate
    memo[comp.name] = total
    return total


def _walk_bytes_coll(comp: Computation, comps, totals: CostTotals,
                     mult: float, seen_while: Dict[str, int]):
    """Per-instruction bytes + collectives at ``comp`` top scope, recursing
    into while bodies with trip multipliers."""
    for instr in comp.instrs:
        op = instr.opcode
        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast"):
            continue
        if op == "while":
            body = comps.get(instr.called.get("body", ""))
            cond = comps.get(instr.called.get("condition", ""))
            trip = _trip_count(cond) if cond else 1
            seen_while[instr.name] = trip
            totals.while_trips[instr.name] = trip
            if body:
                _walk_bytes_coll(body, comps, totals, mult * trip, seen_while)
            continue
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in COLLECTIVES:
            totals.add_coll(base, instr.result_bytes,
                            _group_size(instr.args_text), mult,
                            f"x{mult:g} {instr.result_text} {base}")
        wb = instr.result_bytes
        if instr.opcode in _UPDATE_OPS:  # in-place: write = update region
            upd = instr.operands[1] if len(instr.operands) > 1 else None
            if upd and upd in comp.by_name:
                wb = comp.by_name[upd].result_bytes
        totals.bytes += mult * (wb + _operand_bytes(comp, instr, comps))


def analyze_hlo_text(text: str) -> CostTotals:
    comps = parse_module(text)
    totals = CostTotals()
    entry = comps.get("__entry__")
    if entry is None:
        return totals
    totals.flops = _flops_of_computation(entry, comps, {})
    _walk_bytes_coll(entry, comps, totals, 1.0, {})
    return totals
