"""Roofline analysis from the compiled dry-run artifact (no hardware).

Three terms per (arch x shape x mesh) cell, in seconds:

  compute    = HLO_FLOPs_total   / (chips x peak_FLOP/s)
  memory     = HLO_bytes_total   / (chips x HBM_bw)
  collective = collective_bytes  / (chips x link_bw)

Sources: ``compiled.cost_analysis()`` reports *per-device* flops and bytes
(verified against hand-counted shards), so the chips factors cancel:
term = per_device_quantity / per_chip_rate. collective_bytes comes from
parsing the post-SPMD HLO (``compiled.as_text()``): we sum the result-shape
bytes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction (async -start counted once, -done skipped).

Two collective accountings are kept:
  raw   — sum of result-shape bytes (the assignment's convention)
  wire  — ring-model bytes actually crossing links per device:
          all-reduce 2(n-1)/n x bytes, all-gather/reduce-scatter/all-to-all
          (n-1)/n x full bytes, permute 1x. Used for hillclimb deltas.

Hardware constants (TPU v5e class, from the assignment):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    link_bw: float = 50e9               # bytes/s per ICI link

V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# '%name = <shapes> <op>(' — op must be the instruction, not an operand ref
_INSTR_RE = re.compile(
    r"=\s+(?P<shapes>[^=]*?)\s+(?P<op>" + "|".join(_COLL_OPS)
    + r")(?P<start>-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",") if d]))
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    raw_bytes: int = 0                  # sum of result-shape bytes
    wire_bytes: float = 0.0             # ring-model per-device link bytes
    count: int = 0
    by_op: Dict[str, int] = field(default_factory=dict)
    by_op_count: Dict[str, int] = field(default_factory=dict)
    largest: List[Tuple[int, str]] = field(default_factory=list)

    def add(self, op: str, nbytes: int, group_size: int, line: str):
        self.raw_bytes += nbytes
        self.count += 1
        self.by_op[op] = self.by_op.get(op, 0) + nbytes
        self.by_op_count[op] = self.by_op_count.get(op, 0) + 1
        n = max(group_size, 2)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif op in ("all-gather", "all-to-all"):
            wire = (n - 1) / n * nbytes
        elif op == "reduce-scatter":
            wire = (n - 1) * nbytes      # result is the scattered shard
        else:                            # collective-permute
            wire = float(nbytes)
        self.wire_bytes += wire
        self.largest.append((nbytes, line.strip()[:160]))
        self.largest.sort(reverse=True)
        del self.largest[8:]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m or m.group("start") == "-done":
            continue
        nbytes = _shape_bytes(m.group("shapes"))
        g = _GROUPS_RE.search(line)
        group_size = int(g.group(2)) if g else 2
        stats.add(m.group("op"), nbytes, group_size, line)
    return stats


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6*N*D)
# ---------------------------------------------------------------------------
def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE); D = tokens processed by the
    lowered program (decode cells process global_batch x 1 token).
    Whisper counts encoder+decoder tokens. Training = fwd+bwd (the full 6);
    inference-only cells use 2*N*D (fwd only)."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        if cfg.is_encoder_decoder:
            d_tokens *= 2   # encoder frames + decoder tokens (both seq_len)
        return 6.0 * n_active * d_tokens
    if shape.is_decode:
        return 2.0 * n_active * shape.global_batch
    d_tokens = shape.global_batch * shape.seq_len
    if cfg.is_encoder_decoder:
        d_tokens *= 2
    return 2.0 * n_active * d_tokens


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------
@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_raw_bytes: int
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    collective_wire_s: float
    bottleneck: str
    model_flops_total: float
    useful_flop_ratio: float            # MODEL_FLOPS / (HLO_FLOPs x chips)
    arg_bytes: int = 0
    temp_bytes: int = 0
    out_bytes: int = 0
    coll_by_op: Dict[str, int] = field(default_factory=dict)
    coll_count: int = 0
    largest_collectives: List[Tuple[int, str]] = field(default_factory=list)

    @property
    def step_s(self) -> float:
        """Roofline step time if the three terms overlap perfectly:
        max(terms) — the optimistic bound the perf loop drives down."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound step time, i.e. how close the cell
        is to pure-MFU execution at the bound."""
        chips = max(self.chips, 1)
        useful_s = self.model_flops_total / (chips * V5E.peak_flops)
        return useful_s / self.step_s if self.step_s > 0 else 0.0

    def to_dict(self) -> dict:
        d = dict(self.__dict__)
        d["step_s"] = self.step_s
        d["roofline_fraction"] = self.roofline_fraction
        return d


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll: CollectiveStats, *, chips: int,
                   hw: HW = V5E) -> Tuple[float, float, float, float]:
    compute_s = flops_dev / hw.peak_flops
    memory_s = bytes_dev / hw.hbm_bw
    # assignment convention: collective_bytes / (chips x link_bw) with the
    # parsed totals being per-device already -> divide by link_bw
    collective_s = coll.raw_bytes / hw.link_bw
    collective_wire_s = coll.wire_bytes / hw.link_bw
    return compute_s, memory_s, collective_s, collective_wire_s


def analyze_compiled(compiled, *, arch: str, shape_cfg: ShapeConfig,
                     cfg: ModelConfig, mesh_name: str, chips: int,
                     hw: HW = V5E,
                     hlo_text: Optional[str] = None) -> RooflineReport:
    """Primary numbers come from the trip-count-aware HLO walk
    (roofline/hlo_cost.py); XLA's flat cost_analysis (which counts while
    bodies once) is recorded as a cross-check."""
    from repro.roofline import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax wraps it in a list
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    totals = hlo_cost.analyze_hlo_text(text)
    flops_dev = max(totals.flops, xla_flops)
    bytes_dev = max(totals.bytes, xla_bytes)

    compute_s = flops_dev / hw.peak_flops
    memory_s = bytes_dev / hw.hbm_bw
    collective_s = totals.coll_raw / hw.link_bw
    wire_s = totals.coll_wire / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape_cfg)
    ratio = mf / (flops_dev * chips) if flops_dev else 0.0
    try:
        ma = compiled.memory_analysis()
        arg_b, temp_b, out_b = (ma.argument_size_in_bytes,
                                ma.temp_size_in_bytes,
                                ma.output_size_in_bytes)
    except Exception:
        arg_b = temp_b = out_b = 0
    rep = RooflineReport(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collective_raw_bytes=int(totals.coll_raw),
        collective_wire_bytes=totals.coll_wire,
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, collective_wire_s=wire_s,
        bottleneck=bottleneck, model_flops_total=mf,
        useful_flop_ratio=ratio,
        arg_bytes=arg_b, temp_bytes=temp_b, out_bytes=out_b,
        coll_by_op={k: int(v) for k, v in totals.coll_by_op.items()},
        coll_count=int(totals.coll_count),
        largest_collectives=[(int(b), d)
                             for b, d in totals.largest_collectives],
    )
    rep.xla_flops = xla_flops       # cross-checks (flat, while-body-once)
    rep.xla_bytes = xla_bytes
    rep.while_trips = dict(totals.while_trips)
    return rep
