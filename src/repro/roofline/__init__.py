from repro.roofline.analysis import (  # noqa: F401
    HW, CollectiveStats, RooflineReport, analyze_compiled,
    model_flops, parse_collectives, roofline_terms,
)
