"""Burst/tile autotuning — the paper's LMM-size x burst-length co-design
sweep (§4.4/§5.4, Fig 7/10) as a reusable subsystem (DESIGN.md §9):
candidate enumeration under a VMEM budget (space), analytic/measured cost
(cost), a persistent JSON winner cache (cache), and the dispatch-facing
Autotuner (tuner) consumed by core.offload.OffloadEngine."""
from repro.tuning.cache import TuningCache, TuningKey, TuningRecord  # noqa: F401
from repro.tuning.cost import CostReport, analytic_cost, measured_cost  # noqa: F401
from repro.tuning.space import (  # noqa: F401
    VMEM_FULL_BYTES, TileCandidate, budget_grid, enumerate_candidates)
from repro.tuning.tuner import (  # noqa: F401
    Autotuner, kernel_for, padded_m, sweep_grid)
