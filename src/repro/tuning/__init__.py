"""Burst/tile autotuning — the paper's LMM-size x burst-length co-design
sweep (§4.4/§5.4, Fig 7/10) as a reusable subsystem (DESIGN.md §9):
candidate enumeration under a VMEM budget (space), analytic/calibrated/
measured cost (cost), a persistent JSON winner cache (cache), the
dispatch-facing Autotuner (tuner) consumed by core.offload.OffloadEngine,
and the measured-replay calibration loop (replay + calibrate,
DESIGN.md §14) that fits the analytic model's constants per backend."""
from repro.tuning.cache import TuningCache, TuningKey, TuningRecord  # noqa: F401
from repro.tuning.calibrate import (  # noqa: F401
    BackendCoefficients, CalibratedCoefficients, fit, fit_backend,
    rank_correlation, sibling_path)
from repro.tuning.cost import (  # noqa: F401
    CostReport, activate_calibration_file, analytic_cost, analytic_features,
    calibrated_cost, get_calibration, measured_cost, preferred_cost,
    set_calibration)
from repro.tuning.replay import (  # noqa: F401
    ReplaySample, make_operands, replay, replay_candidate, trimmed_mean)
from repro.tuning.space import (  # noqa: F401
    VMEM_FULL_BYTES, TileCandidate, budget_grid, default_candidate,
    enumerate_candidates)
from repro.tuning.tuner import (  # noqa: F401
    Autotuner, kernel_for, padded_m, sweep_grid)
