"""Compile-and-replay harness for cost-model calibration
(DESIGN.md §14.1).

One replay lowers a single candidate ``KernelRequest`` to a jitted
program *through the §12 backend registry* — the same resolution path
(forced > pinned > capability) production dispatch takes, so
``REPRO_BACKEND`` forcing is honored and ``pallas_tpu`` / ``xla_ref`` /
``host_residual`` each get measurements of the program they would really
run — then executes it ``reps`` times after warmup and reports the
trimmed-mean wall-clock next to the analytic model's FLOP/byte/step
accounting for the same candidate (the features ``calibrate.fit``
regresses against).

Operands are generated from a fixed PRNG seed, so two replays of the same
request build bit-identical programs on identical inputs: the output
checksum is a determinism witness (tests/test_calibration.py).  Weights
are closed over (not traced arguments), matching how serving weights are
donated constants; only the activation is a traced input.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.tuning.cost import analytic_features
from repro.tuning.space import TileCandidate


@dataclass(frozen=True)
class ReplaySample:
    """One replayed (candidate, backend) measurement."""
    kernel: str
    m: int
    n: int
    k: int
    dtype: str                            # "q8_0" | "bf16"
    backend: str                          # backend that ACTUALLY ran
    tiling: Optional[Tuple[int, int, int]]
    times_s: Tuple[float, ...]            # raw per-rep wall-clocks
    warmup: int
    checksum: float                       # f64 sum of the output
    flops: float                          # analytic accounting of the
    bytes_hbm: float                      # same candidate (calibrate.fit
    steps: float                          # feature columns)

    @property
    def time_s(self) -> float:
        return trimmed_mean(self.times_s)


def trimmed_mean(ts: Sequence[float], trim: float = 0.25) -> float:
    """Mean of the middle after dropping samples from each end — robust
    to the one slow outlier a shared CI machine produces.  At least one
    sample is always dropped per side once n >= 3, so the tiny rep
    counts the smoke gate uses (N=3 -> the median, N=5 -> mean of the
    middle three) stay outlier-immune too."""
    if not ts:
        raise ValueError("no timing samples")
    xs = sorted(ts)
    drop = max(int(len(xs) * trim), 1) if len(xs) >= 3 else 0
    mid = xs[drop:len(xs) - drop]
    return sum(mid) / len(mid)


def make_operands(kernel: str, m: int, n: int, k: int, dtype: str,
                  seed: int = 0):
    """Deterministic (x, w) operands for a replay: f32 activation, dense
    or Q8_0-quantized weight, from a fixed PRNG seed."""
    import jax
    import jax.numpy as jnp

    from repro.core.qformats import quantize_q8_0

    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (n, k), jnp.float32) * 0.05
    if dtype == "q8_0":
        w = quantize_q8_0(w)
    return x, w


def replay(kernel: str, m: int, n: int, k: int, dtype: str, *,
           backend: Optional[str] = None,
           tiling: Optional[Tuple[int, int, int]] = None,
           reps: int = 5, warmup: int = 2,
           interpret: Optional[bool] = None,
           seed: int = 0) -> ReplaySample:
    """Measure one candidate on one backend.

    ``backend`` is a registry *pin*, not a force: an active
    ``REPRO_BACKEND`` (or ``REGISTRY.force`` context) outranks it, exactly
    as in production dispatch, and the sample records the backend that
    actually ran (DESIGN.md §12.2 precedence).  ``tiling`` pins the main
    segment's ``(block_m, block_n, block_k)``; the analytic features are
    derived from the same tiling (or the whole-problem default when
    None), so fit rows stay feature-consistent with what executed.
    """
    import jax
    import numpy as np

    from repro.backends.base import MAIN, KernelRequest
    from repro.backends.registry import REGISTRY

    req = KernelRequest(kernel=kernel, m=m, n=n, k=k, dtype=dtype,
                        segment=MAIN, tiling=tiling, interpret=interpret)
    resolved = REGISTRY.resolve(req, pin=backend)
    fn = resolved.build(req)
    x, w = make_operands(kernel, m, n, k, dtype, seed=seed)

    # weights closed over (serving treats them as resident constants);
    # the activation is the traced argument
    jfn = jax.jit(lambda xx: fn(xx, w))
    out = None
    for _ in range(max(warmup, 1)):            # first call compiles
        out = jax.block_until_ready(jfn(x))
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(jfn(x))
        times.append(time.perf_counter() - t0)

    cand = _feature_candidate(kernel, m, n, k, tiling)
    flops, bytes_hbm, steps = analytic_features(cand, m, n, k)
    return ReplaySample(
        kernel=kernel, m=m, n=n, k=k, dtype=dtype,
        backend=resolved.name, tiling=tiling,
        times_s=tuple(times), warmup=warmup,
        checksum=float(np.asarray(out, dtype=np.float64).sum()),
        flops=flops, bytes_hbm=bytes_hbm, steps=steps)


def replay_candidate(cand: TileCandidate, m: int, n: int, k: int,
                     dtype: str, **kw) -> ReplaySample:
    """``replay`` for a space-enumerated ``TileCandidate``."""
    return replay(cand.kernel, m, n, k, dtype,
                  tiling=(cand.block_m, cand.block_n, cand.block_k), **kw)


def _feature_candidate(kernel: str, m: int, n: int, k: int,
                       tiling: Optional[Tuple[int, int, int]]
                       ) -> TileCandidate:
    """The TileCandidate the analytic features are computed for: the
    pinned tiling when one was replayed, else the same whole-problem
    default ``space.default_candidate`` dispatch would fall back to."""
    if tiling is not None:
        bm, bn, bk = tiling
        return TileCandidate(kernel, bm, bn, bk, 0)
    from repro.tuning.space import default_candidate
    return default_candidate(kernel, m, n, k)
