"""Persistent tuning cache (DESIGN.md §9.3).

Winners are keyed by the full problem identity the paper's design sweep
varies: ``(kernel, M, N, K, dtype, vmem_budget)``. The store is a flat JSON
file so caches produced on different hosts/backends can be merged (a
measured entry beats an analytic one for the same key; otherwise lower cost
wins) and shipped with the repo like the paper ships its chosen
32KB/burst-16 operating point.
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

SCHEMA_VERSION = 1


@dataclass(frozen=True)
class TuningKey:
    kernel: str
    m: int
    n: int
    k: int
    dtype: str                    # weight path: q8_0 | bf16
    vmem_budget_bytes: int

    def encode(self) -> str:
        return (f"{self.kernel}|m{self.m}|n{self.n}|k{self.k}"
                f"|{self.dtype}|v{self.vmem_budget_bytes}")

    @staticmethod
    def decode(s: str) -> "TuningKey":
        kernel, m, n, k, dtype, v = s.split("|")
        return TuningKey(kernel, int(m[1:]), int(n[1:]), int(k[1:]),
                         dtype, int(v[1:]))


@dataclass(frozen=True)
class TuningRecord:
    block_m: int
    block_n: int
    block_k: int
    cost_s: float
    vmem_bytes: int
    source: str                   # analytic | calibrated | measured

    def tiling(self) -> Dict[str, int]:
        return {"block_m": self.block_m, "block_n": self.block_n,
                "block_k": self.block_k}


def _better(a: TuningRecord, b: TuningRecord) -> TuningRecord:
    """Merge policy: measured beats calibrated beats analytic (more
    grounded sources win, DESIGN.md §14.2); within a source, lower cost."""
    rank = {"measured": 0, "calibrated": 1, "analytic": 2}
    ka = (rank.get(a.source, 3), a.cost_s)
    kb = (rank.get(b.source, 3), b.cost_s)
    return a if ka <= kb else b


@dataclass
class TuningCache:
    entries: Dict[TuningKey, TuningRecord] = field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    def __len__(self) -> int:
        return len(self.entries)

    def get(self, key: TuningKey) -> Optional[TuningRecord]:
        rec = self.entries.get(key)
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def put(self, key: TuningKey, rec: TuningRecord) -> None:
        cur = self.entries.get(key)
        self.entries[key] = rec if cur is None else _better(rec, cur)

    def merge(self, other: "TuningCache") -> "TuningCache":
        for k, r in other.entries.items():
            self.put(k, r)
        return self

    # -- persistence ----------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION,
                "entries": {k.encode(): asdict(r)
                            for k, r in sorted(self.entries.items(),
                                               key=lambda kv: kv[0].encode())}}

    @classmethod
    def from_dict(cls, d: dict) -> "TuningCache":
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"tuning cache schema {d.get('schema')!r} "
                             f"!= {SCHEMA_VERSION}")
        c = cls()
        for ks, rv in d.get("entries", {}).items():
            c.entries[TuningKey.decode(ks)] = TuningRecord(**rv)
        return c

    def save(self, path: str) -> str:
        """Atomic write (tmp + rename) so a crashed sweep never truncates a
        good cache — same discipline as train/checkpoint.py."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_dict(), f, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    @classmethod
    def load_or_empty(cls, path: Optional[str]) -> "TuningCache":
        """Best-effort load for dispatch-time use: a cache is an
        optimization, so a missing, corrupt, or schema-mismatched file
        degrades to an empty cache (the tuner re-derives winners) instead
        of failing engine construction. Use ``load`` when corruption should
        be an error (tests, explicit merges)."""
        if path and os.path.exists(path):
            try:
                return cls.load(path)
            except (ValueError, KeyError, TypeError, OSError) as e:
                import warnings
                warnings.warn(f"ignoring unreadable tuning cache {path}: {e}")
        return cls()
