"""Measured-replay calibration of the analytic cost model
(DESIGN.md §14.2).

The roofline in ``tuning/cost.py`` ranks candidates with *datasheet*
constants (MXU peak FLOP/s, HBM bytes/s, a guessed per-grid-step
overhead).  Those constants are right for a v5e but wildly wrong for the
backend CI actually runs on (XLA on a laptop CPU), so every cost the
tuner prints is a projection, not a measurement.  This module closes the
loop: given replay measurements (``tuning/replay.py``) it least-squares
fits *effective* per-backend constants and persists them as a versioned
JSON next to the tuning cache, where ``cost.preferred_cost`` picks them
up transparently.

The fitted form is the **additive** roofline

    t(cand) = flops/eff_flops + bytes/eff_bw + steps * overhead_s

rather than the analytic model's ``max(compute, memory) + launch``: the
additive form is linear in ``(1/eff_flops, 1/eff_bw, overhead_s)``, so a
plain linear least squares recovers the constants exactly from
noise-free samples (the regression test of §14.2) and degrades
gracefully on noisy ones.  ``max`` and ``+`` agree in the regimes that
decide rankings (one term dominant); where they differ the additive form
is the conservative upper bound.

Schema (``CalibratedCoefficients.to_dict``)::

    {"schema": 1,
     "default_backend": "xla_ref",
     "backends": {"xla_ref": {"eff_flops": ..., "eff_bw": ...,
                              "overhead_s": ..., "n_samples": ...,
                              "median_rel_err": ...}}}

The store follows the tuning cache's discipline: atomic tmp +
``os.replace`` writes, and ``load_or_none`` degrades a corrupt or
schema-mismatched file to "no calibration" with a warning instead of
failing the caller (a calibration is an optimization, like the cache).
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

SCHEMA_VERSION = 1

#: coefficient floor: a fit on degenerate samples (e.g. all-identical
#: shapes) can return ~0 or negative columns; clamping keeps ``predict``
#: finite and positive without rejecting the whole calibration.
_COEF_FLOOR = 1e-30


@dataclass(frozen=True)
class BackendCoefficients:
    """Effective roofline constants for ONE execution backend."""
    backend: str
    eff_flops: float              # effective FLOP/s
    eff_bw: float                 # effective bytes/s
    overhead_s: float             # per-grid-step dispatch overhead
    n_samples: int = 0
    median_rel_err: float = 0.0   # fit residual on the calibration set

    def predict(self, flops: float, bytes_hbm: float,
                steps: float) -> float:
        """Additive calibrated roofline (module docstring)."""
        return (flops / self.eff_flops + bytes_hbm / self.eff_bw
                + steps * self.overhead_s)

    def predict_parts(self, flops: float, bytes_hbm: float,
                      steps: float) -> Tuple[float, float, float]:
        return (flops / self.eff_flops, bytes_hbm / self.eff_bw,
                steps * self.overhead_s)


@dataclass
class CalibratedCoefficients:
    """Per-backend calibrated constants + the JSON store."""
    by_backend: Dict[str, BackendCoefficients] = field(default_factory=dict)
    default_backend: Optional[str] = None

    def __len__(self) -> int:
        return len(self.by_backend)

    def put(self, coeffs: BackendCoefficients) -> None:
        self.by_backend[coeffs.backend] = coeffs
        if self.default_backend is None:
            self.default_backend = coeffs.backend

    def for_backend(self, backend: Optional[str] = None
                    ) -> Optional[BackendCoefficients]:
        """Coefficients for ``backend`` (None -> the default backend);
        None when this calibration has none for it."""
        name = backend or self.default_backend
        return self.by_backend.get(name) if name else None

    # -- persistence ----------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION,
                "default_backend": self.default_backend,
                "backends": {name: asdict(c)
                             for name, c in sorted(self.by_backend.items())}}

    @classmethod
    def from_dict(cls, d: dict) -> "CalibratedCoefficients":
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"calibration schema {d.get('schema')!r} "
                             f"!= {SCHEMA_VERSION}")
        out = cls(default_backend=d.get("default_backend"))
        for name, cv in d.get("backends", {}).items():
            out.by_backend[name] = BackendCoefficients(**cv)
        return out

    def save(self, path: str) -> str:
        """Atomic write (tmp + ``os.replace``) — same discipline as
        ``tuning/cache.py``: a crashed writer never truncates a good
        coefficients file."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_dict(), f, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    @classmethod
    def load(cls, path: str) -> "CalibratedCoefficients":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    @classmethod
    def load_or_none(cls, path: Optional[str]
                     ) -> Optional["CalibratedCoefficients"]:
        """Best-effort load: a missing, corrupt, truncated, or
        schema-mismatched file degrades to None (uncalibrated analytic
        costs) with a warning instead of raising."""
        if path and os.path.exists(path):
            try:
                return cls.load(path)
            except (ValueError, KeyError, TypeError, OSError) as e:
                warnings.warn(
                    f"ignoring unreadable calibration file {path}: {e}")
        return None


def sibling_path(cache_path: str) -> str:
    """Where a tuning cache's calibration lives: ``foo.json`` ->
    ``foo.calibration.json`` in the same directory (so shipping a cache
    ships its calibration too, DESIGN.md §14.2)."""
    root, _ = os.path.splitext(cache_path)
    return root + ".calibration.json"


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------
def fit(samples: Sequence[Tuple[float, float, float, float]],
        backend: str = "") -> BackendCoefficients:
    """Least-squares fit of the additive roofline.

    ``samples`` are ``(flops, bytes_hbm, steps, measured_s)`` rows —
    features from ``cost.analytic_features`` and times from
    ``replay.replay``.  Solves ``t ~= a*flops + b*bytes + c*steps`` in
    float64 and returns ``BackendCoefficients(eff_flops=1/a, eff_bw=1/b,
    overhead_s=c)``.  Noise-free samples generated by the same form are
    recovered exactly (tests/test_calibration.py); real measurements get
    the least-squares compromise, whose quality ``median_rel_err``
    reports.
    """
    if len(samples) < 3:
        raise ValueError(f"need >= 3 samples to fit 3 coefficients, "
                         f"got {len(samples)}")
    a = np.asarray([s[:3] for s in samples], dtype=np.float64)
    t = np.asarray([s[3] for s in samples], dtype=np.float64)
    # column scaling: flops ~1e9, bytes ~1e6, steps ~1e2 — normalize so
    # lstsq conditioning doesn't swamp the small columns
    scale = np.maximum(np.abs(a).max(axis=0), 1e-300)
    coef, *_ = np.linalg.lstsq(a / scale, t, rcond=None)
    coef = coef / scale
    coef = np.maximum(coef, _COEF_FLOOR)
    pred = a @ coef
    rel = np.abs(pred - t) / np.maximum(np.abs(t), 1e-300)
    return BackendCoefficients(
        backend=backend,
        eff_flops=float(1.0 / coef[0]),
        eff_bw=float(1.0 / coef[1]),
        overhead_s=float(coef[2]),
        n_samples=len(samples),
        median_rel_err=float(np.median(rel)))


def fit_backend(samples: Iterable, backend: str) -> BackendCoefficients:
    """``fit`` over replay samples (objects with ``flops`` /
    ``bytes_hbm`` / ``steps`` / ``time_s`` attributes, i.e.
    ``replay.ReplaySample``) that ran on ``backend``."""
    rows = [(s.flops, s.bytes_hbm, s.steps, s.time_s)
            for s in samples if s.backend == backend]
    return fit(rows, backend=backend)


# ---------------------------------------------------------------------------
# rank correlation (the "does analytic order match measured order?" gate)
# ---------------------------------------------------------------------------
def _ranks(xs: Sequence[float]) -> np.ndarray:
    """Average-tie ranks (scipy-free rankdata)."""
    xs = np.asarray(xs, dtype=np.float64)
    order = np.argsort(xs, kind="stable")
    ranks = np.empty(len(xs), dtype=np.float64)
    i = 0
    while i < len(xs):
        j = i
        while j + 1 < len(xs) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j)       # average of tied slots
        i = j + 1
    return ranks


def rank_correlation(a: Sequence[float], b: Sequence[float]) -> float:
    """Spearman rank correlation in [-1, 1]: Pearson on average-tie
    ranks.  1.0 means the analytic model orders candidates exactly as
    the measurements do — the property the CI gate protects even when
    absolute errors are large."""
    if len(a) != len(b):
        raise ValueError(f"length mismatch: {len(a)} != {len(b)}")
    if len(a) < 2:
        return 1.0
    ra, rb = _ranks(a), _ranks(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = float(np.sqrt((ra * ra).sum() * (rb * rb).sum()))
    if denom == 0.0:                                # all-tied side: no order
        return 1.0 if (ra == rb).all() else 0.0
    return float((ra * rb).sum() / denom)
