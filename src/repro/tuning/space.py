"""Candidate tiling enumeration under a VMEM budget (DESIGN.md §9.1).

The paper's design space is (LMM size) x (burst length); ours is
(vmem_budget) x (block_m, block_n, block_k). A candidate is admissible iff

  * every block divides its dimension exactly (the kernels refuse partial
    tiles — ragged sizes are the mixed_exec residual's job, DESIGN.md §5),
  * block_k holds whole Q8_0 blocks on the quantized paths (burst rule),
  * the kernel's ``vmem_claim_bytes`` fits the budget (the 32KB-LMM analog).

Budgets are swept from a 16KB-LMM *equivalent* up to the full per-core VMEM:
the IMAX point aggregates 46 PE-local memories per lane, so the equivalence
is ``budget_kb * AGG_UNITS`` (coverage.py's cap) mapped onto one core's
VMEM claim. ``budget_grid()`` produces that sweep.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List

from repro.core.qformats import QBLOCK

# Full per-core VMEM on the v5e class (pallas_guide: ~16 MB/core); tilings
# are rejected well before this by the sweep's budgets.
VMEM_FULL_BYTES = 16 * 2**20

# Caps/floors on block sizes. The space is *every* divisor of the dimension
# inside [floor, cap] (plus the whole dimension as a fallback), not just
# powers of two — Whisper's 1500-frame encoder pads to 1504 = 2^5 x 47,
# whose best M tiles (94, 188) are not MXU-aligned; the cost model charges
# them the MXU padding tax instead of excluding them.
BLOCK_M_FLOOR, BLOCK_M_CAP = 8, 256      # sublane multiple preferred
BLOCK_N_FLOOR, BLOCK_N_CAP = 128, 1024   # lane multiple preferred
BLOCK_K_FLOOR, BLOCK_K_CAP = 32, 1024    # burst-length analog

# Canonical power-of-two burst axis for sweep grids (benchmarks/tune_sweep).
BLOCK_K_CANDIDATES = (32, 64, 128, 256, 512, 1024)

KERNELS = ("q8_matmul", "q8_matvec", "bf16_matmul")


@dataclass(frozen=True)
class TileCandidate:
    """One point of the (block_m, block_n, block_k) design space."""
    kernel: str
    block_m: int
    block_n: int
    block_k: int
    vmem_bytes: int

    def as_kwargs(self) -> Dict[str, int]:
        if self.kernel == "q8_matvec":
            return {"block_n": self.block_n}
        return {"block_m": self.block_m, "block_n": self.block_n,
                "block_k": self.block_k}


def _divisors(dim: int, floor: int, cap: int, mult: int = 1) -> List[int]:
    out = [d for d in range(floor, min(dim, cap) + 1)
           if dim % d == 0 and d % mult == 0]
    if not out and dim % mult == 0:
        out = [dim]          # small dim: single whole-dim block
    return out


def _claim_fn(kernel: str) -> Callable[..., int]:
    # imported lazily: repro.kernels pulls in the backend registry, which
    # imports repro.tuning back — at call time both are fully initialized,
    # at module-import time this would be a cycle (and the analytic tuning
    # path stays import-light, as cost.py promises)
    from repro.kernels.bf16_matmul import vmem_claim_bytes as _bf16_claim
    from repro.kernels.q8_matmul import vmem_claim_bytes as _q8mm_claim
    from repro.kernels.q8_matvec import vmem_claim_bytes as _q8mv_claim
    return {"q8_matmul": _q8mm_claim,
            "q8_matvec": _q8mv_claim,
            "bf16_matmul": _bf16_claim}[kernel]


def enumerate_candidates(kernel: str, m: int, n: int, k: int, *,
                         vmem_budget_bytes: int = VMEM_FULL_BYTES,
                         x_bytes: int = 2) -> List[TileCandidate]:
    """All admissible tilings of (M,N,K) for ``kernel`` within the budget.

    Deterministic order (block_k desc, then block_n, block_m desc) so ties
    in the cost model resolve identically across runs and hosts.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; one of {KERNELS}")
    claim = _claim_fn(kernel)
    kmult = QBLOCK if kernel.startswith("q8") else 1
    out: List[TileCandidate] = []
    if kernel == "q8_matvec":
        # the matvec keeps the whole (B, K) activation resident: only the
        # N streaming granularity is tunable; K is a single block.
        if k % QBLOCK:
            return []
        for bn in sorted(_divisors(n, BLOCK_N_FLOOR, BLOCK_N_CAP),
                         reverse=True):
            v = claim(b=m, k=k, block_n=bn, x_bytes=x_bytes)
            if v <= vmem_budget_bytes:
                out.append(TileCandidate(kernel, m, bn, k, v))
        return out
    for bk in sorted(_divisors(k, BLOCK_K_FLOOR, BLOCK_K_CAP, kmult),
                     reverse=True):
        for bn in sorted(_divisors(n, BLOCK_N_FLOOR, BLOCK_N_CAP),
                         reverse=True):
            for bm in sorted(_divisors(m, BLOCK_M_FLOOR, BLOCK_M_CAP),
                             reverse=True):
                v = claim(block_m=bm, block_n=bn, block_k=bk, x_bytes=x_bytes)
                if v <= vmem_budget_bytes:
                    out.append(TileCandidate(kernel, bm, bn, bk, v))
    return out


def _largest_tile(dim: int, cap: int, mult: int = 1) -> int:
    """Largest t <= cap with t % mult == 0 and dim % t == 0 (the same
    fallback rule ``backends/pallas_tpu.py`` applies untuned)."""
    t = min(cap, dim)
    while t > 1 and (dim % t or (mult > 1 and t % mult)):
        t -= mult if mult > 1 and t % mult == 0 else 1
    return max(t, 1)


def default_candidate(kernel: str, m: int, n: int, k: int, *,
                      x_bytes: int = 2) -> TileCandidate:
    """The tiling dispatch falls back to with no tuner attached — the
    hard-coded caps of ``backends/pallas_tpu.py`` expressed as a
    ``TileCandidate`` so benchmarks (tune_sweep's baseline column) and
    replay features (DESIGN.md §14.1) can price the untuned path with the
    same machinery as tuned ones."""
    claim = _claim_fn(kernel)
    if kernel == "q8_matvec":
        bn = _largest_tile(n, 512)
        return TileCandidate(kernel, m, bn, k,
                             claim(b=m, k=k, block_n=bn, x_bytes=x_bytes))
    bm = _largest_tile(m, 128)
    bn = _largest_tile(n, 256)
    bk = _largest_tile(k, 256, mult=QBLOCK if kernel.startswith("q8") else 1)
    return TileCandidate(kernel, bm, bn, bk,
                         claim(block_m=bm, block_n=bn, block_k=bk,
                               x_bytes=x_bytes))


def budget_grid(min_kb: int = 16, max_bytes: int = VMEM_FULL_BYTES,
                agg_units: int = 46) -> List[int]:
    """Geometric sweep of VMEM budgets in bytes, from the paper's smallest
    interesting LMM point (16 KB x AGG_UNITS aggregate ≈ 736 KB) up to full
    VMEM — the x-axis of the (local-memory x burst) grid."""
    out = []
    b = min_kb * 1024 * agg_units
    while b < max_bytes:
        out.append(b)
        b *= 2
    out.append(max_bytes)
    return out
