"""The autotuner: search the admissible tiling space, keep winners in the
persistent cache, answer dispatch-time queries on the fast path
(DESIGN.md §9).

This is the TPU restatement of the paper's central experiment: the LMM-size
x burst-length co-design sweep that lands on 32KB/burst-16. Here the local
memory axis is ``vmem_budget_bytes`` (what one invocation may claim of the
core's VMEM) and the burst axis is ``block_k``; the sweep runs offline or
lazily at dispatch time, and the chosen operating points persist in a JSON
cache exactly like the paper hard-wires its chosen design point into the
bitstream — except ours is re-derivable per shape and budget.

Modes:
  analytic — rank candidates by the deterministic roofline model (CI, CPU).
  measured — wall-clock the top analytic candidates on the real backend.
  auto     — measured on TPU, analytic elsewhere (ops.py's path-selection
             rule applied to tuning).

In analytic/auto-analytic mode the ranking goes through
``cost.preferred_cost``: when a replay calibration exists — passed in, or
found as the versioned JSON sibling of ``cache_path``
(``calibrate.sibling_path``, DESIGN.md §14.2) — candidates are priced
with fitted per-backend constants instead of datasheet ones, so tuner
output on a calibrated host reflects measurements, not projections.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.tuning.cache import TuningCache, TuningKey, TuningRecord
from repro.tuning.calibrate import CalibratedCoefficients, sibling_path
from repro.tuning.cost import (
    CostReport, analytic_cost, measured_cost, preferred_cost)
from repro.tuning.space import (
    VMEM_FULL_BYTES, TileCandidate, enumerate_candidates)

# matches ops.py's decode-vs-prefill split: skinny batches take the matvec
_MATVEC_MAX_M = 16
_SUBLANE = 8            # ops.py pads M to this before dispatch
# measured mode only wall-clocks the analytically-best few candidates
_MEASURE_TOP = 8


def padded_m(m: int) -> int:
    """M after ops.py's sublane padding — tuning keys use this M so
    dispatch-time queries hit the entries warmed offline."""
    return m + (-m) % _SUBLANE


def kernel_for(m: int, quantized: bool) -> str:
    """Which kernel ops.py will dispatch this (raw, unpadded) M to."""
    if quantized:
        return "q8_matvec" if padded_m(m) <= 2 * _SUBLANE else "q8_matmul"
    return "bf16_matmul"


@dataclass
class Autotuner:
    """Facade owned by core.offload.OffloadEngine (one per engine)."""
    cache: TuningCache = field(default_factory=TuningCache)
    vmem_budget_bytes: int = VMEM_FULL_BYTES // 2
    mode: str = "auto"                    # analytic | measured | auto
    cache_path: Optional[str] = None
    # replay-fitted cost coefficients (DESIGN.md §14.2): explicit, or
    # auto-loaded from calibration_path / the cache_path sibling file
    calibration: Optional[CalibratedCoefficients] = None
    calibration_path: Optional[str] = None
    searches: int = 0                     # full sweeps run (cache misses)
    # shapes where nothing fits the budget — memoized in-process so the
    # hot dispatch path never repeats a fruitless sweep (negatives are
    # budget-deterministic and cheap to re-derive, so they don't persist)
    _no_tiling: set = field(default_factory=set, repr=False)

    def __post_init__(self):
        if self.mode not in ("analytic", "measured", "auto"):
            raise ValueError(f"unknown tuning mode {self.mode!r}")
        if self.cache_path:
            self.cache.merge(TuningCache.load_or_empty(self.cache_path))
        if self.calibration is None:
            path = self.calibration_path or (
                sibling_path(self.cache_path) if self.cache_path else None)
            self.calibration = CalibratedCoefficients.load_or_none(path)

    # -- mode resolution -------------------------------------------------
    def _resolved_mode(self) -> str:
        if self.mode != "auto":
            return self.mode
        from repro.backends.platform import on_tpu
        return "measured" if on_tpu() else "analytic"

    # -- search ----------------------------------------------------------
    def search(self, kernel: str, m: int, n: int, k: int) -> Optional[TuningRecord]:
        """Sweep the admissible space for this shape; None if nothing fits
        the budget (caller falls back to the XLA path — the paper's
        host-fallback for uncovered invocations)."""
        self.searches += 1
        cands = enumerate_candidates(
            kernel, m, n, k, vmem_budget_bytes=self.vmem_budget_bytes)
        if not cands:
            return None
        reports = [preferred_cost(c, m, n, k, calibration=self.calibration)
                   for c in cands]
        reports.sort(key=lambda r: r.cost_s)
        if self._resolved_mode() == "measured":
            reports = [measured_cost(r.cand, m, n, k)
                       for r in reports[:_MEASURE_TOP]]
            reports.sort(key=lambda r: r.cost_s)
        best = reports[0]
        return TuningRecord(
            block_m=best.cand.block_m, block_n=best.cand.block_n,
            block_k=best.cand.block_k, cost_s=best.cost_s,
            vmem_bytes=best.cand.vmem_bytes, source=best.source)

    def best_tiling(self, kernel: str, m: int, n: int, k: int,
                    dtype: str) -> Optional[TuningRecord]:
        """Dispatch-time entry point: cache hit is a dict lookup (the fast
        path OffloadEngine sits on); a miss triggers one search whose winner
        is cached for every later invocation of the same shape."""
        key = TuningKey(kernel, m, n, k, dtype, self.vmem_budget_bytes)
        if key in self._no_tiling:        # memoized negative: also a hit
            self.cache.hits += 1
            return None
        rec = self.cache.get(key)
        if rec is not None:
            return rec
        rec = self.search(kernel, m, n, k)
        if rec is None:
            self._no_tiling.add(key)
        else:
            self.cache.put(key, rec)
        return rec

    # -- offline warming -------------------------------------------------
    def warm(self, mulmats: Iterable, dtype: str = "q8_0") -> int:
        """Pre-tune an enumerated workload (core.coverage.MulMat items) so
        serving never stalls on a first-invocation sweep. Returns the number
        of distinct full-K shapes tuned.

        Each shape warms two keys: the full-K query (what the burst
        selection asks, §9.4) and — when the winning ``block_k`` does not
        divide K — the main-segment ``k_main = ⌊K/b⌋·b`` query that
        trace-time planning resolves tiles against (DESIGN.md §10.1), so
        plan recording is dict-hits-only too."""
        seen = set()
        for mm in mulmats:
            quant = dtype.startswith("q8")
            kern = kernel_for(mm.m, quant)
            mp = padded_m(mm.m)
            sig = (kern, mp, mm.n, mm.k)
            if sig in seen:
                continue
            seen.add(sig)
            rec = self.best_tiling(kern, mp, mm.n, mm.k, dtype)
            if rec is not None:
                k_main = (mm.k // rec.block_k) * rec.block_k
                if k_main and k_main != mm.k:
                    self.best_tiling(kern, mp, mm.n, k_main, dtype)
        return len(seen)

    def save(self, path: Optional[str] = None) -> Optional[str]:
        p = path or self.cache_path
        return self.cache.save(p) if p else None


def sweep_grid(kernel: str, m: int, n: int, k: int, *,
               budgets: Sequence[int],
               block_ks: Sequence[int],
               cost_fn=None) -> List[Tuple[int, CostReport]]:
    """The paper's Fig-10-style grid: the cheapest admissible (block_m,
    block_n) completion at each (vmem_budget, block_k) cell, as
    (budget_bytes, CostReport) pairs. Cells where no tiling fits the
    budget are omitted — the coverage cliff of Table 6. ``cost_fn(cand,
    m, n, k) -> CostReport`` defaults to the analytic model; pass a
    measured_cost wrapper on real backends (benchmarks/tune_sweep.py)."""
    cost_fn = cost_fn or analytic_cost
    out: List[Tuple[int, CostReport]] = []
    for budget in budgets:
        cands = enumerate_candidates(kernel, m, n, k,
                                     vmem_budget_bytes=budget)
        for bk in block_ks:
            sub = [c for c in cands if c.block_k == bk]
            if not sub:
                continue
            best = min((cost_fn(c, m, n, k) for c in sub),
                       key=lambda r: r.cost_s)
            out.append((budget, best))
    return out
