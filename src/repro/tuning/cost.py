"""Tiling cost models: analytic roofline (deterministic, CI-safe) and
wall-clock measurement (real backends) — DESIGN.md §9.2.

The analytic model is the paper's PDP argument restated in roofline terms.
For a (M,K) x (N,K) contraction tiled (bm, bn, bk):

  compute_s = 2*M*N*K / peak_flops
  memory_s  = HBM bytes / hbm_bw, where the tiling sets the *re-streaming*
              factors: the activation panel is re-read once per N-tile
              (N/bn passes) and the weight panel once per M-tile (M/bm
              passes) — exactly the reason the paper's larger LMM (here:
              larger tiles under a bigger VMEM budget) cuts DRAM energy.
  launch_s  = grid_steps x per-step overhead — the per-burst configuration
              cost the paper amortizes with longer bursts (here: bigger bk).

cost_s = max(compute_s, memory_s) + launch_s.  PDP/EDP proxies multiply by
the TDP-class chip power (core/energy.py), matching Eq. 1-3.

Wall-clock measurement runs the real kernel via ``kernels.ops`` plumbing and
is only meaningful on a TPU backend; in ``interpret=True`` CPU mode its
numbers reflect the interpreter, so the tuner defaults to the analytic model
off-TPU (DESIGN.md §6.3 path selection applies to tuning too).

A third source sits between the two: **calibrated** costs (DESIGN.md §14)
reuse the analytic model's own FLOP/byte/step accounting
(``analytic_features``) but with per-backend *effective* constants fitted
from replay measurements (``tuning/calibrate.py``).  ``preferred_cost`` is
the seam the tuner ranks through: it transparently prefers calibrated
coefficients when a calibration is active (explicitly passed, or loaded
process-wide via ``set_calibration`` / ``activate_calibration_file``) and
falls back to the analytic roofline otherwise.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core import energy
from repro.core.qformats import QBLOCK
from repro.roofline.analysis import HW, V5E
from repro.tuning.calibrate import BackendCoefficients, CalibratedCoefficients
from repro.tuning.space import TileCandidate

# Per-grid-step launch overhead. On real hardware this is sub-microsecond
# sequencer work; the constant only needs to *rank* candidates (it penalizes
# tiny block_k the way the paper's CONF term penalizes burst 8).
GRID_STEP_OVERHEAD_S = 2e-7


@dataclass(frozen=True)
class CostReport:
    cand: TileCandidate
    compute_s: float
    memory_s: float
    launch_s: float
    cost_s: float
    source: str                   # analytic | calibrated | measured

    def pdp_j(self, power_w: float = energy.TPU_V5E_W) -> float:
        return energy.pdp(self.cost_s, power_w)

    def edp_js(self, power_w: float = energy.TPU_V5E_W) -> float:
        return energy.edp(self.cost_s, power_w)


def _weight_bytes_per_elem(kernel: str) -> float:
    # Q8_0: 1 int8 byte + 4-byte f32 scale per 32 elements.
    if kernel.startswith("q8"):
        return 1.0 + 4.0 / QBLOCK
    return 2.0


def _pad(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def analytic_features(cand: TileCandidate, m: int, n: int, k: int, *,
                      x_bytes: int = 2) -> Tuple[float, float, float]:
    """The analytic model's raw accounting for one candidate:
    ``(flops, bytes_hbm, grid_steps)``.  Shared verbatim between the
    analytic roofline below and the calibrated model (DESIGN.md §14.2) so
    calibration fits constants against *exactly* the features the ranking
    later uses."""
    bm, bn, bk = cand.block_m, cand.block_n, cand.block_k
    # MXU padding tax: tiles off the (sublane=8, lane=128) grid compute on
    # padded operands — the space admits e.g. bm=94 (1504's best divisor)
    # and this term prices it fairly against aligned alternatives.
    align = (_pad(bm, 8) / bm) * (_pad(bn, 128) / bn)
    flops = 2.0 * m * n * k * align
    w_bpe = _weight_bytes_per_elem(cand.kernel)
    if cand.kernel == "q8_matvec":
        # activation loaded once (resident), weights streamed once, out once
        n_passes_x, m_passes_w = 1, 1
        steps = n // bn
    else:
        n_passes_x = n // bn          # x panel re-read per N tile
        m_passes_w = m // bm          # w panel re-read per M tile
        steps = (m // bm) * (n // bn) * (k // bk)
    bytes_hbm = (n_passes_x * m * k * x_bytes
                 + m_passes_w * n * k * w_bpe
                 + m * n * 4)
    return flops, float(bytes_hbm), float(steps)


def analytic_cost(cand: TileCandidate, m: int, n: int, k: int, *,
                  hw: HW = V5E, x_bytes: int = 2) -> CostReport:
    """Deterministic roofline cost of running (M,N,K) with this tiling."""
    flops, bytes_hbm, steps = analytic_features(cand, m, n, k,
                                                x_bytes=x_bytes)
    compute_s = flops / hw.peak_flops
    memory_s = bytes_hbm / hw.hbm_bw
    launch_s = steps * GRID_STEP_OVERHEAD_S
    return CostReport(cand, compute_s, memory_s, launch_s,
                      max(compute_s, memory_s) + launch_s, "analytic")


def calibrated_cost(cand: TileCandidate, m: int, n: int, k: int, *,
                    coeffs: BackendCoefficients,
                    x_bytes: int = 2) -> CostReport:
    """The analytic accounting priced with replay-fitted *effective*
    constants for one backend (DESIGN.md §14.2).  Additive form — see
    ``tuning/calibrate.py`` for why the calibrated model sums terms where
    the analytic one takes ``max``."""
    flops, bytes_hbm, steps = analytic_features(cand, m, n, k,
                                                x_bytes=x_bytes)
    compute_s, memory_s, launch_s = coeffs.predict_parts(
        flops, bytes_hbm, steps)
    return CostReport(cand, compute_s, memory_s, launch_s,
                      compute_s + memory_s + launch_s, "calibrated")


# -- active calibration (process-wide, opt-in) ------------------------------
_ACTIVE_CALIBRATION: Optional[CalibratedCoefficients] = None


def set_calibration(cal: Optional[CalibratedCoefficients]
                    ) -> Optional[CalibratedCoefficients]:
    """Install (or clear, with None) the process-wide calibration that
    ``preferred_cost`` consults.  Returns the previous one so callers can
    restore it (tests, scoped experiments)."""
    global _ACTIVE_CALIBRATION
    prev, _ACTIVE_CALIBRATION = _ACTIVE_CALIBRATION, cal
    return prev


def get_calibration() -> Optional[CalibratedCoefficients]:
    return _ACTIVE_CALIBRATION


def activate_calibration_file(path: str) -> Optional[CalibratedCoefficients]:
    """Load a coefficients file and install it process-wide.  Missing or
    corrupt files warn and leave the current calibration untouched
    (calibration is an optimization, like the tuning cache)."""
    cal = CalibratedCoefficients.load_or_none(path)
    if cal is not None:
        set_calibration(cal)
    return cal


def preferred_cost(cand: TileCandidate, m: int, n: int, k: int, *,
                   backend: Optional[str] = None,
                   calibration: Optional[CalibratedCoefficients] = None,
                   hw: HW = V5E, x_bytes: int = 2) -> CostReport:
    """The ranking seam (DESIGN.md §14.2): calibrated cost when
    coefficients for ``backend`` exist (``calibration`` argument first,
    else the process-wide active calibration; ``backend=None`` means the
    calibration's default backend), analytic roofline otherwise."""
    cal = calibration if calibration is not None else _ACTIVE_CALIBRATION
    coeffs = cal.for_backend(backend) if cal is not None else None
    if coeffs is not None:
        return calibrated_cost(cand, m, n, k, coeffs=coeffs,
                               x_bytes=x_bytes)
    return analytic_cost(cand, m, n, k, hw=hw, x_bytes=x_bytes)


def measured_cost(cand: TileCandidate, m: int, n: int, k: int, *,
                  iters: int = 3, warmup: int = 1,
                  interpret: Optional[bool] = None) -> CostReport:
    """Median wall-clock of the real kernel under this tiling. Imports jax
    lazily so the analytic path stays import-light."""
    import jax
    import jax.numpy as jnp

    from repro.core.qformats import quantize_q8_0
    from repro.kernels.bf16_matmul import bf16_matmul
    from repro.kernels.q8_matmul import q8_matmul
    from repro.kernels.q8_matvec import q8_matvec

    if interpret is None:
        from repro.backends.platform import default_interpret
        interpret = default_interpret()
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (n, k), jnp.float32) * 0.05
    if cand.kernel == "bf16_matmul":
        def fn():
            return bf16_matmul(x, w, interpret=interpret, **cand.as_kwargs())
    else:
        wq = quantize_q8_0(w)
        qs2d, sc = wq.flat_qs(), wq.scales
        if cand.kernel == "q8_matvec":
            def fn():
                return q8_matvec(x, qs2d, sc, interpret=interpret,
                                 **cand.as_kwargs())
        else:
            def fn():
                return q8_matmul(x, qs2d, sc, interpret=interpret,
                                 **cand.as_kwargs())
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    t = ts[len(ts) // 2]
    return CostReport(cand, t, t, 0.0, t, "measured")
