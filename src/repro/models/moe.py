"""Mixture-of-experts FFN with capacity-based dispatch (GShard/Switch style).

Used by ``olmoe-1b-7b`` (64e top-8), ``arctic-480b`` (128e top-2 + dense
residual branch), and ``jamba`` (16e top-2, every other layer).

Sharding story (see sharding/rules.py): expert-stacked weights (E, d, d_ff)
shard E over the "model" axis (expert parallelism) — E is a multiple of 16
for every assigned MoE arch; tokens shard over ("pod","data"). The dispatch
einsums become all-to-all-like collectives under GSPMD.

The dispatch/combine tensors are (T, E, C) one-hots — the classic
capacity-factor formulation. Tokens over capacity are dropped (their combine
weight is zero), matching Switch semantics; tests check the no-drop regime
(capacity_factor high) agrees with a dense loop-over-experts oracle.

The expert FFN itself is the paper's offload target: per-expert GEMMs with
Q8_0-quantizable stacked weights.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding import ctx


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    moe = cfg.moe
    d, dff, E = cfg.d_model, moe.d_ff, moe.num_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5

    def ew(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)

    p = {
        "router": layers.init_linear(ks[0], d, E, dtype=jnp.float32),
        # expert-stacked (E, in, out) — E shards over "model" (EP)
        "w_up": ew(ks[1], (E, d, dff), scale),
        "w_down": ew(ks[2], (E, dff, d), dff ** -0.5),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = ew(ks[3], (E, d, dff), scale)
    if moe.dense_residual_d_ff:
        p["dense"] = layers.init_mlp(ks[4], d, moe.dense_residual_d_ff,
                                     cfg.act, dtype)
    return p


def _capacity(tokens_per_group: int, moe) -> int:
    cap = int(tokens_per_group * moe.experts_per_token
              * moe.capacity_factor / moe.num_experts)
    return max(cap, moe.experts_per_token)


def router_probs(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    logits = layers.linear(p["router"], x.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array, *,
            engine=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Grouped capacity-based top-k dispatch
    (GShard): tokens route within groups of ``moe.dispatch_group`` so the
    dispatch one-hot is (G, Tg, E, Cg) — dispatch-einsum FLOPs stay a small
    fraction of the expert GEMMs (ungrouped dispatch is O(T^2) and at
    train_4k scale costs ~80x the experts themselves). Groups shard over
    the batch axes; experts shard over "model" (EP)."""
    moe = cfg.moe
    b, s, d = x.shape
    E, k = moe.num_experts, moe.experts_per_token

    probs = router_probs(p, cfg, x)                       # (B,S,E) f32
    topw, topi = jax.lax.top_k(probs, k)                  # (B,S,k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)   # renormalize

    # --- load-balance auxiliary loss (Switch eq. 4) ---
    me = jnp.mean(probs.reshape(-1, E), axis=0)                  # mean prob
    onehot_top1 = jax.nn.one_hot(topi[..., 0], E)
    ce = jnp.mean(onehot_top1.reshape(-1, E), axis=0)            # token frac
    aux = E * jnp.sum(me * ce) * moe.load_balance_coef

    # --- group tokens; capacity is per group ---
    T = b * s
    tg = min(moe.dispatch_group, T)
    if T % tg:
        tg = T                       # ragged smoke shapes: one group
    G = T // tg
    cap = _capacity(tg, moe)
    gi = topi.reshape(G, tg, k)
    gw = topw.reshape(G, tg, k)
    xin = x.reshape(G, tg, d)

    # position of each (token, choice) within its expert queue (per group),
    # k-major so higher-priority choices claim capacity first
    oh = jax.nn.one_hot(
        gi.transpose(0, 2, 1).reshape(G, k * tg), E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=1) - 1                         # (G, k*Tg, E)
    pos = jnp.sum(pos * oh, axis=-1).reshape(G, k, tg).transpose(0, 2, 1)
    keep = pos < cap                                          # (G, Tg, k)
    w_kept = gw * keep

    # dispatch one-hot (G, Tg, E, C): token t -> slot pos of expert e
    disp = (jax.nn.one_hot(gi, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=x.dtype)[..., :, None, :])  # (G,Tg,k,E,C+1)
    disp = disp[..., :cap]
    combine = jnp.sum(disp * w_kept[..., None, None].astype(x.dtype), axis=2)
    dispatch = jnp.sum(disp, axis=2)                           # (G,Tg,E,C)

    dispatch = ctx.constrain(dispatch, "batch", None, "model", None)
    combine = ctx.constrain(combine, "batch", None, "model", None)

    # --- expert compute on (G, E, C, d) slots ---
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xin)
    xe = ctx.constrain(xe, "batch", "model", None, None)
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    if cfg.act == "swiglu":
        gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32))
    ye = jnp.einsum("gecf,efd->gecd", h.astype(x.dtype),
                    p["w_down"].astype(x.dtype))
    ye = ctx.constrain(ye, "batch", "model", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine, ye).reshape(b, s, d)
    y = ctx.constrain(y, "batch", None, None)

    if "dense" in p:  # arctic's always-on dense residual branch
        y = y + layers.mlp_apply(p["dense"], x, cfg.act, engine)
    return y.astype(x.dtype), aux


def moe_ffn_dense_oracle(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """No-drop reference: loop over experts densely (tests only)."""
    moe = cfg.moe
    b, s, d = x.shape
    probs = router_probs(p, cfg, x)
    topw, topi = jax.lax.top_k(probs, moe.experts_per_token)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    y = jnp.zeros((b, s, d), jnp.float32)
    for e in range(moe.num_experts):
        up = jnp.einsum("bsd,df->bsf", x.astype(jnp.float32),
                        p["w_up"][e].astype(jnp.float32))
        if cfg.act == "swiglu":
            g = jnp.einsum("bsd,df->bsf", x.astype(jnp.float32),
                           p["w_gate"][e].astype(jnp.float32))
            h = jax.nn.silu(g) * up
        else:
            h = jax.nn.gelu(up)
        ye = jnp.einsum("bsf,fd->bsd", h, p["w_down"][e].astype(jnp.float32))
        w_e = jnp.sum(jnp.where(topi == e, topw, 0.0), axis=-1)
        y = y + ye * w_e[..., None]
    if "dense" in p:
        y = y + layers.mlp_apply(p["dense"], x, cfg.act).astype(jnp.float32)
    return y.astype(x.dtype)
