"""Mamba2 / SSD (state-space duality) mixer — arXiv:2405.21060.

Used by ``mamba2-780m`` (all layers) and ``jamba-v0.1-52b`` (7 of 8 layers,
per DESIGN.md §6.5 we use the SSD recurrence for both with per-arch d_state).

The blocked SSD algorithm is *matmul-dominated* (the C·Bᵀ and state einsums
are dot-products over d_state / head_dim), so the paper's dot-product offload
technique applies to most of its FLOPs; only the chunk-boundary recurrence is
sequential. The in/out projections are ordinary offloadable GEMMs.

Two execution paths share one parameterization:
  * ``ssd_scan``        — chunked train/prefill over a full sequence
  * ``ssm_decode_step`` — O(1) per-token recurrent update with carried state
and a pure step-by-step reference ``ssd_reference`` used by tests to verify
the chunked algorithm against the naive recurrence.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers
from repro.sharding import ctx


class SSMState(NamedTuple):
    """Carried decode state for one SSD layer."""
    conv: jax.Array   # (B, d_conv - 1, conv_dim) rolling input window
    ssd: jax.Array    # (B, H, P, N) recurrent state
    length: jax.Array  # scalar int32 — tokens absorbed so far

    @classmethod
    def zeros(cls, b: int, ssm: SSMConfig, d_model: int, dtype=jnp.float32):
        di = ssm.d_inner(d_model)
        nh = ssm.n_heads(d_model)
        conv_dim = di + 2 * ssm.n_groups * ssm.d_state
        return cls(
            conv=jnp.zeros((b, ssm.d_conv - 1, conv_dim), dtype),
            ssd=jnp.zeros((b, nh, ssm.head_dim, ssm.d_state), jnp.float32),
            length=jnp.zeros((), jnp.int32),
        )


def init_ssm(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)
    gN = ssm.n_groups * ssm.d_state
    conv_dim = di + 2 * gN
    ks = jax.random.split(key, 4)
    # in_proj emits [z (di), x (di), B (gN), C (gN), dt (nh)]
    return {
        "in_proj": layers.init_linear(ks[0], d, 2 * di + 2 * gN + nh, dtype=dtype),
        "out_proj": layers.init_linear(ks[1], di, d, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (ssm.d_conv, conv_dim), jnp.float32)
                   * (ssm.d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        # A is a per-head scalar (Mamba2): A = -exp(A_log) in (-inf, 0)
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(            # softplus^-1 of U(1e-3, 1e-1)
            jnp.linspace(1e-3, 1e-1, nh, dtype=jnp.float32))),
        "norm": layers.init_norm(di, "rmsnorm", dtype),
    }


# ---------------------------------------------------------------------------
# Chunked SSD scan (train / prefill)
# ---------------------------------------------------------------------------
def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum a[..., j+1:i+1].

    a: (..., T). Returns (..., T, T) with -inf above the diagonal.
    """
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
             B: jax.Array, C: jax.Array, chunk: int,
             initial_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD over a full sequence (Mamba2 Alg. 1, blocked-matmul form).

    x:  (b, s, h, p)   per-head inputs (pre-multiplied by nothing; dt applied here)
    dt: (b, s, h)      positive step sizes
    A:  (h,)           negative per-head decay rates
    B:  (b, s, g, n)   input projections (groups broadcast to heads)
    C:  (b, s, g, n)   output projections
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if s % chunk:
        chunk = s  # single chunk for ragged smoke shapes
    nc = s // chunk
    rep = h // g

    # dt-discretized input and decay
    xdt = x.astype(jnp.float32) * dt[..., None]                # (b,s,h,p)
    da = dt * A[None, None, :]                                 # (b,s,h)  <= 0

    # chunk views
    xc = xdt.reshape(b, nc, chunk, h, p)
    dac = da.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)    # (b,h,nc,l)
    Bc = jnp.repeat(B.astype(jnp.float32).reshape(b, nc, chunk, g, n), rep, axis=3)
    Cc = jnp.repeat(C.astype(jnp.float32).reshape(b, nc, chunk, g, n), rep, axis=3)

    da_cum = jnp.cumsum(dac, axis=-1)                          # (b,h,nc,l)
    L = jnp.exp(_segsum(dac))                                  # (b,h,nc,l,l)

    # 1) intra-chunk (diagonal blocks): dot-product heavy — offloadable
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # 2) per-chunk states: decayed contribution of each position to chunk end
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)          # (b,h,nc,l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # 3) inter-chunk recurrence over chunk boundary states
    if initial_state is None:
        init = jnp.zeros((b, 1, h, p, n), jnp.float32)
    else:
        init = initial_state.astype(jnp.float32)[:, None]
    states = jnp.concatenate([init, states], axis=1)           # (b,nc+1,h,p,n)
    chunk_decay = da_cum[..., -1]                              # (b,h,nc)
    pad = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(pad))                        # (b,h,nc+1,nc+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # 4) state -> output contribution within each chunk
    state_decay_out = jnp.exp(da_cum)                          # (b,h,nc,l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def ssd_reference(x, dt, A, B, C,
                  initial_state: Optional[jax.Array] = None):
    """Naive per-step recurrence (the oracle for ssd_scan):
       h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_tᵀ ;  y_t = C_t · h_t."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=2)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    state = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))

    def step(state, t):
        xt = x[:, t].astype(jnp.float32)          # (b,h,p)
        dtt = dt[:, t].astype(jnp.float32)        # (b,h)
        decay = jnp.exp(dtt * A[None, :])         # (b,h)
        upd = jnp.einsum("bhn,bhp->bhpn", Bh[:, t], xt * dtt[..., None])
        state = state * decay[..., None, None] + upd
        yt = jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state)
        return state, yt

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3), state        # (b,s,h,p), (b,h,p,n)


# ---------------------------------------------------------------------------
# Full mixer: in_proj -> conv -> SSD -> gated norm -> out_proj
# ---------------------------------------------------------------------------
def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    ssm = cfg.ssm
    di = ssm.d_inner(cfg.d_model)
    gN = ssm.n_groups * ssm.d_state
    nh = ssm.n_heads(cfg.d_model)
    z, xBC, dt = jnp.split(zxbcdt, [di, di + di + 2 * gN], axis=-1)
    return z, xBC, dt, di, gN, nh


def ssm_mixer(p: dict, cfg: ModelConfig, u: jax.Array, *,
              engine=None) -> jax.Array:
    """Full-sequence SSD mixer. u: (B, S, d_model) -> (B, S, d_model)."""
    ssm = cfg.ssm
    b, s, _ = u.shape
    zxbcdt = layers.linear(p["in_proj"], u, engine, "ssm.in_proj")
    z, xBC, dt, di, gN, nh = _split_proj(cfg, zxbcdt.astype(u.dtype))

    # causal depthwise conv over the (x, B, C) channels
    w = p["conv_w"].astype(jnp.float32)            # (d_conv, conv_dim)
    xpad = jnp.pad(xBC.astype(jnp.float32), ((0, 0), (ssm.d_conv - 1, 0), (0, 0)))
    conv = sum(xpad[:, i:i + s] * w[i] for i in range(ssm.d_conv))
    xBC = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))

    x, B, C = jnp.split(xBC, [di, di + gN], axis=-1)
    x = x.reshape(b, s, nh, ssm.head_dim)
    B = B.reshape(b, s, ssm.n_groups, ssm.d_state)
    C = C.reshape(b, s, ssm.n_groups, ssm.d_state)
    x = ctx.constrain(x, "batch", None, "model", None)
    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    y, _ = ssd_scan(x, dt, A, B, C, ssm.chunk)
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di)

    # gated RMSNorm (Mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.norm_apply(p["norm"], y.astype(u.dtype), "rmsnorm")
    return layers.linear(p["out_proj"], y, engine, "ssm.out_proj").astype(u.dtype)


def ssm_decode_step(p: dict, cfg: ModelConfig, u: jax.Array,
                    state: SSMState, *, engine=None
                    ) -> Tuple[jax.Array, SSMState]:
    """One-token recurrent update. u: (B, 1, d_model)."""
    ssm = cfg.ssm
    b = u.shape[0]
    zxbcdt = layers.linear(p["in_proj"], u[:, 0], engine, "ssm.in_proj")
    z, xBC, dt, di, gN, nh = _split_proj(cfg, zxbcdt)

    # rolling conv window: state.conv holds the previous d_conv-1 inputs
    window = jnp.concatenate(
        [state.conv.astype(jnp.float32), xBC.astype(jnp.float32)[:, None]], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("btc,tc->bc", window, w) + p["conv_b"].astype(jnp.float32)
    xBC_a = jax.nn.silu(conv)
    new_conv = window[:, 1:].astype(state.conv.dtype)

    x, B, C = jnp.split(xBC_a, [di, di + gN], axis=-1)
    x = x.reshape(b, nh, ssm.head_dim)
    B = B.reshape(b, ssm.n_groups, ssm.d_state)
    C = C.reshape(b, ssm.n_groups, ssm.d_state)
    rep = nh // ssm.n_groups
    Bh = jnp.repeat(B, rep, axis=1)
    Ch = jnp.repeat(C, rep, axis=1)
    A = -jnp.exp(p["A_log"])
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (b, nh)

    decay = jnp.exp(dt1 * A[None, :])
    upd = jnp.einsum("bhn,bhp->bhpn", Bh, x * dt1[..., None])
    new_ssd = state.ssd * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_ssd)
    y = y + x * p["D"][None, :, None]
    y = y.reshape(b, di)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.norm_apply(p["norm"], y.astype(u.dtype), "rmsnorm")
    out = layers.linear(p["out_proj"], y[:, None], engine, "ssm.out_proj")
    return out.astype(u.dtype), SSMState(new_conv, new_ssd, state.length + 1)
