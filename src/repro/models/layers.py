"""Shared building blocks: norms, rotary embeddings, linear/MLP, embeddings.

Functional style: ``init_*`` returns a param dict; ``*_apply`` consumes it.
Weights are stored (out_features, in_features) — the kernels' W[N, K] layout.
The linear path is pluggable: training/dry-run uses the XLA contraction;
serving can route through core.offload.OffloadEngine with Q8_0 weights.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qformats import QTensor


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.bfloat16, scale: Optional[float] = None) -> dict:
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_out, d_in), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array, engine=None, name: str = "linear") -> jax.Array:
    """y = x @ W^T (+ b). ``engine`` routes through the offload dispatcher
    (paper path: Q8_0/bf16 Pallas kernel main + host residual). The engine
    path is trace-pure (DESIGN.md §10.1) — routing resolves from static
    shapes and the static ``name`` identifies the call site in recorded
    dispatch plans — so callers may sit inside ``jax.jit`` freely."""
    w = p["w"]
    if engine is not None:
        y = engine.linear(x, w, name=name).astype(x.dtype)
    elif isinstance(w, QTensor):
        # XLA dequant path (same math as kernels/ref.py)
        wd = (w.qs.astype(jnp.float32) * w.scales[..., None]).reshape(w.shape)
        y = jax.lax.dot_general(x, wd.astype(x.dtype),
                                (((x.ndim - 1,), (1,)), ((), ())))
    else:
        y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (1,)), ((), ())))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(d: int, kind: str, dtype=jnp.bfloat16) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: dict, x: jax.Array, kind: str, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table (n, d)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10_000.0) * dim / (d // 2 - 1 + 1e-9))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, d_ff: int, act: str, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": init_linear(ks[0], d, d_ff, dtype=dtype),
         "down": init_linear(ks[1], d_ff, d, dtype=dtype)}
    if act == "swiglu":
        p["gate"] = init_linear(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, act: str, engine=None) -> jax.Array:
    up = linear(p["up"], x, engine, "ffn.up")
    if act == "swiglu":
        gate = linear(p["gate"], x, engine, "ffn.gate")
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32))
    return linear(p["down"], h.astype(x.dtype), engine, "ffn.down")


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
                      ).astype(dtype)}


def embed(p: dict, ids: jax.Array) -> jax.Array:
    t = p["table"]
    if isinstance(t, QTensor):
        # row-wise dequant of the Q8_0 table (whisper.cpp quantizes the
        # token embedding; lookups dequantize only the gathered rows)
        qs = jnp.take(t.qs, ids, axis=0)          # (..., K/32, 32)
        sc = jnp.take(t.scales, ids, axis=0)      # (..., K/32)
        rows = qs.astype(jnp.float32) * sc[..., None]
        return rows.reshape(*ids.shape, t.k)
    return jnp.take(t, ids, axis=0)


def unembed(p: dict, x: jax.Array, engine=None) -> jax.Array:
    """Tied readout: logits = x @ table^T (the paper's ``dec.vocab`` kernel
    class — its single largest dot-product)."""
    t = p["table"]
    if engine is not None or isinstance(t, QTensor):
        return linear({"w": t}, x, engine, "dec.vocab")
    return jax.lax.dot_general(x, t, (((x.ndim - 1,), (1,)), ((), ())))
