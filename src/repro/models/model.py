"""Family dispatch: one public API over every assigned architecture.

  init_params(key, cfg, max_positions)        -> param pytree
  forward(params, cfg, batch)                 -> (logits, aux)   train/prefill
  loss_fn(params, cfg, batch)                 -> (loss, metrics)
  init_serve_state(params, cfg, batch, max_len) -> decode state pytree
  serve_step(params, cfg, token, state)       -> (logits, state')  one token

Batch dict conventions (mirrored by launch/input_specs.py):
  LM families : {"tokens": (B,S) i32, "labels": (B,S) i32}
  vlm         : + {"patches": (B,P,E_vis) f32}  — precomputed anyres tiles,
                projected and spliced over the first P token positions
  audio       : {"mel": (B,F,n_mels) f32, "tokens": (B,T), "labels": (B,T)}
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, transformer, whisper
from repro.sharding import ctx


class ServeState(NamedTuple):
    """Decode-state wrapper uniform across families.

    Two layouts share this type (DESIGN.md §11.1):
      standard — ``step`` and the per-layer cache ``length`` counters are
        scalars; every batch row decodes in lockstep (generate/transcribe).
      slot     — counters are per-slot vectors (``step``: (B,), stacked
        lengths: (R, B)) so each slot of a continuous-batching pool sits
        at its own position inside one fixed-shape batch.
    ``slot_layout`` converts standard -> slot; data leaves are identical
    in both (counters aside, every layer_states leaf carries the batch on
    axis 1, after the layer-stack axis — the invariant the slot-pool
    splice in serve/kvcache.py relies on).
    """
    layer_states: Any     # list per pattern position (LM) | WhisperDecodeState
    step: jax.Array       # () or (B,) i32 — absolute position of next token


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig, max_positions: int = 0) -> dict:
    if cfg.family == "audio":
        return whisper.init_whisper(key, cfg, max_positions)
    pdtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 4)
    params = {
        "embed": layers.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model,
                                       pdtype),
        "stack": transformer.init_decoder_stack(ks[1], cfg),
        "final_norm": layers.init_norm(cfg.d_model, cfg.norm, pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_linear(ks[2], cfg.d_model,
                                               cfg.padded_vocab, dtype=pdtype)
    if cfg.family == "vlm":
        params["projector"] = layers.init_linear(
            ks[3], cfg.vision_embed_dim, cfg.d_model, bias=True, dtype=pdtype)
    return params


# ---------------------------------------------------------------------------
# Embedding / readout shared by LM families
# ---------------------------------------------------------------------------
def _embed_inputs(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  engine=None) -> jax.Array:
    x = layers.embed(params["embed"], batch["tokens"]).astype(_dtype(cfg))
    if cfg.family == "vlm" and "patches" in batch:
        proj = layers.linear(params["projector"], batch["patches"],
                             engine, "vlm.projector").astype(x.dtype)
        p = proj.shape[1]
        # splice: precomputed patch embeddings occupy the first P positions
        x = jnp.concatenate([proj, x[:, p:]], axis=1)
    return ctx.constrain(x, "batch", None, None)


def _readout(params: dict, cfg: ModelConfig, x: jax.Array,
             engine=None) -> jax.Array:
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], x, engine)
    return layers.linear(params["lm_head"], x, engine, "lm_head")


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def hidden_forward(params: dict, cfg: ModelConfig,
                   batch: Dict[str, jax.Array], *,
                   engine=None, attn_chunk: int = 2048
                   ) -> Tuple[jax.Array, jax.Array]:
    """Backbone only: (final hidden states pre-readout, moe_aux_loss)."""
    if cfg.family == "audio":
        memory = whisper.encode(params, cfg, batch["mel"], engine=engine,
                                attn_chunk=attn_chunk)
        h = whisper.decode_train(params, cfg, batch["tokens"], memory,
                                 engine=engine, attn_chunk=attn_chunk,
                                 return_hidden=True)
        return h, jnp.zeros((), jnp.float32)
    x = _embed_inputs(params, cfg, batch, engine)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = transformer.apply_decoder_stack(params["stack"], cfg, x,
                                             positions=positions,
                                             engine=engine,
                                             attn_chunk=attn_chunk)
    return x, aux


def forward(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            engine=None, attn_chunk: int = 2048
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits, moe_aux_loss)."""
    h, aux = hidden_forward(params, cfg, batch, engine=engine,
                            attn_chunk=attn_chunk)
    if cfg.family == "audio":
        return whisper_readout(params, cfg, h, engine), aux
    return _readout(params, cfg, h, engine), aux


def whisper_readout(params: dict, cfg: ModelConfig, x: jax.Array,
                    engine=None) -> jax.Array:
    x = layers.norm_apply(params["dec_norm"], x, cfg.norm)
    return layers.unembed(params["embed"], x, engine)


def _ce_of_logits(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> Tuple[jax.Array, jax.Array]:
    """Masked CE sums for one chunk. Pad columns (>= vocab_size) excluded."""
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    if v > vocab_size:  # Megatron-style vocab pad: mask pad columns
        col = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
        logits = jnp.where(col < vocab_size, logits, -1e30)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * mask), jnp.sum(mask)


def loss_fn(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            engine=None, attn_chunk: int = 2048, ce_chunk: int = 512
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (labels already shifted by the data pipeline);
    label -1 positions are masked.

    The readout + CE is *sequence-chunked* under jax.checkpoint: full
    (B, S, V) f32 logits are never materialized — at qwen/whisper scale
    (V=152k/52k, B*S=1M tokens) the monolithic logits tensor alone would be
    hundreds of GiB per pod. Chunking costs one extra readout GEMM in the
    backward pass per chunk (remat) and bounds the logits temp at
    (B, ce_chunk, V).
    """
    h, aux = hidden_forward(params, cfg, batch, engine=engine,
                            attn_chunk=attn_chunk)
    labels = batch["labels"]
    readout = (whisper_readout if cfg.family == "audio" else _readout)

    b, s, d = h.shape
    n_chunks = s // ce_chunk if (s % ce_chunk == 0 and s > ce_chunk) else 1
    if n_chunks == 1:
        logits = readout(params, cfg, h, engine)
        ce_sum, ntok = _ce_of_logits(logits, labels, cfg.vocab_size)
    else:
        hc = jnp.moveaxis(h.reshape(b, n_chunks, ce_chunk, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, n_chunks, ce_chunk), 1, 0)

        @jax.checkpoint
        def chunk_ce(h_i, l_i):
            logits = readout(params, cfg, h_i, engine)
            logits = ctx.constrain(logits, "batch", None, "model")
            return _ce_of_logits(logits, l_i, cfg.vocab_size)

        def body(carry, xs):
            cs, nt = chunk_ce(*xs)
            return (carry[0] + cs, carry[1] + nt), None

        (ce_sum, ntok), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, lc))
    ntok = jnp.maximum(ntok, 1.0)
    loss = ce_sum / ntok
    total = loss + aux
    return total, {"ce": loss, "moe_aux": aux, "ntok": ntok}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def slot_layout(state: ServeState, batch: int) -> ServeState:
    """Standard -> slot layout (DESIGN.md §11.1): broadcast the scalar
    step/length counters to per-slot vectors so each row of a fixed-shape
    slot pool tracks its own decode position.

    The leaf rule is structural: counters are the only ``ndim <= 1``
    leaves of a decode state — ``()`` (an unstacked length / ServeState
    ``step``) broadcasts to ``(batch,)``, ``(R,)`` (a layer-stacked
    length) to ``(R, batch)``. Every data leaf (KV buffers, SSM states,
    whisper cross-KV) is ``ndim >= 3`` with the batch on axis 1 and
    passes through untouched. Idempotent on already-slot-layout states.
    """
    def conv(a):
        if a.ndim == 0:
            return jnp.broadcast_to(a, (batch,))
        if a.ndim == 1:
            return jnp.broadcast_to(a[:, None], (a.shape[0], batch))
        return a

    step = (jnp.broadcast_to(state.step, (batch,)) if state.step.ndim == 0
            else state.step)
    return ServeState(
        layer_states=jax.tree_util.tree_map(conv, state.layer_states),
        step=step)


def slot_batch_axis(leaf_is_step: bool) -> int:
    """Batch axis of a slot-layout leaf for the pool splice
    (serve/kvcache.py): ``ServeState.step`` is ``(B,)`` -> axis 0; every
    ``layer_states`` leaf — data and ``(R, B)`` counters alike — carries
    the batch on axis 1 after the layer-stack axis."""
    return 0 if leaf_is_step else 1


def slot_state_specs(state: ServeState, mesh) -> ServeState:
    """PartitionSpec pytree for a slot-layout ``ServeState``: the slot
    axis (``slot_batch_axis``) shards over the mesh's "data" axis — the
    slot pool IS sharded serving's data axis (DESIGN.md §13) — whenever
    the pool width divides it; every other dim stays replicated. Lives
    next to ``slot_layout`` because it encodes the same structural
    invariant (batch on axis 1 of every ``layer_states`` leaf); the
    divisibility fallback keeps one call site valid on any mesh, in the
    style of sharding/rules.py."""
    from jax.sharding import PartitionSpec as P
    dsize = mesh.shape["data"] if "data" in mesh.axis_names else 1

    def spec(leaf, axis):
        if dsize <= 1 or leaf.ndim <= axis or leaf.shape[axis] % dsize:
            return P()
        return P(*([None] * axis + ["data"]))

    return ServeState(
        layer_states=jax.tree_util.tree_map(
            lambda l: spec(l, slot_batch_axis(False)), state.layer_states),
        step=spec(state.step, slot_batch_axis(True)))


def state_kv_bytes(state: Any) -> int:
    """Committed bytes of a decode-state pytree (KV buffers + counters +
    block tables). The serving benchmarks report this next to tok/s so
    the paged pool's memory win (DESIGN.md §15.4) is measured by the same
    harness that gates token parity."""
    return sum(int(l.size) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(state))


def init_serve_state(params: dict, cfg: ModelConfig, batch: int, max_len: int,
                     *, memory: Optional[jax.Array] = None, engine=None,
                     prefill_len: int = 0) -> ServeState:
    if cfg.family == "audio":
        assert memory is not None, "whisper decode needs encoder memory"
        st = whisper.init_whisper_decode_state(params, cfg, memory, max_len,
                                               engine=engine, dtype=_dtype(cfg))
    else:
        st = transformer.init_decode_state(cfg, batch, max_len, _dtype(cfg))
    return ServeState(layer_states=st, step=jnp.asarray(prefill_len, jnp.int32))


def serve_step(params: dict, cfg: ModelConfig, token: jax.Array,
               state: ServeState, *, engine=None
               ) -> Tuple[jax.Array, ServeState]:
    """token: (B, 1) i32 -> (logits (B, 1, V), state').

    Trace-pure with an ``engine`` attached (DESIGN.md §10.1): offload
    routing resolves from static shapes at trace time and nothing mutates
    host state, so serve/engine.py jits this step unconditionally
    (regression-tested by tests/test_plan.py)."""
    if cfg.family == "audio":
        logits, st = whisper.decode_step(params, cfg, token,
                                         state.layer_states, engine=engine)
        return logits, ServeState(st, state.step + 1)
    x = layers.embed(params["embed"], token).astype(_dtype(cfg))
    x, st = transformer.decode_step_stack(params["stack"], cfg, x,
                                          state.layer_states, engine=engine)
    logits = _readout(params, cfg, x, engine)
    return logits, ServeState(st, state.step + 1)


def verify_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                state: ServeState, *, engine=None
                ) -> Tuple[jax.Array, ServeState]:
    """Score a W-token verify window in one forward (DESIGN.md §17.1):
    tokens (B, W) i32 -> (logits (B, W, V), state') with every cache
    length (and ``step``) advanced by W. ``logits[:, j]`` equals what
    ``serve_step`` would emit after feeding ``tokens[:, :j+1]`` one at a
    time — the token-exactness contract speculative acceptance relies
    on. Audio (whisper) only for now: the draft/verify ladder is the
    Whisper scaling study's regime (tiny drafts, base/small verifies)."""
    if cfg.family != "audio":
        raise NotImplementedError(
            "speculative verify windows are wired for the audio family "
            "(the Whisper ladder); LM families still serve_step one token")
    logits, st = whisper.verify_step(params, cfg, tokens,
                                     state.layer_states, engine=engine)
    return logits, ServeState(st, state.step + tokens.shape[1])


def set_slot_lengths(state: ServeState, new_len: jax.Array) -> ServeState:
    """Splice per-slot decode positions to ``new_len`` (B,) — the
    speculative rollback (DESIGN.md §17.1): after a verify window
    advanced every length by W, the accepted prefix keeps only
    ``1 + accept_len`` of those tokens, so the counters rewind while the
    over-written KV entries beyond ``new_len`` stay in place (masked by
    the validity test, then overwritten by the next window).

    Structural rule, the inverse discipline of ``slot_layout``: in the
    slot layout the counters are exactly the ``ndim <= 2`` leaves —
    ``step`` (B,) and layer-stacked lengths (R, B) — and every data leaf
    is ``ndim >= 3``, so counters broadcast-assign from ``new_len`` and
    data passes through untouched.

    The paged layout (DESIGN.md §15.2) breaks that structural rule: its
    block/cross tables are ndim-2 *data* leaves (B, max_pages) int32, so
    it splices by field name instead — only ``length`` (R, B) and
    ``step`` rewind; the tables and page arenas pass through untouched
    (rejected-suffix *pages* are released host-side by the paged
    scheduler's post-round trim, DESIGN.md §17.4)."""
    new_len = jnp.asarray(new_len, jnp.int32)
    ls = state.layer_states
    if isinstance(ls, whisper.WhisperPagedDecodeState):
        ls = ls._replace(
            length=jnp.broadcast_to(new_len[None, :], ls.length.shape))
        return ServeState(layer_states=ls,
                          step=jnp.broadcast_to(new_len, state.step.shape))

    def conv(a):
        if a.ndim == 1:                       # (B,) unstacked counter
            return jnp.broadcast_to(new_len, a.shape)
        if a.ndim == 2:                       # (R, B) layer-stacked counter
            return jnp.broadcast_to(new_len[None, :], a.shape)
        return a

    return ServeState(
        layer_states=jax.tree_util.tree_map(conv, state.layer_states),
        step=jnp.broadcast_to(new_len, state.step.shape))


def prefill(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
            state: ServeState, *, engine=None, attn_chunk: int = 2048
            ) -> Tuple[jax.Array, ServeState]:
    """Sequence prefill that fills the decode caches, returning last-token
    logits. Implemented as a scan of serve_step for state-carrying families
    (correct, if not flash-fast; the prefill_32k dry-run cells lower
    ``forward`` instead, which is the throughput path). This is the
    serving engine's LM prefill: one jitted call replaces the former
    per-token Python loop, and its dispatch plan records one scan-body
    execution — the ledger commits it ``seq_len`` times (DESIGN.md §10.2)."""
    tokens = batch["tokens"]
    s = tokens.shape[1]

    def body(st, t):
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        logits, st = serve_step(params, cfg, tok, st, engine=engine)
        return st, logits

    state, logits = jax.lax.scan(body, state, jnp.arange(s))
    return logits[-1], state
