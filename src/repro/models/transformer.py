"""Decoder-only transformer stack for the dense / MoE / SSM / hybrid / VLM
families, with a periodic layer *pattern* so heterogeneous stacks (jamba's
1:7 attention:mamba interleave with MoE every other layer) still scan.

Layers are grouped into a repeating pattern of length P (P = lcm of the
attention and MoE periods); parameters are stacked (R, ...) per pattern
position with R = num_layers / P repeats. ``lax.scan`` over R keeps the HLO
(and compile time) O(P) instead of O(num_layers) — essential for the
80-layer qwen1.5-110b dry-run — and ``jax.checkpoint`` applies the remat
policy per scanned block.
"""
from __future__ import annotations

import math
from typing import List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.attention import KVCache, attention, decode_attention, init_attention
from repro.sharding import ctx


class LayerSpec(NamedTuple):
    mixer: str   # "attn" | "ssm"
    ffn: str     # "dense" | "moe" | "none"


def layer_pattern(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    p = 1
    if cfg.family == "hybrid":
        p = math.lcm(cfg.attn_every, cfg.moe_every if cfg.moe else 1)
    elif cfg.moe is not None and cfg.moe_every > 1:
        p = cfg.moe_every
    if cfg.num_layers % p:
        raise ValueError(f"{cfg.name}: num_layers {cfg.num_layers} "
                         f"not divisible by pattern {p}")
    specs = []
    for i in range(p):
        if cfg.family == "ssm":
            mixer = "ssm"
        elif cfg.family == "hybrid":
            mixer = "attn" if i % cfg.attn_every == cfg.attn_offset else "ssm"
        else:
            mixer = "attn"
        if cfg.moe is not None and i % cfg.moe_every == cfg.moe_offset:
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "dense"
        else:
            ffn = "none"
        specs.append(LayerSpec(mixer, ffn))
    return tuple(specs)


def n_repeats(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(layer_pattern(cfg))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _stack_init(fn, key, r: int):
    """vmap an init over R repeats -> leaves gain a leading (R, ...) dim."""
    return jax.vmap(fn)(jax.random.split(key, r))


def _init_block(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {"norm1": layers.init_norm(cfg.d_model, cfg.norm, dtype)}
    if spec.mixer == "attn":
        p["attn"] = init_attention(ks[0], cfg, dtype)
    else:
        p["ssm"] = ssm_lib.init_ssm(ks[0], cfg, dtype)
    if spec.ffn != "none":
        p["norm2"] = layers.init_norm(cfg.d_model, cfg.norm, dtype)
        if spec.ffn == "moe":
            p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def init_decoder_stack(key, cfg: ModelConfig) -> dict:
    dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    pattern = layer_pattern(cfg)
    r = n_repeats(cfg)
    ks = jax.random.split(key, len(pattern))
    blocks = [
        _stack_init(lambda k, s=spec: _init_block(k, cfg, s, dtype), ks[i], r)
        for i, spec in enumerate(pattern)
    ]
    return {"blocks": blocks}


# ---------------------------------------------------------------------------
# Full-sequence apply (train / prefill)
# ---------------------------------------------------------------------------
def _apply_block(p: dict, cfg: ModelConfig, spec: LayerSpec, x: jax.Array, *,
                 positions, engine, attn_chunk: int) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    # batch pinned only; residual-stream sequence parallelism measured
    # WORSE here (collective +75%, §Perf A5 refuted — GSPMD inserts extra
    # resharding at the MoE/router and CE boundaries instead of clean
    # all-gather/reduce-scatter pairs)
    x = ctx.constrain(x, "batch", None, None)
    h = layers.norm_apply(p["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        mixed = attention(p["attn"], cfg, h, positions=positions,
                          causal=True, chunk=attn_chunk, engine=engine)
    else:
        mixed = ssm_lib.ssm_mixer(p["ssm"], cfg, h, engine=engine)
    x = x + mixed.astype(x.dtype)
    if spec.ffn != "none":
        h = layers.norm_apply(p["norm2"], x, cfg.norm)
        if spec.ffn == "moe":
            y, aux = moe_lib.moe_ffn(p["moe"], cfg, h, engine=engine)
        else:
            y = layers.mlp_apply(p["ffn"], h, cfg.act, engine=engine)
        x = x + y.astype(x.dtype)
    return x, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def apply_decoder_stack(params: dict, cfg: ModelConfig, x: jax.Array, *,
                        positions: Optional[jax.Array] = None,
                        engine=None, attn_chunk: int = 2048
                        ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, moe_aux_loss)."""
    pattern = layer_pattern(cfg)

    def repeat_fn(x, block_params: List[dict]):
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(pattern):
            x, a = _apply_block(block_params[i], cfg, spec, x,
                                positions=positions, engine=engine,
                                attn_chunk=attn_chunk)
            aux = aux + a
        return x, aux

    repeat_fn = _remat(repeat_fn, cfg)

    if cfg.scan_layers:
        def body(carry, xs):
            x, aux = carry
            x, a = repeat_fn(x, xs)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for r in range(n_repeats(cfg)):
            block_r = jax.tree_util.tree_map(lambda a: a[r], params["blocks"])
            x, a = repeat_fn(x, block_r)
            aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# Decode (one token, carried state)
# ---------------------------------------------------------------------------
LayerState = Union[KVCache, ssm_lib.SSMState]


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> List[LayerState]:
    """Per-pattern-position stacked states (leading dim R)."""
    pattern = layer_pattern(cfg)
    r = n_repeats(cfg)
    out: List[LayerState] = []
    for spec in pattern:
        if spec.mixer == "attn":
            cache_cls = (__import__("repro.models.attention",
                                    fromlist=["QKVCache"]).QKVCache
                         if cfg.kv_quant == "q8" else KVCache)
            st = cache_cls.zeros(batch, max_len, cfg.num_kv_heads,
                                 cfg.head_dim, dtype)
        else:
            st = ssm_lib.SSMState.zeros(batch, cfg.ssm, cfg.d_model)
        out.append(jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (r, *a.shape)), st))
    return out


def decode_step_stack(params: dict, cfg: ModelConfig, x: jax.Array,
                      states: List[LayerState], *, engine=None
                      ) -> Tuple[jax.Array, List[LayerState]]:
    """x: (B, 1, d); states as from init_decode_state. Returns (y, states')."""
    pattern = layer_pattern(cfg)

    def repeat_fn(x, block_params, states_r):
        new_states = []
        for i, spec in enumerate(pattern):
            p = block_params[i]
            h = layers.norm_apply(p["norm1"], x, cfg.norm)
            if spec.mixer == "attn":
                mixed, st = decode_attention(p["attn"], cfg, h, states_r[i],
                                             engine=engine)
            else:
                mixed, st = ssm_lib.ssm_decode_step(p["ssm"], cfg, h,
                                                    states_r[i], engine=engine)
            x = x + mixed.astype(x.dtype)
            new_states.append(st)
            if spec.ffn != "none":
                h = layers.norm_apply(p["norm2"], x, cfg.norm)
                if spec.ffn == "moe":
                    y, _ = moe_lib.moe_ffn(p["moe"], cfg, h, engine=engine)
                else:
                    y = layers.mlp_apply(p["ffn"], h, cfg.act, engine=engine)
                x = x + y.astype(x.dtype)
        return x, new_states

    if cfg.scan_layers:
        def body(x, xs):
            block_params, states_r = xs
            x, new_states = repeat_fn(x, block_params, states_r)
            return x, new_states
        x, new_states = jax.lax.scan(body, x, (params["blocks"], states))
    else:
        r = n_repeats(cfg)
        acc = []
        for i in range(r):
            block_r = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            states_r = jax.tree_util.tree_map(lambda a: a[i], states)
            x, st = repeat_fn(x, block_r, states_r)
            acc.append(st)
        # restack (R, ...) per position
        new_states = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *acc)
    return x, new_states
