"""Whisper encoder-decoder backbone (the paper's workload, §3 Fig 1).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed 80-channel mel frames and a single linear projection stands in
for the two stride conv layers. Everything downstream — encoder self-attn
stack, decoder self+cross attention, tied vocab readout — is real and routes
every GEMM through the paper's offload engine when one is passed.

Decode follows whisper.cpp's split (paper Fig 1): the encoder runs once per
utterance, each decoder layer's cross K/V is projected once from the encoder
memory (``dec.cross.kv`` in the coverage enumeration), then tokens decode
autoregressively against the cached self-attention KV.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.attention import (
    KVCache, PagedKVCache, attention, decode_attention, init_attention)
from repro.models.transformer import _remat
from repro.sharding import ctx


class WhisperDecodeState(NamedTuple):
    self_kv: List[KVCache]          # stacked (R, ...) decoder self-attn cache
    cross_kv: Tuple[jax.Array, jax.Array]  # (R, B, F, Hkv, hd) x2, fixed


class WhisperPagedDecodeState(NamedTuple):
    """Paged slot-pool decode state (DESIGN.md §15.2): self-attn KV and
    the per-utterance cross-KV both live in fixed-shape page arenas, with
    one block table per slot shared by every layer (a page is ``page``
    positions x all ``R`` layers). Physical page 0 of each arena is the
    trash page free slots write/read through. ``length`` carries the
    per-layer (R, B) decode positions exactly like the contiguous slot
    layout, so ``decode_step`` position handling is unchanged."""
    self_k: jax.Array        # (R, P, page, Hkv, hd) self-KV page arena
    self_v: jax.Array        # (R, P, page, Hkv, hd)
    cross_k: jax.Array       # (R, Pc, cpage, Hkv, hd) cross-KV page arena
    cross_v: jax.Array       # (R, Pc, cpage, Hkv, hd)
    block_table: jax.Array   # (B, max_pages) i32 — self logical -> physical
    cross_table: jax.Array   # (B, n_cross_pages) i32 — frames -> physical
    length: jax.Array        # (R, B) i32 — tokens valid per layer/slot


def warm_tuning(cfg: ModelConfig, engine, *, n_frames: int = 1500,
                n_tokens: int = 27, batch: int = 1,
                quant: Optional[str] = None) -> int:
    """Pre-tune every GEMM shape of one Whisper inference (the coverage
    enumerator's invocation classes, batch-scaled) so the first utterance
    never stalls on an autotuning sweep — the offline analog of the paper
    choosing its LMM/burst point before synthesis (DESIGN.md §9.4).
    ``quant`` is the *serving* quantization (ServeEngine may override
    cfg.quant); it selects which kernel family's keys get warmed. Returns
    the number of distinct shapes tuned; 0 if the engine carries no tuner."""
    if engine is None or getattr(engine, "tuner", None) is None:
        return 0
    from repro.core.coverage import MulMat, enumerate_whisper
    q = quant if quant is not None else cfg.quant
    dtype = "q8_0" if q == "q8_0" else "bf16"
    mulmats = [MulMat(m.name, m=m.m * batch, k=m.k, n=m.n)
               for m in enumerate_whisper(cfg, n_frames, n_tokens)]
    return engine.tuner.warm(mulmats, dtype=dtype)


def _stack_init(fn, key, r: int):
    return jax.vmap(fn)(jax.random.split(key, r))


def _init_enc_block(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "norm1": layers.init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "norm2": layers.init_norm(cfg.d_model, cfg.norm, dtype),
        "ffn": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "norm1": layers.init_norm(cfg.d_model, cfg.norm, dtype),
        "self_attn": init_attention(ks[0], cfg, dtype),
        "norm_x": layers.init_norm(cfg.d_model, cfg.norm, dtype),
        "cross_attn": init_attention(ks[1], cfg, dtype, cross=True),
        "norm2": layers.init_norm(cfg.d_model, cfg.norm, dtype),
        "ffn": layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init_whisper(key, cfg: ModelConfig, max_positions: int = 0) -> dict:
    dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    maxp = max(max_positions, cfg.encoder_ctx, 448)
    return {
        # frontend stub: mel (.., n_mels) -> d_model (conv x2 stride 2 stand-in)
        "frontend": layers.init_linear(ks[0], cfg.n_mels, d, bias=True,
                                       dtype=dtype),
        "enc_pos": {"table": layers.sinusoidal_positions(maxp, d).astype(dtype)},
        "enc_blocks": _stack_init(lambda k: _init_enc_block(k, cfg, dtype),
                                  ks[1], cfg.num_encoder_layers),
        "enc_norm": layers.init_norm(d, cfg.norm, dtype),
        "embed": layers.init_embedding(ks[2], cfg.padded_vocab, d, dtype),
        "dec_pos": {"table": (jax.random.normal(ks[3], (maxp, d), jnp.float32)
                              * 0.01).astype(dtype)},
        "dec_blocks": _stack_init(lambda k: _init_dec_block(k, cfg, dtype),
                                  ks[4], cfg.num_layers),
        "dec_norm": layers.init_norm(d, cfg.norm, dtype),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------
def encode(params: dict, cfg: ModelConfig, mel: jax.Array, *,
           engine=None, attn_chunk: int = 2048) -> jax.Array:
    """mel: (B, F, n_mels) precomputed frames -> (B, F, d) memory.

    Trace-pure with an ``engine`` (DESIGN.md §10.1): serving jits the
    whole prefill (encode + cross-K/V projection) in one compiled call."""
    x = layers.linear(params["frontend"], mel.astype(jnp.float32), engine,
                      "enc.frontend")
    x = jax.nn.gelu(x)
    f = x.shape[1]
    dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    x = (x + params["enc_pos"]["table"][:f].astype(jnp.float32)).astype(dtype)

    def block(x, p):
        x = ctx.constrain(x, "batch", None, None)
        h = layers.norm_apply(p["norm1"], x, cfg.norm)
        x = x + attention(p["attn"], cfg, h, causal=False, chunk=attn_chunk,
                          engine=engine).astype(x.dtype)
        h = layers.norm_apply(p["norm2"], x, cfg.norm)
        x = x + layers.mlp_apply(p["ffn"], h, cfg.act, engine=engine
                                 ).astype(x.dtype)
        return x

    block = _remat(block, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, p: (block(c, p), None), x,
                            params["enc_blocks"])
    else:
        for i in range(cfg.num_encoder_layers):
            p = jax.tree_util.tree_map(lambda a: a[i], params["enc_blocks"])
            x = block(x, p)
    return layers.norm_apply(params["enc_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Decoder (teacher-forced full sequence)
# ---------------------------------------------------------------------------
def decode_train(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 memory: jax.Array, *, engine=None,
                 attn_chunk: int = 2048,
                 return_hidden: bool = False) -> jax.Array:
    """tokens: (B, T) -> logits (B, T, V), attending to encoder memory.
    return_hidden skips final norm + readout (chunked-CE path)."""
    t = tokens.shape[1]
    x = layers.embed(params["embed"], tokens)
    x = x + params["dec_pos"]["table"][:t].astype(x.dtype)

    def block(x, p):
        x = ctx.constrain(x, "batch", None, None)
        h = layers.norm_apply(p["norm1"], x, cfg.norm)
        x = x + attention(p["self_attn"], cfg, h, causal=True,
                          chunk=attn_chunk, engine=engine).astype(x.dtype)
        h = layers.norm_apply(p["norm_x"], x, cfg.norm)
        x = x + attention(p["cross_attn"], cfg, h, memory=memory,
                          chunk=attn_chunk, engine=engine).astype(x.dtype)
        h = layers.norm_apply(p["norm2"], x, cfg.norm)
        x = x + layers.mlp_apply(p["ffn"], h, cfg.act, engine=engine
                                 ).astype(x.dtype)
        return x

    block = _remat(block, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, p: (block(c, p), None), x,
                            params["dec_blocks"])
    else:
        for i in range(cfg.num_layers):
            p = jax.tree_util.tree_map(lambda a: a[i], params["dec_blocks"])
            x = block(x, p)
    if return_hidden:
        return x
    x = layers.norm_apply(params["dec_norm"], x, cfg.norm)
    return layers.unembed(params["embed"], x, engine)


# ---------------------------------------------------------------------------
# Autoregressive decode
# ---------------------------------------------------------------------------
def precompute_cross_kv(params: dict, cfg: ModelConfig, memory: jax.Array, *,
                        engine=None) -> Tuple[jax.Array, jax.Array]:
    """Project each decoder layer's cross K/V once per utterance
    (the paper's ``dec.cross.kv`` kernel class). Returns (R,B,F,Hkv,hd) x2."""
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    b, f, _ = memory.shape

    def per_layer(p):
        k = layers.linear(p["cross_attn"]["k"], memory, engine, "dec.cross.k")
        v = layers.linear(p["cross_attn"]["v"], memory, engine, "dec.cross.v")
        dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
        return (k.reshape(b, f, hkv, hd).astype(dtype),
                v.reshape(b, f, hkv, hd).astype(dtype))

    return jax.vmap(per_layer)(params["dec_blocks"])


def init_whisper_decode_state(params: dict, cfg: ModelConfig, memory: jax.Array,
                              max_len: int, *, engine=None,
                              dtype=jnp.bfloat16) -> WhisperDecodeState:
    b = memory.shape[0]
    kv = KVCache.zeros(b, max_len, cfg.num_kv_heads, cfg.head_dim, dtype)
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), kv)
    return WhisperDecodeState(
        self_kv=stacked,
        cross_kv=precompute_cross_kv(params, cfg, memory, engine=engine))


def _paged_stack(params: dict, cfg: ModelConfig, x: jax.Array,
                 state: WhisperPagedDecodeState, *, engine=None
                 ) -> Tuple[jax.Array, WhisperPagedDecodeState]:
    """Shared paged decoder-block stack (DESIGN.md §15.2/§17.4) over a
    (B, W, d) embedded+positioned window: self-KV reads/writes go through
    the per-slot block table (see ``attention.PagedKVCache`` — W > 1
    scatters every window entry through its own (page, offset) pair) and
    each layer's cross-KV is gathered from its pages back into the
    contiguous (B, F, Hkv, hd) view — F is an exact multiple of the cross
    page size (pool invariant), so position t of the gathered view IS
    position t of the contiguous one and the attention math (hence every
    token) is unchanged."""
    b = x.shape[0]
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    bt, ct = state.block_table, state.cross_table

    def body(x, xs):
        p, sk, sv, length, ckp, cvp = xs
        cache = PagedKVCache(sk, sv, bt, length)
        h = layers.norm_apply(p["norm1"], x, cfg.norm)
        mixed, cache = decode_attention(p["self_attn"], cfg, h, cache,
                                        engine=engine)
        x = x + mixed.astype(x.dtype)
        ck = ckp[ct].reshape(b, -1, hkv, hd)
        cv = cvp[ct].reshape(b, -1, hkv, hd)
        h = layers.norm_apply(p["norm_x"], x, cfg.norm)
        mixed, _ = decode_attention(p["cross_attn"], cfg, h, cache,
                                    memory_kv=(ck, cv), engine=engine)
        x = x + mixed.astype(x.dtype)
        h = layers.norm_apply(p["norm2"], x, cfg.norm)
        x = x + layers.mlp_apply(p["ffn"], h, cfg.act, engine=engine
                                 ).astype(x.dtype)
        return x, (cache.k_pages, cache.v_pages, cache.length)

    xs = (params["dec_blocks"], state.self_k, state.self_v, state.length,
          state.cross_k, state.cross_v)
    if cfg.scan_layers:
        x, (nk, nv, nl) = jax.lax.scan(body, x, xs)
    else:
        outs = []
        for i in range(cfg.num_layers):
            xi = jax.tree_util.tree_map(lambda a: a[i], xs)
            x, o = body(x, xi)
            outs.append(o)
        nk, nv, nl = (jnp.stack([o[j] for o in outs]) for j in range(3))
    x = layers.norm_apply(params["dec_norm"], x, cfg.norm)
    logits = layers.unembed(params["embed"], x, engine)
    return logits, WhisperPagedDecodeState(
        self_k=nk, self_v=nv, cross_k=state.cross_k, cross_v=state.cross_v,
        block_table=bt, cross_table=ct, length=nl)


def _decode_step_paged(params: dict, cfg: ModelConfig, token: jax.Array,
                       state: WhisperPagedDecodeState, *, engine=None
                       ) -> Tuple[jax.Array, WhisperPagedDecodeState]:
    """Paged twin of ``decode_step``: embed + per-slot position, then the
    shared paged stack at W=1."""
    x = layers.embed(params["embed"], token)
    pos = state.length[0]                       # (B,) per-slot positions
    table = params["dec_pos"]["table"]
    x = x + jnp.take(table, pos, axis=0)[:, None].astype(x.dtype)
    return _paged_stack(params, cfg, x, state, engine=engine)


def _verify_step_paged(params: dict, cfg: ModelConfig, tokens: jax.Array,
                      state: WhisperPagedDecodeState, *, engine=None
                      ) -> Tuple[jax.Array, WhisperPagedDecodeState]:
    """Paged twin of ``verify_step`` (DESIGN.md §17.4): the W-token
    verify window scores in ONE forward through the shared paged stack —
    window position j reads its learned positional row at ``length[b] +
    j`` and its self-KV entry scatters through the block table, so the
    logits match the contiguous verify bit-for-bit."""
    w = tokens.shape[1]
    x = layers.embed(params["embed"], tokens)
    pos = state.length[0]                       # (B,) per-slot positions
    table = params["dec_pos"]["table"]
    posw = pos[:, None] + jnp.arange(w)[None, :]
    x = x + jnp.take(table, posw, axis=0).astype(x.dtype)
    return _paged_stack(params, cfg, x, state, engine=engine)


def _decoder_stack(params: dict, cfg: ModelConfig, x: jax.Array,
                   state: WhisperDecodeState, *, engine=None
                   ) -> Tuple[jax.Array, WhisperDecodeState]:
    """Shared decoder-block stack for the one-token step and the W-token
    verify window (DESIGN.md §17.1): x is (B, W, d) embedded+positioned
    input; ``decode_attention`` appends all W self-KV entries and masks
    window causality, so W=1 reproduces the old step bit-for-bit."""
    def body(x, xs):
        p, kv, ck, cv = xs
        h = layers.norm_apply(p["norm1"], x, cfg.norm)
        mixed, kv = decode_attention(p["self_attn"], cfg, h, kv, engine=engine)
        x = x + mixed.astype(x.dtype)
        h = layers.norm_apply(p["norm_x"], x, cfg.norm)
        mixed, _ = decode_attention(p["cross_attn"], cfg, h, kv,
                                    memory_kv=(ck, cv), engine=engine)
        x = x + mixed.astype(x.dtype)
        h = layers.norm_apply(p["norm2"], x, cfg.norm)
        x = x + layers.mlp_apply(p["ffn"], h, cfg.act, engine=engine
                                 ).astype(x.dtype)
        return x, kv

    ck, cv = state.cross_kv
    if cfg.scan_layers:
        x, new_kv = jax.lax.scan(body, x, (params["dec_blocks"],
                                           state.self_kv, ck, cv))
    else:
        caches = []
        for i in range(cfg.num_layers):
            xs = jax.tree_util.tree_map(
                lambda a: a[i], (params["dec_blocks"], state.self_kv, ck, cv))
            x, kv_i = body(x, xs)
            caches.append(kv_i)
        new_kv = jax.tree_util.tree_map(lambda *z: jnp.stack(z), *caches)
    x = layers.norm_apply(params["dec_norm"], x, cfg.norm)
    logits = layers.unembed(params["embed"], x, engine)
    return logits, WhisperDecodeState(self_kv=new_kv, cross_kv=state.cross_kv)


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                state: WhisperDecodeState, *, engine=None
                ) -> Tuple[jax.Array, WhisperDecodeState]:
    """token: (B, 1) int32 -> (logits (B, 1, V), state').

    Positions come from the layer-0 self-KV length: scalar for a lockstep
    batch, per-row ``(B,)`` in the slot-pool layout (DESIGN.md §11.1) —
    each slot then reads its own learned positional embedding row.
    ``WhisperPagedDecodeState`` dispatches to the paged twin
    (DESIGN.md §15.2)."""
    if isinstance(state, WhisperPagedDecodeState):
        return _decode_step_paged(params, cfg, token, state, engine=engine)
    x = layers.embed(params["embed"], token)
    pos = (state.self_kv.length[0] if state.self_kv.length.ndim
           else state.self_kv.length)
    table = params["dec_pos"]["table"]
    if pos.ndim:                                    # per-slot positions (B,)
        x = x + jnp.take(table, pos, axis=0)[:, None].astype(x.dtype)
    else:
        x = x + jax.lax.dynamic_slice_in_dim(table, pos, 1,
                                             axis=0).astype(x.dtype)
    return _decoder_stack(params, cfg, x, state, engine=engine)


def verify_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                state: WhisperDecodeState, *, engine=None
                ) -> Tuple[jax.Array, WhisperDecodeState]:
    """Score a W-token window in ONE forward (DESIGN.md §17.1): tokens
    (B, W) int32 -> (logits (B, W, V), state') with every layer's self-KV
    advanced by W. ``logits[:, j]`` is the next-token distribution after
    consuming ``tokens[:, :j+1]`` — exactly what ``decode_step`` would
    return fed those tokens one at a time, which is what makes
    speculative acceptance token-exact against the greedy verifier.
    Position handling mirrors ``decode_step``: the layer-0 self-KV length
    is the window base, scalar (lockstep) or per-row (slot layout)."""
    if isinstance(state, WhisperPagedDecodeState):
        return _verify_step_paged(params, cfg, tokens, state, engine=engine)
    w = tokens.shape[1]
    x = layers.embed(params["embed"], tokens)
    pos = (state.self_kv.length[0] if state.self_kv.length.ndim
           else state.self_kv.length)
    table = params["dec_pos"]["table"]
    if pos.ndim:                                    # per-slot positions (B,)
        posw = pos[:, None] + jnp.arange(w)[None, :]
        x = x + jnp.take(table, posw, axis=0).astype(x.dtype)
    else:
        x = x + jax.lax.dynamic_slice_in_dim(table, pos, w,
                                             axis=0)[None].astype(x.dtype)
    return _decoder_stack(params, cfg, x, state, engine=engine)
