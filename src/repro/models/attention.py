"""GQA attention: query-chunked (flash-style) causal attention for train and
prefill, plus single-step KV-cache decode.

The query-chunked online-softmax scan keeps the score matrix at
(B, H, chunk, S) instead of (B, H, S, S) — without it, prefill_32k would
materialize multi-GB score tensors per device. On real TPUs the same
structure is what a Pallas flash kernel pipelines through VMEM; expressing it
as a lax.scan lets XLA fuse it and keeps the dry-run honest about memory.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding import ctx

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16,
                   cross: bool = False) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "q": layers.init_linear(ks[0], d, hq * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k": layers.init_linear(ks[1], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v": layers.init_linear(ks[2], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o": layers.init_linear(ks[3], hq * hd, d, dtype=dtype),
    }


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def _repeat_kv_heads(kv: jax.Array, hq: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hq, D). With Hq constrained onto the model
    axis each device materializes only its local Hq/|model| head slice, so
    the repeat is cheap; the flat-head layout is what lets the big attention
    tensors shard 16-way on heads (Hkv=8 alone cannot)."""
    hkv = kv.shape[2]
    if hkv == hq:
        return kv
    kv = jnp.repeat(kv, hq // hkv, axis=2)
    return ctx.constrain(kv, "batch", None, "model", None)


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       causal: bool, chunk: int,
                       q_offset: int = 0) -> jax.Array:
    """Query-chunked attention, flat heads, per-chunk remat.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D). Returns (B, Sq, Hq, D).
    Each chunk body is jax.checkpoint'ed so the scan over chunks never
    stacks (chunk x Sk) f32 logits as autodiff residuals — without this the
    whisper train_4k dry-run kept 48 GiB logit buffers alive. The PV matmul
    runs in bf16 with f32 accumulation (MXU-native, flash-standard).
    """
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    scale = d ** -0.5
    chunk = min(chunk, sq)
    if sq % chunk:
        chunk = sq  # fall back to single chunk for ragged smoke shapes
    n_chunks = sq // chunk

    k = _repeat_kv_heads(k, hq)
    v = _repeat_kv_heads(v, hq)
    qc = q.reshape(b, n_chunks, chunk, hq, d).transpose(1, 0, 3, 2, 4)
    kpos = jnp.arange(sk)

    @jax.checkpoint
    def one_chunk(ci, qi):
        # qi: (B, Hq, chunk, D)
        logits = jnp.einsum("bhqd,bshd->bhqs", qi, k,
                            preferred_element_type=jnp.float32) * scale
        logits = ctx.constrain(logits, "batch", "model", None, None)
        if causal:
            qpos = q_offset + ci * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqs,bshd->bhqd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.astype(q.dtype)

    if n_chunks == 1:
        out = one_chunk(0, qc[0])[None]
    else:
        out = jax.lax.map(lambda args: one_chunk(*args),
                          (jnp.arange(n_chunks), qc))
    # (nc, B, Hq, chunk, D) -> (B, Sq, Hq, D)
    return out.transpose(1, 0, 3, 2, 4).reshape(b, sq, hq, d)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_core(q, k, v, causal, k_chunk, scale, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, k_chunk, scale, q_offset)
    return out


def _flash_fwd_impl(q, k, v, causal, k_chunk, scale, q_offset):
    """q: (B,H,Sq,D); k/v: (B,H,Sk,D). Online-softmax forward scan over
    k-blocks; returns (out, logsumexp) — the flash-2 forward."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    n_k = sk // k_chunk
    kb = k.reshape(b, h, n_k, k_chunk, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h, n_k, k_chunk, d).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(sq)

    # layout intent: heads on the model axis when they divide; otherwise
    # SEQUENCE parallelism on Sq (context parallel) — without this pin,
    # GSPMD shards the contraction dim D and all-reduces every (Sq, Ck)
    # logits tile (measured 960 GiB/step on qwen2.5's 40 heads, §Perf A3).
    mesh = ctx.current_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    heads_ok = msize > 1 and q.shape[1] % msize == 0
    h_tok = "model" if heads_ok else "model_force"
    s_tok = None
    q = ctx.constrain(q, "batch", h_tok, s_tok, None)

    def kv_block(carry, inputs):
        m, l, acc = carry
        kj, vj, kstart = inputs
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        s = ctx.constrain(s, "batch", h_tok, s_tok, None)
        if causal:
            kpos = kstart + jnp.arange(k_chunk)
            s = jnp.where((kpos[None, :] <= qpos[:, None])[None, None],
                          s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(kv_block, init,
                                  (kb, vb, jnp.arange(n_k) * k_chunk))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


def _flash_fwd(q, k, v, causal, k_chunk, scale, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, k_chunk, scale, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, k_chunk, scale, q_offset, res, dout):
    """Flash-2 backward: recompute p per k-block from (q, k, lse) — no
    stacked probs residuals (naive autodiff of the fwd scan stores a
    (n_k, B, H, Sq, k_chunk) probs stack, which measured WORSE than the
    chunked baseline; see EXPERIMENTS.md §Perf A2)."""
    q, k, v, out, lse = res
    b, h, sq, d = q.shape
    sk = k.shape[2]
    n_k = sk // k_chunk
    kb = k.reshape(b, h, n_k, k_chunk, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h, n_k, k_chunk, d).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(sq)
    dout_f = dout.astype(jnp.float32)
    # delta_i = rowsum(dout_i * out_i)  (flash-2 trick)
    delta = jnp.sum(dout_f * out.astype(jnp.float32), axis=-1)

    mesh = ctx.current_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    heads_ok = msize > 1 and q.shape[1] % msize == 0
    h_tok = "model" if heads_ok else "model_force"
    s_tok = None

    def kv_block(dq, inputs):
        kj, vj, kstart = inputs
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        s = ctx.constrain(s, "batch", h_tok, s_tok, None)
        if causal:
            kpos = kstart + jnp.arange(k_chunk)
            s = jnp.where((kpos[None, :] <= qpos[:, None])[None, None],
                          s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                       # (B,H,Sq,Ck)
        dv_j = jnp.einsum("bhqk,bhqd->bhkd", p.astype(dout.dtype), dout_f)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dout_f,
                        vj.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds,
                             kj.astype(jnp.float32))
        dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(jnp.float32))
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, h, sq, d), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(kv_block, dq0,
                                    (kb, vb, jnp.arange(n_k) * k_chunk))
    dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(b, h, sk, d)
    dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(b, h, sk, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     causal: bool, chunk: int, k_chunk: int = 1024,
                     q_offset: int = 0) -> jax.Array:
    """Online-softmax (flash-2) attention — beyond-paper optimization of
    the memory roofline term (EXPERIMENTS.md §Perf).

    The chunked baseline materializes (Sq, Sk) f32 logits and makes ~5
    probs-sized HBM round trips (mask, softmax, PV, and their backward);
    at S>=4k those dominate the train-cell memory term. Here only
    (Sq, k_chunk) tiles ever exist; the custom VJP recomputes them per
    block in the backward (true flash-2 — naive autodiff of the forward
    scan would stack per-block probs residuals and measured WORSE than
    the baseline). ``chunk`` is accepted for API parity; the q dimension
    is processed whole since tiles are already k-blocked.
    """
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    k_chunk = min(k_chunk, sk)
    if sk % k_chunk:
        k_chunk = sk
    k = _repeat_kv_heads(k, hq)
    v = _repeat_kv_heads(v, hq)
    qt = q.transpose(0, 2, 1, 3)           # (B,H,Sq,D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_core(qt, kt, vt, causal, k_chunk, d ** -0.5, q_offset)
    return out.transpose(0, 2, 1, 3)


def attention(p: dict, cfg: ModelConfig, x: jax.Array, *,
              positions: Optional[jax.Array] = None,
              memory: Optional[jax.Array] = None,
              causal: bool = True,
              chunk: int = 2048,
              engine=None) -> jax.Array:
    """Self- or cross-attention over a full sequence (train / prefill).

    memory: encoder states for cross-attention (disables causal + rope).
    """
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if memory is None else memory
    q = _split_heads(layers.linear(p["q"], x, engine, "attn.q"), hq)
    k = _split_heads(layers.linear(p["k"], src, engine, "attn.k"), hkv)
    v = _split_heads(layers.linear(p["v"], src, engine, "attn.v"), hkv)
    q = ctx.constrain(q, "batch", None, "model", None)
    k = ctx.constrain(k, "batch", None, "model", None)
    v = ctx.constrain(v, "batch", None, "model", None)
    if memory is None and cfg.pos_embedding == "rope":
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    impl = (_flash_attention if cfg.attn_impl == "flash"
            else _chunked_attention)
    out = impl(q, k, v, causal=(memory is None and causal), chunk=chunk)
    return layers.linear(p["o"], out.reshape(b, s, hq * hd), engine, "attn.o")


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    """``length`` is scalar int32 for lockstep batches (every row at the
    same position) or per-row ``(B,)`` int32 in the slot-pool layout
    (DESIGN.md §11.1), where continuous batching keeps each slot at its
    own decode position inside one fixed-shape batch."""
    k: jax.Array          # (B, S_max, Hkv, D)
    v: jax.Array          # (B, S_max, Hkv, D)
    length: jax.Array     # () or (B,) int32 — tokens currently valid

    @classmethod
    def zeros(cls, b: int, s_max: int, hkv: int, hd: int, dtype=jnp.bfloat16):
        return cls(jnp.zeros((b, s_max, hkv, hd), dtype),
                   jnp.zeros((b, s_max, hkv, hd), dtype),
                   jnp.zeros((), jnp.int32))


class PagedKVCache(NamedTuple):
    """Paged decode cache (DESIGN.md §15.2): K/V live in a fixed-shape
    page arena shared by every slot; each row reaches its pages through a
    per-slot ``block_table`` gather. Physical page 0 is the trash page —
    free slots' table rows all point at it, so the fixed-shape batch can
    keep writing garbage rows without owning memory. ``length`` is always
    per-row ``(B,)`` (the pool layout is the only consumer)."""
    k_pages: jax.Array       # (P, page, Hkv, D) physical page arena
    v_pages: jax.Array       # (P, page, Hkv, D)
    block_table: jax.Array   # (B, max_pages) int32 — logical -> physical
    length: jax.Array        # (B,) int32 — tokens currently valid


class QKVCache(NamedTuple):
    """Int8-quantized KV cache — the paper's Q8_0 block idea applied to the
    *decode-dominant* bytes (beyond-paper, EXPERIMENTS.md §Perf C). One
    scale per (position, head) over the head_dim block; K/V stream as int8
    + f32 scales (~2.06 B/elt pair -> 1.03) and dequantize inline right
    before the attention MACs, exactly like IMAX's ALU3 inline dequant."""
    k_qs: jax.Array       # int8 (B, S_max, Hkv, D)
    v_qs: jax.Array       # int8 (B, S_max, Hkv, D)
    k_scale: jax.Array    # f32  (B, S_max, Hkv)
    v_scale: jax.Array    # f32  (B, S_max, Hkv)
    length: jax.Array

    @classmethod
    def zeros(cls, b: int, s_max: int, hkv: int, hd: int, dtype=None):
        return cls(jnp.zeros((b, s_max, hkv, hd), jnp.int8),
                   jnp.zeros((b, s_max, hkv, hd), jnp.int8),
                   jnp.zeros((b, s_max, hkv), jnp.float32),
                   jnp.zeros((b, s_max, hkv), jnp.float32),
                   jnp.zeros((), jnp.int32))


def quantize_kv(x: jax.Array):
    """(B, S, H, D) -> (int8 qs, f32 scale (B,S,H)); symmetric per-head."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / 127.0
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = xf * inv[..., None]
    q = jnp.clip(jnp.round(q), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(qs: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (qs.astype(jnp.float32) * scale[..., None]).astype(dtype)


def paged_window_update(pages: jax.Array, block_table: jax.Array,
                        length: jax.Array, val: jax.Array) -> jax.Array:
    """Scatter a per-row W-token window into the page arena (DESIGN.md
    §15.2/§17.4). ``val`` is (B, W, Hkv, D); row b's window position j
    lands at logical position ``length[b] + j``, i.e. physical page
    ``block_table[b, (length[b]+j) // page]`` offset ``(length[b]+j) %
    page`` — a window may straddle a page boundary, so each window entry
    resolves its own (page, offset) pair. Active rows' pages are
    CoW-private (paging.py ensures this in the pre-round capacity pass),
    so scatter indices never collide across rows; free rows' table
    entries all point at trash page 0, whose contents are never read.
    The logical-page index clamps to the table width like the W=1 path:
    in-contract callers (``length + W <= capacity``, enforced by the
    schedulers' admission guard) never hit the clamp on an active row."""
    ps = pages.shape[1]
    n_log = block_table.shape[1]
    w = val.shape[1]
    pos = length[:, None] + jnp.arange(w)[None, :]          # (B, W)
    lp = jnp.minimum(pos // ps, n_log - 1)
    off = pos % ps
    phys = jnp.take_along_axis(block_table, lp, axis=1)     # (B, W)
    return pages.at[phys, off].set(val.astype(pages.dtype))


def paged_window_gather(pages: jax.Array,
                        block_table: jax.Array) -> jax.Array:
    """Gather each row's pages into its contiguous (n_log*page, ...)
    view — token t sits at gathered position t, so downstream validity
    masks are identical to the contiguous layout (token-exact)."""
    b, n_log = block_table.shape
    ps = pages.shape[1]
    return pages[block_table].reshape(b, n_log * ps, *pages.shape[2:])


def _cache_update(buf: jax.Array, val: jax.Array,
                  length: jax.Array) -> jax.Array:
    """Write ``val``'s entries per row starting at that row's position.
    ``length`` scalar: every row writes at the same index (lockstep
    batch). ``length`` (B,): per-row write positions — the slot-pool
    layout (DESIGN.md §11.1), vmapped so each slot advances
    independently. ``val`` may carry W > 1 new positions (the verify
    window, DESIGN.md §17.1) — dynamic_update_slice writes all W
    contiguously from the row's position."""
    val = val.astype(buf.dtype)
    if length.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, val, length, axis=1)
    return jax.vmap(
        lambda b, v, p: jax.lax.dynamic_update_slice_in_dim(b, v, p, axis=0)
    )(buf, val, length)


def decode_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                     cache: KVCache, *,
                     memory_kv: Optional[tuple] = None,
                     engine=None):
    """One decode step over a W-token window. x: (B, W, d) — W=1 is the
    plain autoregressive step; W=k+1 is the speculative verify window
    (DESIGN.md §17.1), which appends all W new KV entries contiguously
    and masks so query j sees exactly positions <= length + j (window
    causality falls out of the same validity test). Returns
    (out, new_cache) with ``length`` advanced by W.

    memory_kv: precomputed (k, v) encoder projections for cross-attention
    (whisper's dec.cross.kv — computed once per utterance, paper §3 Fig 1).

    ``cache.length`` may be scalar (lockstep batch) or per-row ``(B,)``
    (slot-pool layout, DESIGN.md §11.1); each row then reads/writes its
    own position so slots at different decode depths share one batch.
    """
    b, w = x.shape[0], x.shape[1]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(layers.linear(p["q"], x, engine, "dec.attn.q"), hq)

    if memory_kv is None:
        knew = _split_heads(layers.linear(p["k"], x, engine, "dec.attn.k"), hkv)
        vnew = _split_heads(layers.linear(p["v"], x, engine, "dec.attn.v"), hkv)
        per_row = cache.length.ndim == 1
        offs = jnp.arange(w)
        if cfg.pos_embedding == "rope":
            pos = (cache.length[:, None] + offs[None, :] if per_row
                   else (cache.length + offs)[None, :])
            q = layers.apply_rope(q, pos, cfg.rope_theta)
            knew = layers.apply_rope(knew, pos, cfg.rope_theta)
        if isinstance(cache, PagedKVCache):
            # paged write (DESIGN.md §15.2/§17.4): each row scatters its
            # W new entries through its block-table row — per-entry
            # (page, offset) resolution, so a verify window straddling a
            # page boundary lands across both pages. Free slots' table
            # rows point at trash page 0, so garbage rows never touch
            # owned memory; active rows write CoW-private pages, so
            # scatter indices never collide.
            k_pages = paged_window_update(cache.k_pages, cache.block_table,
                                          cache.length, knew)
            v_pages = paged_window_update(cache.v_pages, cache.block_table,
                                          cache.length, vnew)
            new_cache = PagedKVCache(k_pages, v_pages, cache.block_table,
                                     cache.length + w)
            # paged read: gather each row's pages into its contiguous
            # (n_log*page,) view — token t sits at gathered position t, so
            # the per-row valid mask below is identical to the contiguous
            # layout and the attention math is unchanged (token-exact).
            k = paged_window_gather(k_pages, cache.block_table)
            v = paged_window_gather(v_pages, cache.block_table)
        elif isinstance(cache, QKVCache):
            # int8 cache path: quantize the new entry, stream int8 +
            # scales, dequantize inline before the MACs (paper-style)
            kq, ks = quantize_kv(knew)
            vq, vs = quantize_kv(vnew)
            upd = lambda buf, val: _cache_update(buf, val, cache.length)
            new_cache = QKVCache(upd(cache.k_qs, kq), upd(cache.v_qs, vq),
                                 upd(cache.k_scale, ks),
                                 upd(cache.v_scale, vs), cache.length + w)
            k = dequantize_kv(new_cache.k_qs, new_cache.k_scale, x.dtype)
            v = dequantize_kv(new_cache.v_qs, new_cache.v_scale, x.dtype)
        else:
            k = _cache_update(cache.k, knew, cache.length)
            v = _cache_update(cache.v, vnew, cache.length)
            new_cache = KVCache(k, v, cache.length + w)
        # per-query validity: query j attends key position s iff
        # s <= length + j — its own new entry is visible, later window
        # entries are not (window causality, DESIGN.md §17.1)
        pos_idx = jnp.arange(k.shape[1])
        qpos = (cache.length[:, None] + offs[None, :] if per_row
                else (cache.length + offs))          # (B, W) | (W,)
        valid = (pos_idx[None, None, :] <= qpos[:, :, None] if per_row
                 else pos_idx[None, :] <= qpos[:, None])   # (B,W,S) | (W,S)
    else:
        k, v = memory_kv
        new_cache = cache
        valid = None

    # Grouped decode contraction (repeated KV never materialized — at 32k
    # cache scale a 64-head repeat would move 8x the cache bytes per step).
    # Constraint placement mirrors sharding/rules.cache_specs: the model
    # axis lands on Hkv when it divides, otherwise on S — the S case is
    # flash-decode-style sequence parallelism where each model shard
    # contracts its cache slice and GSPMD inserts the tiny softmax/out
    # all-reduces.
    mesh = ctx.current_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    kv_sharded = msize > 1 and hkv % msize == 0
    batch_ok = mesh is not None and b % ctx.batch_shard_size(mesh) == 0
    s_tok = None if kv_sharded else ("model" if batch_ok else "seq")
    g = hq // hkv
    qg = q.reshape(b, w, hkv, g, hd)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    logits = ctx.constrain(logits, "batch", "model" if kv_sharded else None,
                           None, None, s_tok)
    if valid is not None:
        # (B,W,S) per-row / (W,S) lockstep -> broadcast over (h, g)
        vmask = (valid[:, None, None, :, :] if valid.ndim == 3
                 else valid[None, None, None, :, :])
        logits = jnp.where(vmask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(b, w, hq * hd)
    return layers.linear(p["o"], out, engine, "dec.attn.o"), new_cache
