"""The backend protocol and the request object it answers (DESIGN.md §12.1).

A ``KernelRequest`` describes one *segment* of one linear invocation — the
burst-aligned main segment or the ragged residual tail of the paper's mixed
execution — in purely static terms (shapes, dtype, tile hints). A
``Backend`` looks at a request and either declines it (``supports``) or
returns a callable that runs it (``build``). Nothing else in the codebase
selects a kernel implementation; ``registry.dispatch`` is the single seam
every future target (GPU Pallas, pure-CPU CI, a real CGLA simulator) plugs
into.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

#: kernels the execution layer knows how to name (tuning keys use the same
#: identifiers — ``tuning.kernel_for`` is the canonical mapper).
KERNELS = ("q8_matmul", "q8_matvec", "bf16_matmul")

MAIN = "main"
RESIDUAL = "residual"


@dataclass(frozen=True)
class KernelRequest:
    """One segment of one linear call, described statically.

    ``m`` is the logical row count of the flattened activation (pre
    sublane padding); ``k`` is the contraction length *this segment* sees
    (k_main for the aligned segment, k_res for the tail) — backends never
    learn about the split, they just run their slice.
    """
    kernel: str                               # one of KERNELS
    m: int
    n: int
    k: int
    dtype: str                                # "q8_0" | "bf16"
    segment: str = MAIN                       # MAIN | RESIDUAL
    tiling: Optional[Tuple[int, int, int]] = None   # pinned (bm, bn, bk)
    block_k: int = 256                        # untuned fallback K tile
    interpret: Optional[bool] = None          # None -> platform default
    # False marks a *structural* routing decision (a capacity-based
    # offload=False fallback, like the residual arm) that REPRO_BACKEND
    # forcing must not override (DESIGN.md §12.2)
    forceable: bool = True
    # dispatch-time collaborators; excluded from equality so requests stay
    # comparable/hashable on their static identity
    tuner: Any = field(default=None, compare=False, repr=False)

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k


@runtime_checkable
class Backend(Protocol):
    """What the registry requires of an execution backend."""

    name: str

    def supports(self, req: KernelRequest) -> bool:
        """Capability: can this backend run ``req`` correctly at all?
        (Used when a plan or ``REPRO_BACKEND`` pins this backend.)"""
        ...

    def auto(self, req: KernelRequest) -> bool:
        """Would this backend volunteer for ``req`` under automatic
        capability resolution? Stricter than ``supports`` — e.g. the
        Pallas backend supports interpret-mode execution anywhere but only
        volunteers on a real TPU (DESIGN.md §6.3)."""
        ...

    def build(self, req: KernelRequest) -> Callable:
        """A callable ``(x_segment, w_segment) -> f32 output`` for this
        request. ``w_segment`` is a ``jax.Array`` or a ``QTensor`` already
        sliced to the segment's K range."""
        ...

    def cost_hints(self, req: KernelRequest) -> Dict[str, Any]:
        """Rough dispatch-relevant facts (flops, native-vs-emulated, unit)
        for benchmarks and resolution diagnostics."""
        ...
