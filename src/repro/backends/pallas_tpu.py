"""The Pallas TPU backend: the paper's accelerator path (DESIGN.md §12).

Owns everything that used to live inline in ``kernels/ops.py``: sublane
padding, matvec-vs-matmul selection for skinny decode batches, and tile
resolution (explicit plan tiling > tuner cache > module defaults,
DESIGN.md §10.1 / §9.4). Off-TPU the same kernels run ``interpret=True``
for correctness tests; the backend only *volunteers* (``auto``) on a real
TPU — elsewhere it must be pinned explicitly.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.backends import platform
from repro.backends.base import KERNELS, MAIN, KernelRequest
from repro.core.qformats import QBLOCK, QTensor
from repro.kernels.bf16_matmul import bf16_matmul
from repro.kernels.q8_matmul import q8_matmul
from repro.kernels.q8_matvec import q8_matvec

_SUBLANE = 8  # f32 min sublane tile on TPU


def _pad_m(x: jax.Array, mult: int = _SUBLANE):
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def _tuned(tuner, kernel: str, m: int, n: int, k: int, dtype: str):
    """Winning tiling for the *main-segment* shape, or None (tuner absent or
    nothing admissible under its VMEM budget)."""
    if tuner is None:
        return None
    return tuner.best_tiling(kernel, m, n, k, dtype)


def _block_shape(rec) -> Tuple[int, int, int]:
    """Normalize a tiling source — TuningRecord or plan-entry tuple."""
    if isinstance(rec, tuple):
        return rec
    return rec.block_m, rec.block_n, rec.block_k


def _largest_tile(dim: int, cap: int, mult: int = 1) -> int:
    """Largest t <= cap with t % mult == 0 and dim % t == 0."""
    t = min(cap, dim)
    while t > 1 and (dim % t or (mult > 1 and t % mult)):
        t -= mult if mult > 1 and t % mult == 0 else 1
    return max(t, 1)


def q8_main(x2d: jax.Array, wq: QTensor, *, interpret: bool,
            block_k: int, tuner=None, tiling=None) -> jax.Array:
    """Aligned-segment Q8_0 path: matvec variant for skinny M, tiled matmul
    otherwise. Handles M/N padding so the kernel only sees full tiles.
    Tile shapes come (in precedence order) from an explicit ``tiling`` — a
    trace-time plan entry's resolved ``(block_m, block_n, block_k)``
    (DESIGN.md §10.1) — else a tuner-cache lookup (DESIGN.md §9.4), else
    the module-level defaults."""
    qs2d = wq.flat_qs()
    n, k = qs2d.shape
    xp, m = _pad_m(x2d)
    mp = xp.shape[0]
    if mp <= 2 * _SUBLANE:
        rec = tiling or _tuned(tuner, "q8_matvec", mp, n, k, "q8_0")
        # decode: N tiled at 512 when divisible, else largest divisor tile
        bn = _block_shape(rec)[1] if rec else _largest_tile(n, 512)
        out = q8_matvec(xp, qs2d, wq.scales, block_n=bn, interpret=interpret)
    else:
        rec = tiling or _tuned(tuner, "q8_matmul", mp, n, k, "q8_0")
        if rec:
            bm, bn, bk = _block_shape(rec)
        else:
            bm = _largest_tile(mp, 128)
            bn = _largest_tile(n, 256)
            bk = _largest_tile(k, block_k, mult=QBLOCK)
        out = q8_matmul(xp, qs2d, wq.scales, block_m=bm, block_n=bn,
                        block_k=bk, interpret=interpret)
    return out[:m]


def bf16_main(x2d: jax.Array, w: jax.Array, *, interpret: bool,
              block_k: int, tuner=None, tiling=None) -> jax.Array:
    xp, m = _pad_m(x2d)
    mp = xp.shape[0]
    n, k = w.shape
    rec = tiling or _tuned(tuner, "bf16_matmul", mp, n, k, "bf16")
    if rec:
        bm, bn, bk = _block_shape(rec)
    else:
        bm = _largest_tile(mp, 128)
        bn = _largest_tile(n, 256)
        bk = _largest_tile(k, block_k)
    return bf16_matmul(xp, w, block_m=bm, block_n=bn, block_k=bk,
                       interpret=interpret)[:m]


class PallasTPUBackend:
    """Accelerator kernels — native on TPU, ``interpret=True`` elsewhere."""

    name = "pallas_tpu"

    def supports(self, req: KernelRequest) -> bool:
        # main segments only: the residual tail is by construction ragged
        # (its whole reason to exist is that it doesn't tile) and belongs
        # to the host path
        if req.segment != MAIN or req.kernel not in KERNELS:
            return False
        if req.dtype == "q8_0" and req.k % QBLOCK != 0:
            return False
        return True

    def auto(self, req: KernelRequest) -> bool:
        return self.supports(req) and platform.on_tpu()

    def _interpret(self, req: KernelRequest) -> bool:
        return (req.interpret if req.interpret is not None
                else platform.default_interpret())

    def build(self, req: KernelRequest):
        kw = dict(interpret=self._interpret(req), block_k=req.block_k,
                  tuner=req.tuner, tiling=req.tiling)
        if req.dtype == "q8_0":
            return functools.partial(q8_main, **kw)
        return functools.partial(bf16_main, **kw)

    def cost_hints(self, req: KernelRequest):
        return {"flops": req.flops, "unit": "MXU",
                "native": platform.on_tpu(),
                "interpret": self._interpret(req)}
