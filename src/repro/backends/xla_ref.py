"""The XLA reference backend: ``lax.dot_general`` via the ``ref.py``
oracles — always available, semantics-defining (DESIGN.md §12).

This is the path the model/dry-run flow always took off-TPU: the *same*
dequant math as the Pallas kernels, lowered by XLA. It volunteers for any
main segment (the terminal default of capability resolution) and is the
backend ``REPRO_BACKEND=xla_ref`` forces for no-Pallas CI runs.
"""
from __future__ import annotations

from repro.backends.base import MAIN, KernelRequest
from repro.core.qformats import QBLOCK
from repro.kernels import ref


class XLARefBackend:
    """Reference semantics on whatever XLA targets — the always-green path."""

    name = "xla_ref"

    def supports(self, req: KernelRequest) -> bool:
        # the ref dequant reshapes whole Q8_0 blocks; dense runs anywhere
        return req.dtype != "q8_0" or req.k % QBLOCK == 0

    def auto(self, req: KernelRequest) -> bool:
        # terminal default for main segments; residuals prefer the host
        # path (registered ahead of this backend) to keep f32 semantics
        return self.supports(req)

    def build(self, req: KernelRequest):
        if req.dtype == "q8_0":
            return ref.q8_matmul_ref
        return ref.matmul_bf16_ref

    def cost_hints(self, req: KernelRequest):
        return {"flops": req.flops, "unit": "XLA", "native": True,
                "interpret": False}
