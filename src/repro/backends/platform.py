"""Single cached platform probe (DESIGN.md §12.2).

Every "am I on a TPU?" question in the execution layer routes through this
module — kernels/ops.py, tuning/tuner.py and tuning/cost.py previously each
probed ``jax.default_backend()`` themselves. One probe means one consistent
answer per process (JAX's backend choice is fixed once initialized anyway)
and one place for tests to reset when they spoof a platform.
"""
from __future__ import annotations

_PROBE: dict = {}


def backend_platform() -> str:
    """The JAX platform name ("tpu" | "cpu" | "gpu"), probed once per
    process. jax is imported lazily so import-light callers (the analytic
    tuning path) stay import-light."""
    if "platform" not in _PROBE:
        import jax
        _PROBE["platform"] = jax.default_backend()
    return _PROBE["platform"]


def on_tpu() -> bool:
    return backend_platform() == "tpu"


def default_interpret() -> bool:
    """Pallas kernels run ``interpret=True`` off-TPU (DESIGN.md §6.3)."""
    return not on_tpu()


def reset_probe_cache() -> None:
    """Drop the cached probe (tests that monkeypatch the platform)."""
    _PROBE.clear()
