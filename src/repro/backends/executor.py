"""Mixed-execution executor: one entry point for every linear
(DESIGN.md §12.3).

``matmul`` is what the legacy surfaces (``kernels.ops.matmul``,
``core.mixed_exec.mixed_matmul{,_q8}``, ``OffloadEngine.execute``) are now
thin shims over: flatten leading batch dims, split the K contraction at
the burst boundary (paper §3.2 — the accelerator never sees a partial
burst), dispatch *each segment* through the backend registry, and add the
partial sums — bit-compatible with the monolithic oracle in f32.

The split mechanics live here, the kernel choice does not: every segment
becomes a ``KernelRequest`` and ``registry.dispatch`` picks who runs it.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends.base import MAIN, RESIDUAL, KernelRequest
from repro.backends.registry import REGISTRY
from repro.core.mixed_exec import split_aligned
from repro.core.qformats import QBLOCK, QTensor
from repro.sharding import ctx
from repro.tuning import kernel_for


def _note_dispatch(segment: str, backend_name: str, kernel: str) -> None:
    """Count one registry dispatch on the active telemetry (DESIGN.md
    §16.3). Dispatch resolution happens at jax *trace* time — host code
    with no handle to thread through — so the process-global active
    handle is the honest scope; a no-op when telemetry is off."""
    from repro import obs                  # lazy: avoid import cycles
    tele = obs.active()
    if tele is not None:
        tele.inc("repro_dispatch_total", segment=segment,
                 backend=backend_name, kernel=kernel)


def _flatten_leading(x: jax.Array):
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    return x.reshape(m, x.shape[-1]), lead


def _slice_k(w, start: int, stop: int):
    """Slice a weight to a K range. QTensor slicing moves whole Q8_0
    blocks — callers guarantee block-aligned boundaries (the burst is a
    QBLOCK multiple)."""
    if isinstance(w, QTensor):
        b0, b1 = start // QBLOCK, stop // QBLOCK
        return QTensor(qs=w.qs[..., b0:b1, :], scales=w.scales[..., b0:b1])
    return w[:, start:stop]


def split_matmul(x: jax.Array, w, burst: int, *,
                 main_fn: Optional[Callable] = None,
                 backend: Optional[str] = None,
                 tiling: Optional[Tuple[int, int, int]] = None,
                 tuner=None,
                 interpret: Optional[bool] = None,
                 block_k: int = 256,
                 forceable: bool = True) -> jax.Array:
    """y = x @ W^T with the K-contraction split at the burst boundary.

    x: (..., K); w: (N, K) array or QTensor over W[N, K]. The aligned main
    segment dispatches through the registry (optionally pinned to
    ``backend``) unless ``main_fn`` overrides it (the legacy
    ``mixed_matmul`` contract); the residual always resolves by capability
    — the host path, keeping the paper's concurrent-ARM-arm semantics.
    Returns f32.
    """
    quant = isinstance(w, QTensor)
    if quant and burst % QBLOCK != 0:
        raise ValueError(f"burst {burst} must be a multiple of QBLOCK={QBLOCK}")
    k = x.shape[-1]
    n = w.shape[0]
    m = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
    dtype = "q8_0" if quant else "bf16"
    kern = kernel_for(m, quant)
    k_main, k_res = split_aligned(k, burst)
    parts = []
    if k_main:
        fn = main_fn
        if fn is None:
            req = KernelRequest(kernel=kern, m=m, n=n, k=k_main, dtype=dtype,
                                segment=MAIN, tiling=tiling, block_k=block_k,
                                interpret=interpret, forceable=forceable,
                                tuner=tuner)
            b = REGISTRY.resolve(req, pin=backend)
            _note_dispatch("main", b.name, kern)
            fn = b.build(req)
        parts.append(fn(x[..., :k_main], _slice_k(w, 0, k_main)))
    if k_res:
        req = KernelRequest(kernel=kern, m=m, n=n, k=k_res, dtype=dtype,
                            segment=RESIDUAL, interpret=interpret)
        b = REGISTRY.resolve(req)
        _note_dispatch("residual", b.name, kern)
        fn = b.build(req)
        parts.append(fn(x[..., k_main:], _slice_k(w, k_main, k)))
    if not parts:
        return jnp.zeros((*x.shape[:-1], n), jnp.float32)
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


def matmul(x: jax.Array, w, *,
           burst: int = 256,
           backend: Optional[str] = None,
           tiling: Optional[Tuple[int, int, int]] = None,
           tuner=None,
           interpret: Optional[bool] = None,
           block_k: int = 256,
           forceable: bool = True) -> jax.Array:
    """The registry-era public matmul: handles leading batch dims, then
    ``split_matmul``. x: (..., K); returns (..., N) f32. ``backend`` pins
    the main segment (a recorded ``PlanEntry.backend``, DESIGN.md §12.3);
    ``tiling`` pins the main-segment tiles to a plan entry's resolution —
    with both set this is a pure function of its arguments, no cache
    lookups at execution (DESIGN.md §10.1). ``forceable=False`` marks the
    pin structural — exempt from ``REPRO_BACKEND`` (a capacity fallback
    must keep its reference path, DESIGN.md §12.2)."""
    x2d, lead = _flatten_leading(x)
    out = split_matmul(x2d, w, burst, backend=backend, tiling=tiling,
                       tuner=tuner, interpret=interpret, block_k=block_k,
                       forceable=forceable)
    out = out.reshape(*lead, out.shape[-1])
    if lead:
        # re-anchor the batch dim under sharded serving (DESIGN.md §13):
        # GSPMD propagation can lose the slot-DP sharding across the
        # split/add composition, and every linear flows through here, so
        # this one constraint keeps the whole decode step slot-sharded.
        # No-op without an active mesh (ctx), and the divisibility
        # fallback leaves batch-1 prefill activations unconstrained.
        out = ctx.constrain(out, "batch", *([None] * (out.ndim - 1)))
    return out
