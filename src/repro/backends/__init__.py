"""Pluggable execution backends (DESIGN.md §12).

One dispatch API for the paper's mixed execution: a ``KernelRequest``
describes one segment of one linear statically, a ``Backend`` answers it,
and ``REGISTRY.dispatch(request)`` is the single call site that selects a
kernel implementation. Built-ins, in capability-resolution order:

  pallas_tpu     the Pallas accelerator kernels (native on TPU,
                 interpret-mode elsewhere) — the IMAX analog
  host_residual  the f32 host/VPU einsum arm for unaligned tails —
                 the concurrent-ARM-host analog
  xla_ref        ``lax.dot_general`` reference semantics, always
                 available — the terminal default and the
                 ``REPRO_BACKEND=xla_ref`` no-Pallas CI path

``kernels.ops.matmul``, ``core.mixed_exec.mixed_matmul{,_q8}`` and
``core.offload.OffloadEngine.execute`` are thin shims over
``backends.executor``; new targets (GPU Pallas, a real CGLA simulator)
plug in via ``REGISTRY.register``.
"""
from repro.backends.base import (  # noqa: F401
    KERNELS, MAIN, RESIDUAL, Backend, KernelRequest)
from repro.backends.host_residual import HostResidualBackend  # noqa: F401
from repro.backends.pallas_tpu import PallasTPUBackend  # noqa: F401
from repro.backends.platform import (  # noqa: F401
    backend_platform, default_interpret, on_tpu, reset_probe_cache)
from repro.backends.registry import (  # noqa: F401
    FORCE_ENV, REGISTRY, BackendRegistry, pin_for_prefer)
from repro.backends.xla_ref import XLARefBackend  # noqa: F401

# registration order IS capability-resolution priority (DESIGN.md §12.2)
REGISTRY.register(PallasTPUBackend())
REGISTRY.register(HostResidualBackend())
REGISTRY.register(XLARefBackend())

from repro.backends import executor  # noqa: E402,F401  (needs REGISTRY)
