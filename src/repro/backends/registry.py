"""The backend registry and its resolver (DESIGN.md §12.2).

``dispatch(request)`` is the ONE call site in the codebase that selects a
kernel implementation. Resolution precedence for a *main* segment:

  1. a forced backend — ``force("name")`` context or the ``REPRO_BACKEND``
     env var (how CI runs the whole tier-1 suite on the no-Pallas path);
  2. a pinned backend — ``PlanEntry.backend`` or an explicit
     ``prefer_pallas`` translation from the legacy shims;
  3. capability order: the first registered backend whose ``auto(request)``
     volunteers (pallas_tpu on TPU, then host_residual for residual
     segments, with xla_ref the always-available terminal default).

Residual segments skip 1–2: the host residual arm is *structural* — part
of the paper's mixed-execution semantics (f32 on the host), not a choice a
user should redirect — so forcing ``xla_ref`` never silently changes
residual numerics. A forced or pinned backend that cannot support the
request falls through to capability order rather than erroring, so e.g.
``REPRO_BACKEND=pallas_tpu`` still routes ragged tails to the host path.

Forcing beats a plan pin *by design* — it is how one env var retargets a
whole suite whose plans pin pallas — which cuts both ways: set
``REPRO_BACKEND`` for the whole process (before plans are recorded), not
mid-flight, or ledger ``by_backend`` attribution for already-recorded
plans will name the planned backend while the forced one actually runs.
Scoped experiments should use the ``force()`` context around both
planning and execution (``benchmarks/backend_matrix.py`` does this).
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Tuple

from repro.backends.base import MAIN, Backend, KernelRequest

#: env var forcing a main-segment backend process-wide (read live, so test
#: monkeypatching works without re-imports); empty value means unset.
FORCE_ENV = "REPRO_BACKEND"


class BackendRegistry:
    """Ordered backend collection + the capability resolver."""

    def __init__(self) -> None:
        self._backends: Dict[str, Backend] = {}
        self._order: List[str] = []
        self._forced: Optional[str] = None

    # -- membership ------------------------------------------------------
    def register(self, backend: Backend) -> Backend:
        """Add a backend; registration order IS capability-resolution
        priority. Re-registering a name replaces it in place (keeps its
        priority slot) so tests can swap doubles in."""
        if backend.name not in self._backends:
            self._order.append(backend.name)
        self._backends[backend.name] = backend
        return backend

    def get(self, name: str) -> Backend:
        try:
            return self._backends[name]
        except KeyError:
            raise KeyError(
                f"unknown backend {name!r}; registered: {self.names()}"
            ) from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._order)

    # -- forcing ---------------------------------------------------------
    def forced(self) -> Optional[str]:
        """The forced backend name, if any: an active ``force()`` context
        wins over the ``REPRO_BACKEND`` env var."""
        return self._forced or os.environ.get(FORCE_ENV) or None

    @contextmanager
    def force(self, name: str):
        """Force main-segment resolution to ``name`` while active."""
        self.get(name)                       # fail fast on typos
        prev, self._forced = self._forced, name
        try:
            yield self
        finally:
            self._forced = prev

    # -- resolution ------------------------------------------------------
    def resolve(self, req: KernelRequest,
                pin: Optional[str] = None) -> Backend:
        """The backend that will run ``req`` (see module docstring for the
        precedence rules)."""
        if req.segment == MAIN:
            # forcing skips structural decisions (forceable=False: a
            # capacity-based fallback must keep its reference path, the
            # same exemption residual segments get); the pin still applies
            names = (self.forced(), pin) if req.forceable else (pin,)
            for name in names:
                if name:
                    b = self.get(name)
                    if b.supports(req):
                        return b
        for name in self._order:
            b = self._backends[name]
            if b.auto(req):
                return b
        raise LookupError(f"no registered backend volunteers for {req}")

    def dispatch(self, req: KernelRequest,
                 pin: Optional[str] = None) -> Callable:
        """Resolve and build: the callable that runs this segment."""
        return self.resolve(req, pin).build(req)


#: the process-wide registry every dispatch goes through; populated with
#: the three built-in backends by ``repro.backends.__init__``.
REGISTRY = BackendRegistry()


def pin_for_prefer(prefer_pallas: Optional[bool]) -> Optional[str]:
    """Translate the legacy ``prefer_pallas`` tri-state of
    ``kernels.ops.matmul`` / ``OffloadEngine`` into a registry pin:
    True -> pallas_tpu, False -> xla_ref, None -> capability resolution
    (which reproduces the old pallas-on-TPU/XLA-elsewhere rule)."""
    if prefer_pallas is None:
        return None
    return "pallas_tpu" if prefer_pallas else "xla_ref"
