"""The host-residual backend: the paper's ARM-host path (DESIGN.md §12).

In the paper each vector's unaligned tail (L mod b elements) runs
concurrently on the ARM host while IMAX consumes the aligned bursts; here
that tail is a skinny f32 ``jnp.einsum`` contraction on the VPU — exactly
the residual arm that used to live inline in ``core/mixed_exec.py``.
Residual weights are dequantized on this path (whole Q8_0 blocks: the
burst is a QBLOCK multiple, so the tail starts block-aligned).

Capability-wise it can run *any* segment — it is plain jnp — which is what
lets ``benchmarks/backend_matrix.py`` pin it as a whole-problem host
baseline (the paper's CPU-only comparison row). Under automatic resolution
it only volunteers for residual segments.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.backends.base import RESIDUAL, KernelRequest
from repro.core.qformats import QBLOCK, QTensor


def _dense_host(x, w):
    return jnp.einsum("...k,nk->...n", x.astype(jnp.float32),
                      w.astype(jnp.float32))


def _q8_host(x, wq: QTensor):
    # residual weights dequantized on the host path
    w = wq.qs.astype(jnp.float32) * wq.scales[..., None]
    w = w.reshape(*w.shape[:-2], -1)
    return jnp.einsum("...k,nk->...n", x.astype(jnp.float32), w)


class HostResidualBackend:
    """f32 einsum on the host/VPU — the mixed-execution residual arm."""

    name = "host_residual"

    def supports(self, req: KernelRequest) -> bool:
        return req.dtype != "q8_0" or req.k % QBLOCK == 0

    def auto(self, req: KernelRequest) -> bool:
        return req.segment == RESIDUAL and self.supports(req)

    def build(self, req: KernelRequest):
        if req.dtype == "q8_0":
            return _q8_host
        return _dense_host

    def cost_hints(self, req: KernelRequest):
        return {"flops": req.flops, "unit": "VPU/host", "native": True,
                "interpret": False}
