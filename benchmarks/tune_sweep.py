"""Paper §4.4/§5.4 (Fig 7 + Fig 10 jointly): the LMM-size x burst-length
co-design sweep as a TPU (vmem_budget x block_k) autotuning grid, plus a
tuned-vs-default comparison for the Whisper-tiny GEMM shapes (d=384,
d_ff=1536).

For every (VMEM budget, block_k) cell the autotuner's candidate space is
searched for the cheapest admissible (block_m, block_n) completion; the
cell reports cost plus PDP/EDP proxies where the power term scales with the
budget (the Fig 7 local-memory power trend, DESIGN.md §9.5). Cells where no
tiling fits the budget print "-" — Table 6's coverage cliff.

Usage:
  PYTHONPATH=src python -m benchmarks.tune_sweep [--measure] [--iters N]
      [--save-cache PATH]

Flags:
  --measure          wall-clock the winning candidates through the real
                     kernels (interpret mode off-TPU; slow) instead of the
                     deterministic analytic roofline model.
  --iters N          timing iterations per measured cell (default 3).
  --save-cache PATH  persist the tuned winners as a JSON tuning cache
                     consumable by core.offload.OffloadEngine.
"""
from __future__ import annotations

import argparse

from benchmarks.common import fmt_table, save
from repro.core import energy
from repro.backends.pallas_tpu import _largest_tile
from repro.tuning import (
    VMEM_FULL_BYTES, Autotuner, analytic_cost, budget_grid, measured_cost,
    padded_m, sweep_grid)
from repro.tuning.space import BLOCK_K_CANDIDATES, TileCandidate

# Whisper-tiny's dominant GEMM classes (paper Table 1: d=384, d_ff=1536;
# 1500 encoder frames pad to 1504, decode batch pads to 8).
TINY_SHAPES = [
    # (name, kernel, M, N, K)
    ("enc.attn.qkv", "q8_matmul", padded_m(1500), 1152, 384),
    ("enc.ffn.up", "q8_matmul", padded_m(1500), 1536, 384),
    ("enc.ffn.down", "q8_matmul", padded_m(1500), 384, 1536),
    ("dec.ffn.up", "q8_matvec", 8, 1536, 384),
    ("dec.ffn.down", "q8_matvec", 8, 384, 1536),
    ("enc.ffn.up.bf16", "bf16_matmul", padded_m(1500), 1536, 384),
]

# Budget axis: 16 KB (the paper's smallest LMM point) -> full per-core
# VMEM. agg_units=1: one TPU core's VMEM, no PE aggregation (DESIGN.md §6.1).
BUDGETS = budget_grid(min_kb=16, agg_units=1)
assert BUDGETS[-1] == VMEM_FULL_BYTES


def _vmem_power_w(budget_bytes: int) -> float:
    """Fig 7 analog: the chip-power share attributed to the claimed local
    memory grows mildly with the budget (16->256 KB costs IMAX ~60%/lane;
    we apply a gentler 20% swing across the whole VMEM range)."""
    return energy.TPU_V5E_W * (0.8 + 0.2 * budget_bytes / VMEM_FULL_BYTES)


def _default_candidate(kernel: str, m: int, n: int, k: int) -> TileCandidate:
    """The hard-coded tiling ops.py would pick with no tuner attached."""
    from repro.kernels.bf16_matmul import vmem_claim_bytes as bf16_claim
    from repro.kernels.q8_matmul import vmem_claim_bytes as q8mm_claim
    from repro.kernels.q8_matvec import vmem_claim_bytes as q8mv_claim
    if kernel == "q8_matvec":
        bn = _largest_tile(n, 512)
        return TileCandidate(kernel, m, bn, k,
                             q8mv_claim(b=m, k=k, block_n=bn))
    bm = _largest_tile(m, 128)
    bn = _largest_tile(n, 256)
    bk = _largest_tile(k, 256, mult=32 if kernel.startswith("q8") else 1)
    claim = q8mm_claim if kernel == "q8_matmul" else bf16_claim
    return TileCandidate(kernel, bm, bn, bk,
                         claim(block_m=bm, block_n=bn, block_k=bk))


def _cost(cand, m, n, k, measure: bool, iters: int):
    if measure:
        return measured_cost(cand, m, n, k, iters=iters)
    return analytic_cost(cand, m, n, k)


def run(measure: bool = False, iters: int = 3,
        save_cache: str | None = None) -> dict:
    mode = "measured" if measure else "analytic"
    name, kernel, m, n, k = ("enc.ffn.down", "q8_matmul",
                             padded_m(1500), 384, 1536)
    block_ks = [b for b in BLOCK_K_CANDIDATES if k % b == 0]

    # --- the (vmem_budget x block_k) grid for the headline shape ---------
    cost_fn = ((lambda c, cm, cn, ck: measured_cost(c, cm, cn, ck,
                                                    iters=iters))
               if measure else analytic_cost)
    cells = sweep_grid(kernel, m, n, k, budgets=BUDGETS,
                       block_ks=block_ks, cost_fn=cost_fn)
    by_cell = {(b, r.cand.block_k): r for b, r in cells}
    grid_rows, grid_cells = [], []
    for budget in BUDGETS:
        row = [f"{budget//1024}KB" if budget < 2**20
               else f"{budget/2**20:.0f}MB"]
        for bk in block_ks:
            best = by_cell.get((budget, bk))
            if best is None:
                row.append("-")
                continue
            p = _vmem_power_w(budget)
            grid_cells.append({
                "budget_bytes": budget, "block_k": bk,
                "cost_s": best.cost_s, "pdp_j": best.pdp_j(p),
                "edp_js": best.edp_js(p), "source": best.source,
                "tiling": best.cand.as_kwargs()})
            row.append(f"{best.pdp_j(p)*1e6:.2f}")
        grid_rows.append(row)
    print(f"(vmem_budget x block_k) PDP grid [uJ, {mode}] — "
          f"{name} (M={m}, N={n}, K={k})")
    print(fmt_table(grid_rows, ["budget", *(f"bk={b}" for b in block_ks)]))
    best_cell = min(grid_cells, key=lambda c: c["pdp_j"])
    print(f"PDP-optimal cell: budget="
          f"{best_cell['budget_bytes']//1024}KB block_k="
          f"{best_cell['block_k']} (paper: 32KB LMM, burst 16)")

    # --- tuned vs hard-coded defaults over the tiny shape set ------------
    tuner = Autotuner(vmem_budget_bytes=VMEM_FULL_BYTES // 2,
                      mode=mode, cache_path=save_cache)
    cmp_rows, comparisons = [], []
    for sname, skern, sm, sn, sk in TINY_SHAPES:
        dtype = "q8_0" if skern.startswith("q8") else "bf16"
        rec = tuner.best_tiling(skern, sm, sn, sk, dtype)
        dflt = _default_candidate(skern, sm, sn, sk)
        dcost = _cost(dflt, sm, sn, sk, measure, iters).cost_s
        tcost = rec.cost_s if rec else dcost
        tiling = (f"({rec.block_m},{rec.block_n},{rec.block_k})"
                  if rec else "default")
        cmp_rows.append([sname, skern, f"{sm}x{sn}x{sk}", tiling,
                         f"{tcost*1e6:.2f}", f"{dcost*1e6:.2f}",
                         f"{dcost/tcost:.2f}x" if tcost else "-"])
        comparisons.append({"name": sname, "kernel": skern,
                            "shape": [sm, sn, sk],
                            "tuned_cost_s": tcost, "default_cost_s": dcost,
                            "tuned": rec.tiling() if rec else None})
    print(f"\ntuned vs hard-coded defaults [{mode} cost, us] — "
          "whisper-tiny shapes")
    print(fmt_table(cmp_rows, ["class", "kernel", "MxNxK", "tuned tiling",
                               "tuned", "default", "speedup"]))
    regressions = [c for c in comparisons
                   if c["tuned_cost_s"] > c["default_cost_s"] * 1.001]
    print(f"tuned beats-or-matches default on "
          f"{len(comparisons)-len(regressions)}/{len(comparisons)} shapes")

    if save_cache:
        print(f"tuning cache saved to {tuner.save()} "
              f"({len(tuner.cache)} entries)")
    out = {"mode": mode, "grid_shape": {"name": name, "m": m, "n": n, "k": k},
           "grid": grid_cells, "pdp_optimal": best_cell,
           "comparisons": comparisons,
           "tuned_never_worse": not regressions}
    save("tune_sweep", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--measure", action="store_true",
                    help="wall-clock the kernels instead of analytic cost")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--save-cache", default=None,
                    help="path to persist the JSON tuning cache")
    args = ap.parse_args(argv)
    run(measure=args.measure, iters=args.iters, save_cache=args.save_cache)


if __name__ == "__main__":
    main()
