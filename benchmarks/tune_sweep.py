"""Paper §4.4/§5.4 (Fig 7 + Fig 10 jointly): the LMM-size x burst-length
co-design sweep as a TPU (vmem_budget x block_k) autotuning grid, plus a
tuned-vs-default comparison for the Whisper-tiny GEMM shapes (d=384,
d_ff=1536).

For every (VMEM budget, block_k) cell the autotuner's candidate space is
searched for the cheapest admissible (block_m, block_n) completion; the
cell reports cost plus PDP/EDP proxies where the power term scales with the
budget (the Fig 7 local-memory power trend, DESIGN.md §9.5). Cells where no
tiling fits the budget print "-" — Table 6's coverage cliff.

Column provenance (DESIGN.md §14): every cost/PDP/speedup column is
labeled with its source.  ``analytic`` columns are roofline *projections*
priced with datasheet constants — not wall-clock measurements, and the
output says so explicitly.  When a replay calibration exists
(``benchmarks/calibration_error.py`` writes one; ``--calibration PATH``
points at another), the same columns are priced with fitted per-backend
constants and labeled ``calibrated``.  ``--measured`` adds true wall-clock
replay columns next to either; ``--measure`` switches the *ranking* cost
model itself to wall-clock (slow, only meaningful on real backends).

Usage:
  PYTHONPATH=src python -m benchmarks.tune_sweep [--measure] [--measured]
      [--iters N] [--save-cache PATH] [--calibration PATH]

Flags:
  --measure          wall-clock the winning candidates through the real
                     kernels (interpret mode off-TPU; slow) instead of the
                     deterministic analytic roofline model.
  --measured         add measured wall-clock replay columns (and a
                     measured speedup) to the tuned-vs-default table.
  --iters N          timing iterations per measured cell (default 3).
  --save-cache PATH  persist the tuned winners as a JSON tuning cache
                     consumable by core.offload.OffloadEngine.
  --calibration PATH calibrated-coefficients JSON to price costs with
                     (default: auto-detect the file
                     benchmarks/calibration_error.py last wrote).
"""
from __future__ import annotations

import argparse
import os

from benchmarks.common import fmt_table, save
from repro.core import energy
from repro.tuning import (
    VMEM_FULL_BYTES, Autotuner, CalibratedCoefficients, TileCandidate,
    budget_grid, default_candidate, measured_cost, padded_m, preferred_cost,
    replay_candidate, sweep_grid)
from repro.tuning.space import BLOCK_K_CANDIDATES

#: where calibration_error.py persists fitted coefficients
DEFAULT_CALIBRATION = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "bench", "calibration_coeffs.json")

# Whisper-tiny's dominant GEMM classes (paper Table 1: d=384, d_ff=1536;
# 1500 encoder frames pad to 1504, decode batch pads to 8).
TINY_SHAPES = [
    # (name, kernel, M, N, K)
    ("enc.attn.qkv", "q8_matmul", padded_m(1500), 1152, 384),
    ("enc.ffn.up", "q8_matmul", padded_m(1500), 1536, 384),
    ("enc.ffn.down", "q8_matmul", padded_m(1500), 384, 1536),
    ("dec.ffn.up", "q8_matvec", 8, 1536, 384),
    ("dec.ffn.down", "q8_matvec", 8, 384, 1536),
    ("enc.ffn.up.bf16", "bf16_matmul", padded_m(1500), 1536, 384),
]

# Budget axis: 16 KB (the paper's smallest LMM point) -> full per-core
# VMEM. agg_units=1: one TPU core's VMEM, no PE aggregation (DESIGN.md §6.1).
BUDGETS = budget_grid(min_kb=16, agg_units=1)
assert BUDGETS[-1] == VMEM_FULL_BYTES


def _vmem_power_w(budget_bytes: int) -> float:
    """Fig 7 analog: the chip-power share attributed to the claimed local
    memory grows mildly with the budget (16->256 KB costs IMAX ~60%/lane;
    we apply a gentler 20% swing across the whole VMEM range)."""
    return energy.TPU_V5E_W * (0.8 + 0.2 * budget_bytes / VMEM_FULL_BYTES)


def run(measure: bool = False, iters: int = 3,
        save_cache: str | None = None,
        measured: bool = False,
        calibration: str | None = None) -> dict:
    cal = CalibratedCoefficients.load_or_none(
        calibration if calibration is not None else DEFAULT_CALIBRATION)
    # the label every cost column carries — the provenance of the numbers
    label = "measured" if measure else ("calibrated" if cal else "analytic")
    mode = "measured" if measure else "analytic"
    name, kernel, m, n, k = ("enc.ffn.down", "q8_matmul",
                             padded_m(1500), 384, 1536)
    block_ks = [b for b in BLOCK_K_CANDIDATES if k % b == 0]

    # --- the (vmem_budget x block_k) grid for the headline shape ---------
    if measure:
        def cost_fn(c, cm, cn, ck):
            return measured_cost(c, cm, cn, ck, iters=iters)
    else:
        def cost_fn(c, cm, cn, ck):
            return preferred_cost(c, cm, cn, ck, calibration=cal)
    cells = sweep_grid(kernel, m, n, k, budgets=BUDGETS,
                       block_ks=block_ks, cost_fn=cost_fn)
    by_cell = {(b, r.cand.block_k): r for b, r in cells}
    grid_rows, grid_cells = [], []
    for budget in BUDGETS:
        row = [f"{budget//1024}KB" if budget < 2**20
               else f"{budget/2**20:.0f}MB"]
        for bk in block_ks:
            best = by_cell.get((budget, bk))
            if best is None:
                row.append("-")
                continue
            p = _vmem_power_w(budget)
            grid_cells.append({
                "budget_bytes": budget, "block_k": bk,
                "cost_s": best.cost_s, "pdp_j": best.pdp_j(p),
                "edp_js": best.edp_js(p), "source": best.source,
                "tiling": best.cand.as_kwargs()})
            row.append(f"{best.pdp_j(p)*1e6:.2f}")
        grid_rows.append(row)
    print(f"(vmem_budget x block_k) PDP grid [uJ, {label}] — "
          f"{name} (M={m}, N={n}, K={k})")
    print(fmt_table(grid_rows, ["budget", *(f"bk={b}" for b in block_ks)]))
    best_cell = min(grid_cells, key=lambda c: c["pdp_j"])
    print(f"PDP-optimal cell: budget="
          f"{best_cell['budget_bytes']//1024}KB block_k="
          f"{best_cell['block_k']} (paper: 32KB LMM, burst 16)")

    # --- tuned vs hard-coded defaults over the tiny shape set ------------
    tuner = Autotuner(vmem_budget_bytes=VMEM_FULL_BYTES // 2,
                      mode=mode, cache_path=save_cache, calibration=cal)
    headers = ["class", "kernel", "MxNxK", "tuned tiling",
               f"tuned[{label}]", f"default[{label}]", f"speedup[{label}]"]
    if measured:
        headers += ["tuned[wall]", "default[wall]", "speedup[wall]"]
    cmp_rows, comparisons = [], []
    for sname, skern, sm, sn, sk in TINY_SHAPES:
        dtype = "q8_0" if skern.startswith("q8") else "bf16"
        rec = tuner.best_tiling(skern, sm, sn, sk, dtype)
        dflt = default_candidate(skern, sm, sn, sk)
        dcost = cost_fn(dflt, sm, sn, sk).cost_s
        tcost = rec.cost_s if rec else dcost
        tcand = (TileCandidate(skern, rec.block_m, rec.block_n, rec.block_k,
                               rec.vmem_bytes) if rec else dflt)
        tiling = (f"({rec.block_m},{rec.block_n},{rec.block_k})"
                  if rec else "default")
        row = [sname, skern, f"{sm}x{sn}x{sk}", tiling,
               f"{tcost*1e6:.2f}", f"{dcost*1e6:.2f}",
               f"{dcost/tcost:.2f}x" if tcost else "-"]
        comp = {"name": sname, "kernel": skern, "shape": [sm, sn, sk],
                "cost_label": rec.source if rec else label,
                "tuned_cost_s": tcost, "default_cost_s": dcost,
                "tuned": rec.tiling() if rec else None}
        if measured:
            tmeas = replay_candidate(tcand, sm, sn, sk, dtype,
                                     reps=iters).time_s
            dmeas = replay_candidate(dflt, sm, sn, sk, dtype,
                                     reps=iters).time_s
            row += [f"{tmeas*1e6:.2f}", f"{dmeas*1e6:.2f}",
                    f"{dmeas/tmeas:.2f}x"]
            comp.update(tuned_measured_s=tmeas, default_measured_s=dmeas)
        cmp_rows.append(row)
        comparisons.append(comp)
    print(f"\ntuned vs hard-coded defaults [{label} cost, us] — "
          "whisper-tiny shapes")
    print(fmt_table(cmp_rows, headers))
    regressions = [c for c in comparisons
                   if c["tuned_cost_s"] > c["default_cost_s"] * 1.001]
    print(f"tuned beats-or-matches default on "
          f"{len(comparisons)-len(regressions)}/{len(comparisons)} shapes")
    if label == "analytic":
        print("NOTE: all costs/speedups above are analytic roofline "
              "PROJECTIONS, not wall-clock measurements. Run "
              "benchmarks/calibration_error.py to fit calibrated "
              "constants, or pass --measured for replay columns.")
    elif label == "calibrated":
        backend = cal.default_backend
        print(f"costs are calibrated predictions (replay-fitted constants "
              f"for backend={backend}, DESIGN.md §14.2)")

    if save_cache:
        print(f"tuning cache saved to {tuner.save()} "
              f"({len(tuner.cache)} entries)")
    out = {"mode": mode, "cost_label": label,
           "calibration_backend": cal.default_backend if cal else None,
           "grid_shape": {"name": name, "m": m, "n": n, "k": k},
           "grid": grid_cells, "pdp_optimal": best_cell,
           "comparisons": comparisons,
           "tuned_never_worse": not regressions}
    save("tune_sweep", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--measure", action="store_true",
                    help="wall-clock the kernels instead of analytic cost")
    ap.add_argument("--measured", action="store_true",
                    help="add wall-clock replay columns to the "
                         "tuned-vs-default table")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--save-cache", default=None,
                    help="path to persist the JSON tuning cache")
    ap.add_argument("--calibration", default=None,
                    help="calibrated-coefficients JSON (default: "
                         "auto-detect experiments/bench/"
                         "calibration_coeffs.json)")
    args = ap.parse_args(argv)
    run(measure=args.measure, iters=args.iters, save_cache=args.save_cache,
        measured=args.measured, calibration=args.calibration)


if __name__ == "__main__":
    main()
