"""Shared benchmark plumbing: output locations, timing, result records."""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Any, Callable, Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(ROOT, "experiments", "bench")


def save(name: str, payload: Dict[str, Any]) -> str:
    """Persist a benchmark result atomically (tmp + os.replace): a crash
    or Ctrl-C mid-dump must never leave a truncated JSON that report.py
    or a CI artifact upload then chokes on (DESIGN.md §14.2 applies the
    same discipline to the tuning cache and calibration store)."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    fd, tmp = tempfile.mkstemp(dir=OUT_DIR, prefix=f".{name}.",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def timeit_median(fn: Callable[[], Any], iters: int = 3,
                  warmup: int = 1) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def fmt_table(rows, headers) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    def line(cells):
        return "| " + " | ".join(str(c).ljust(w)
                                 for c, w in zip(cells, widths)) + " |"
    sep = "|" + "|".join("-" * (w + 2) for w in widths) + "|"
    return "\n".join([line(headers), sep, *(line(r) for r in rows)])
