"""Paper Fig 4 / §1: runtime share of the dot-product kernel + Amdahl bound.

We run the full whisper-tiny config on this container's CPU twice — intact,
and with every *weight* GEMM replaced by an O(1) stand-in — and attribute
the difference to the dot-product kernel, mirroring the paper's per-op
profile. Attention score/AV einsums (also mul_mat in ggml terms) stay in
both runs, so our measured share is a LOWER bound on the paper's 87-91 %.
The Amdahl bounds are recomputed from the paper's own shares exactly.
Usage:
  PYTHONPATH=src python -m benchmarks.profile_shares

No CLI flags; ``run(n_frames=384, n_tokens=16)`` is parameterized for
callers. Wall-clock heavy: runs the full whisper-tiny config twice on CPU.
Writes experiments/bench/profile_shares.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, save, timeit_median
from repro.configs.registry import get_config
from repro.core.amdahl import PAPER_SHARE, amdahl_bound, profile_shares
from repro.models import layers, model as model_lib


class _NullGemm:
    """Offload-engine stand-in whose linear() is O(output size)."""

    def linear(self, x, w, name="linear"):
        n = w.shape[0]
        return jnp.zeros((*x.shape[:-1], n), jnp.float32) + jnp.sum(x) * 0


def run(n_frames: int = 384, n_tokens: int = 16) -> dict:
    cfg = get_config("whisper-tiny")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)
    mel = jax.random.normal(jax.random.PRNGKey(1), (1, n_frames, cfg.n_mels))
    toks = jnp.ones((1, n_tokens), jnp.int32)
    batch = {"mel": mel, "tokens": toks, "labels": toks}

    fwd_full = jax.jit(lambda p, b: model_lib.forward(p, cfg, b)[0])
    null = _NullGemm()
    fwd_null = jax.jit(
        lambda p, b: model_lib.forward(p, cfg, b, engine=null)[0])

    shares = profile_shares(lambda: fwd_full(params, batch),
                            lambda: fwd_null(params, batch), iters=3)
    rows = [
        ["ours (weight GEMMs only)", f"{shares['dot_share']*100:.1f}%",
         f"{shares['amdahl_bound']:.1f}x"],
        ["paper FP16 (all mul_mat)", f"{PAPER_SHARE['fp16']*100:.1f}%",
         f"{amdahl_bound(PAPER_SHARE['fp16']):.1f}x"],
        ["paper Q8_0 (all mul_mat)", f"{PAPER_SHARE['q8_0']*100:.1f}%",
         f"{amdahl_bound(PAPER_SHARE['q8_0']):.1f}x"],
    ]
    print("Fig 4 analog — dot-product runtime share + Amdahl bound")
    print(fmt_table(rows, ["measurement", "dot share", "max speedup"]))
    print(f"(t_full={shares['t_full_s']:.2f}s t_rest={shares['t_rest_s']:.2f}s"
          f" on this CPU; frames={n_frames})")
    out = {**shares,
           "paper_bounds": {k: amdahl_bound(v)
                            for k, v in PAPER_SHARE.items()},
           "dominant": shares["dot_share"] > 0.5}
    save("profile_shares", out)
    return out


if __name__ == "__main__":
    run()
