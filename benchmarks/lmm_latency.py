"""Paper Fig 11 / §5.1: LMM size -> projected E2E latency via the coverage
fallback model, for tiny/base/small x {fp16, q8_0}.

T(budget) = T_host x [uncovered + covered/accel_speedup]; anchored to the
paper's measured host-only times so absolute seconds are comparable.
Usage:
  PYTHONPATH=src python -m benchmarks.lmm_latency

No flags; prints projected E2E latency vs LMM size for tiny/base/small x
{fp16, q8_0} and writes experiments/bench/lmm_latency.json.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config
from repro.core.coverage import (
    LMM_SIZES_KB, enumerate_whisper, fallback_time_fraction)

# paper CPU-only anchors (s) for the jfk.wav workload (Fig 8 CPU bars /
# Table 5 scale): tiny ~11, base ~25, small ~100 (approximate anchors;
# the *trend* is the reproduction target)
HOST_ANCHOR_S = {"whisper-tiny": 11.2, "whisper-base": 26.0,
                 "whisper-small": 110.0}
# effective covered-kernel speedups, calibrated to the paper's observed
# system-level gains (Table 5 mean 1.04x; Fig 11 32->256KB gain 1.25x tiny)
ACCEL = {"fp16": 3.0, "q8_0": 2.5}


def run() -> dict:
    out = {}
    rows = []
    for arch, t_host in HOST_ANCHOR_S.items():
        ms = enumerate_whisper(get_config(arch))
        for path, acc in ACCEL.items():
            latencies = [t_host * fallback_time_fraction(ms, kb, acc)
                         for kb in LMM_SIZES_KB]
            rows.append([arch, path] + [f"{t:.1f}" for t in latencies])
            out[f"{arch}/{path}"] = dict(zip(LMM_SIZES_KB, latencies))
    print("Fig 11 analog — projected E2E latency (s) vs LMM size")
    print(fmt_table(rows, ["model", "path"] +
                    [f"{kb}KB" for kb in LMM_SIZES_KB]))

    # headline checks: monotone decrease; base/small big drop at 64KB
    tiny = out["whisper-tiny/fp16"]
    small = out["whisper-small/q8_0"]
    checks = {
        "monotone": all(tiny[a] >= tiny[b] - 1e-9 for a, b in
                        zip(LMM_SIZES_KB, LMM_SIZES_KB[1:])),
        "small_drops_after_32kb": small[64] < small[32],
        # paper Fig 11: tiny improves 1.25x from 32->256 KB; our
        # flop-weighted model lands 1.25-2x (same regime, residual slightly
        # overweighted vs the paper's dot-count weighting)
        "tiny_32_to_256_regime": 1.0 < tiny[32] / tiny[256] < 2.0,
    }
    print("claims:", checks)
    payload = {"latencies": {k: {str(s): v for s, v in d.items()}
                             for k, d in out.items()}, "checks": checks}
    save("lmm_latency", payload)
    return payload


if __name__ == "__main__":
    run()
