"""Decode throughput: tokens/s for whisper-tiny greedy decode, engine-off
vs engine-on — the wall-clock proof of the plan/ledger refactor
(DESIGN.md §10).

Before the refactor, attaching an ``OffloadEngine`` forced the decode step
out of ``jax.jit`` (in-trace stats mutation made it impure), so the
paper's flagship configuration — Q8_0 dot products through the offload
dispatcher — was the *slowest* one this repo could run: every decode step
re-traced the whole decoder through op-by-op dispatch. After the split,
routing resolves at trace time, the step jits unconditionally, and
engine-on decode pays only its (identical-math) kernel cost.

Measured on the CI-class CPU container (whisper-tiny smoke config, B=2,
24 decode steps, XLA path both sides):

  pre-refactor  : engine-on ~33 tok/s (un-jitted op-by-op dispatch; the
                  penalty is unbounded — it grows with model depth since
                  every decode step re-dispatches every op)
  post-refactor : engine-off ~2546 tok/s, engine-on ~2389 tok/s —
                  ratio 1.07x, a ~78x engine-on speedup, comfortably
                  within the 2x acceptance bound; the residual gap is
                  the mixed-execution split's extra partial-sum adds

Usage:
  PYTHONPATH=src python -m benchmarks.decode_throughput [--smoke]

``--smoke`` shrinks the workload for the CI gate (it still exercises the
jitted engine-on path end to end, so a dispatch regression that breaks
jit-with-engine fails the workflow). Writes
experiments/bench/decode_throughput.json.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.serve.engine import ServeEngine


def _decode_tok_s(engine: ServeEngine, mel: np.ndarray, max_new: int,
                  iters: int = 3) -> float:
    """Median decode tokens/s over ``iters`` transcribe calls (first call
    pays compilation; it is excluded by a warmup run)."""
    engine.transcribe(mel, max_new=max_new)             # warmup/compile
    rates = []
    for _ in range(iters):
        res = engine.transcribe(mel, max_new=max_new)
        toks = sum(r.steps for r in res)
        # rate uses the decode phase only so the (identical) encoder
        # prefill does not dilute the comparison
        dec = sum(r.decode_s for r in res)
        rates.append(toks / max(dec, 1e-9))
    rates.sort()
    return rates[len(rates) // 2]


def run(smoke: bool = False) -> dict:
    cfg = get_smoke_config("whisper-tiny")
    b, frames = (1, 8) if smoke else (2, 16)
    max_new = 6 if smoke else 24
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 64)
    mel = np.random.default_rng(0).standard_normal(
        (b, frames, cfg.n_mels)).astype(np.float32)

    off_engine = OffloadEngine(interpret=True, prefer_pallas=False)
    eng_on = ServeEngine(cfg, params, max_len=max_new + 8, quant="q8_0",
                         offload=off_engine, eos_id=-1)
    eng_off = ServeEngine(cfg, params, max_len=max_new + 8, quant="q8_0",
                          eos_id=-1)

    # median-of-3 in smoke mode too: the smoke decode window is ~ms-scale
    # and a single sample would make the CI ratio gate flake-prone
    iters = 3
    tok_s_off = _decode_tok_s(eng_off, mel, max_new, iters)
    tok_s_on = _decode_tok_s(eng_on, mel, max_new, iters)
    ratio = tok_s_off / max(tok_s_on, 1e-9)

    rows = [["engine-off", f"{tok_s_off:.1f}", "-"],
            ["engine-on", f"{tok_s_on:.1f}", f"{ratio:.2f}x"]]
    print("whisper-tiny decode throughput (tokens/s, jitted step both ways)")
    print(fmt_table(rows, ["config", "decode tok/s", "off/on ratio"]))
    within_2x = ratio <= 2.0
    print(f"engine-on within 2x of engine-off: {within_2x} "
          f"(plan/ledger split keeps the offloaded step jitted)")
    rep = eng_on.energy_report([])
    out = {"smoke": smoke, "batch": b, "frames": frames, "max_new": max_new,
           "tok_s_engine_off": tok_s_off, "tok_s_engine_on": tok_s_on,
           "off_on_ratio": ratio, "within_2x": within_2x,
           "dispatch": rep["dispatch"],
           "ledger": {"offloaded_calls": off_engine.stats.offloaded_calls,
                      "fallback_calls": off_engine.stats.fallback_calls,
                      "offload_rate": off_engine.stats.offload_rate()}}
    save("decode_throughput", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI benchmark-smoke gate")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    # CI gate: a dispatch regression that un-jits the engine-on path shows
    # up as an extreme ratio (pre-refactor measured ~7x)
    return 0 if out["within_2x"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
