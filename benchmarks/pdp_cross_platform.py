"""Paper Fig 9: cross-platform PDP under the TDP-normalized power model,
extended with our TPU-v5e projection (beyond-paper column).

IMAX/Jetson/RTX rows reproduce the paper's arithmetic from its own measured
latencies and power constants (Eq. 1). The TPU row projects whisper-tiny
decode from the roofline model: weights-bound decode time x TDP-class chip
power — the *same* normalized methodology the paper defends in §4.1.
Usage:
  PYTHONPATH=src python -m benchmarks.pdp_cross_platform

No flags; prints the Fig 9 PDP table (IMAX/Jetson/RTX rows from paper
constants, TPU row from the roofline projection) and writes
experiments/bench/pdp_cross_platform.json.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config
from repro.core import energy

# Paper latencies (s) for the ~10 s jfk.wav workload
LAT = {
    ("tiny", "fp16", "imax"): 15.39, ("tiny", "q8_0", "imax"): 10.71,
    # Jetson/RTX latencies implied by paper PDP / TDP
    ("tiny", "fp16", "jetson"): 22.59 / 15.0,
    ("tiny", "q8_0", "jetson"): 27.16 / 15.0,
    ("tiny", "q8_0", "rtx4090"): 121.38 / 450.0,
}
POWER = {"imax_fp16": 1.294, "imax_q8_0": 2.64,   # 2-lane 28nm + kernels
         "jetson": energy.P_JETSON_W, "rtx4090": energy.P_RTX4090_W}


def _tpu_whisper_decode_time(cfg, n_tokens: int = 27) -> float:
    """Roofline decode time on ONE v5e chip: per token, read all weights
    (Q8_0: ~1 byte/param) + encoder pass compute."""
    n = cfg.n_params()
    per_tok_s = n * 1.0 / 819e9                  # bytes / HBM bw (q8: 1B)
    enc_flops = 2 * n * 1500                     # encoder forward
    enc_s = enc_flops / 197e12
    return enc_s + n_tokens * per_tok_s


def run() -> dict:
    rows = []
    results = {}
    for (model, path, plat), t in LAT.items():
        paper_pdp = energy.PAPER_PDP_J.get((model, path, plat))
        if plat == "imax":
            # IMAX PDP uses the mixed Eq. 2 model: accelerator-active time
            # at P_IMAX + host remainder at P_ARM. The paper does not
            # publish t_active for Fig 9, so we derive it from its PDP and
            # verify Eq. 2 consistency (0 <= t_active <= t).
            p_acc = POWER[f"imax_{path}"]
            t_active = ((paper_pdp - t * energy.P_ARM_A72_W)
                        / (p_acc - energy.P_ARM_A72_W))
            pdp = energy.pdp_mixed(t_active, t, p_acc)
            assert 0.0 <= t_active <= t, "Eq.2-inconsistent paper figures"
            p_show = p_acc
        else:
            p_show = POWER[plat]
            pdp = energy.pdp(t, p_show)
        rows.append([plat, path, f"{t:.2f}", f"{p_show:.2f}", f"{pdp:.2f}",
                     f"{paper_pdp:.2f}" if paper_pdp else "-"])
        results[f"{plat}/{path}"] = {"time_s": t, "power_w": p_show,
                                     "pdp_j": pdp, "paper_pdp_j": paper_pdp}

    cfg = get_config("whisper-tiny")
    t_tpu = _tpu_whisper_decode_time(cfg)
    rep = energy.tpu_projection(t_tpu, chips=1)
    rows.append(["tpu_v5e(proj)", "q8_0", f"{t_tpu:.3f}",
                 f"{rep.power_w:.0f}", f"{rep.pdp_j:.2f}", "-"])
    results["tpu_v5e/q8_0"] = {"time_s": t_tpu, "power_w": rep.power_w,
                               "pdp_j": rep.pdp_j}

    print("Fig 9 analog — whisper-tiny PDP under TDP-normalized power")
    print(fmt_table(rows, ["platform", "path", "time(s)", "power(W)",
                           "PDP(J) ours", "PDP(J) paper"]))
    imax = results["imax/q8_0"]["pdp_j"]
    jets = results["jetson/q8_0"]["pdp_j"]
    rtx = results["rtx4090/q8_0"]["pdp_j"]
    ratios = {"imax_vs_jetson": jets / imax, "imax_vs_rtx": rtx / imax}
    print(f"IMAX vs Jetson: {ratios['imax_vs_jetson']:.2f}x lower PDP "
          f"(paper: 2.35x) | vs RTX4090: {ratios['imax_vs_rtx']:.2f}x "
          f"(paper: 10.48x)")
    out = {"rows": results, "ratios": ratios,
           "paper_ratios": {"imax_vs_jetson": 2.35, "imax_vs_rtx": 10.48}}
    save("pdp_cross_platform", out)
    return out


if __name__ == "__main__":
    run()
