"""Speculative decoding unified with continuous-batching and paged-KV
serving (DESIGN.md §17.4; the paper's §5.1 sustained multi-utterance
evaluation run through the §17 two-model ladder).

Queued utterances admit into freed wave rows at round boundaries, the
(B, k+1) verify window reads/writes the §15 page arena through block
tables (multi-entry scatter, windows straddling page boundaries), and
the pre-round capacity pass preempts-and-replays when a tight arena
runs dry. The gates, asserted every run (CI via ``--smoke`` on the
default AND multidev legs):

  - token-exact parity: under a deterministic Poisson arrival trace
    with mid-flight admission, the round-boundary schedulers
    (``SpecContinuousScheduler`` AND ``PagedSpecScheduler``) reproduce
    BOTH references exactly — the run-to-completion ``SpecScheduler``
    wave and plain greedy on the verifier alone — for dense f32 and
    q8_0+offload
  - tight-arena parity: a page arena too small for the active set
    forces preempt-and-replay mid-schedule (``preemptions > 0``
    asserted) and still reproduces both references token-exactly
  - mid-flight admission: requests really are admitted while earlier
    requests hold live rows (``midflight > 0`` asserted), so the
    round-boundary path is exercised, not just batch-start admission
  - zero step retraces: across each whole drain the verify window and
    the draft step compile exactly once per engine
  - exact attribution: per-request PDP sums to the batch total every
    drive (asserted in ``_drive``); on q8_0+offload the shared ledger's
    by_role split sums to the flop totals and the §16.2 ledger spans
    claim every committed FLOP

Workload: the reduced ladder + echo parameterization from
``benchmarks.speculative`` (tiny draft, base-rung verifier, decoder
blocks scaled toward identity so acceptance is high); arrival gaps are
Poisson in round units on a virtual clock, so the trace is
machine-independent. ``--trace-out``/``--metrics-out`` export the q8
paged engine's Perfetto trace (validated by tools/check_trace.py in CI)
and metrics exposition.

Usage:
  PYTHONPATH=src python -m benchmarks.paged_speculative [--smoke]
      [--trace-out PATH] [--metrics-out PATH]

Writes experiments/bench/paged_speculative.json.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import fmt_table, save
from benchmarks.speculative import _echo_params, _ladder_cfg
from repro import obs
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.serve.engine import ServeEngine
from repro.serve.speculative import SpecScheduler

K = 4


def _workload(cfg, smoke: bool, rng: np.random.Generator):
    """Distinct utterances with varied budgets; Poisson arrival gaps in
    round units at ~2x service rate so admissions land mid-flight."""
    n_req, n_frames = (8, 16) if smoke else (14, 32)
    lo, hi = (4, 10) if smoke else (6, 16)
    mels = [rng.standard_normal((1, n_frames, cfg.n_mels)).astype(np.float32)
            for _ in range(n_req)]
    max_news = [int(rng.integers(lo, hi + 1)) for _ in range(n_req)]
    # a round emits ~k+1 tokens/row at echo acceptance: mean service is
    # max_new/(k+1) rounds; 2x load on 2 slots backs the queue up
    mean_gap = float(np.mean(max_news)) / (K + 1) / (2 * 2)
    arrivals = np.floor(np.cumsum(rng.exponential(mean_gap, n_req)))
    return mels, max_news, arrivals, n_frames, hi


def _drive(sched, mels: List[np.ndarray], max_news: List[int],
           arrivals: np.ndarray) -> Dict[str, object]:
    """Replay the arrival trace on a virtual round clock (one unit per
    speculative round), counting admissions that land while earlier
    requests hold live rows — the §17.4 round-boundary path."""
    t, i, n = 0, 0, len(mels)
    rid2idx: Dict[int, int] = {}
    midflight = 0
    wall0 = time.perf_counter()
    while i < n or sched.n_queued or sched.n_active:
        while i < n and arrivals[i] <= t:
            rid2idx[sched.submit(mels[i], max_new=max_news[i])] = i
            i += 1
        was_active = sched.n_active
        admitted = sched.admit()
        if was_active and admitted:
            midflight += len(admitted)
        if sched.n_active:
            sched.decode_step()
            t += 1
        elif i < n:
            t = int(arrivals[i])          # idle: jump to the next arrival
    wall = time.perf_counter() - wall0
    att = sched.attribution()
    per_req = sum(att["per_request_pdp_j"].values())
    assert abs(per_req - att["batch_pdp_j"]) <= \
        1e-6 * max(1.0, att["batch_pdp_j"]), \
        "per-request PDP attribution must sum to the batch total (§11.3)"
    got = sched.finished
    rids = sorted(rid2idx, key=rid2idx.get)
    steps = sum(got[r].steps for r in rids)
    return {"tokens": [got[r].tokens for r in rids],
            "steps": steps, "wall_s": wall,
            "tok_s": steps / max(wall, 1e-9),
            "midflight": midflight,
            "rounds": t}


def _variant(name: str, quant: str, make_offload, smoke: bool,
             telemetry=None) -> Dict[str, object]:
    rng = np.random.default_rng(0)        # same trace for every variant
    vcfg = _ladder_cfg("base")
    dcfg = _ladder_cfg("tiny")
    alpha = 0.02
    vparams = _echo_params(model_lib.init_params(jax.random.PRNGKey(1),
                                                 vcfg), alpha)
    dparams = _echo_params(model_lib.init_params(jax.random.PRNGKey(0),
                                                 dcfg), alpha)
    mels, max_news, arrivals, n_frames, hi = _workload(vcfg, smoke, rng)
    n_slots = 2
    max_len = hi + K + 2                  # submit guard: max_new + k + 1

    def spec_of(eng):
        return eng.speculative(dcfg, dparams, k=K)

    def engine(tele=None):
        return ServeEngine(vcfg, vparams, max_len=max_len, quant=quant,
                           offload=make_offload(), eos_id=-1,
                           telemetry=tele)

    # reference 1: plain greedy on the verifier alone, batch-1
    eng_g = engine()
    greedy = [eng_g.transcribe(m, sot_id=1, max_new=mn)[0].tokens
              for m, mn in zip(mels, max_news)]
    # reference 2: the run-to-completion SpecScheduler wave (§17.4)
    eng_w = engine()
    wave_sch = SpecScheduler(spec_of(eng_w), n_slots=n_slots)
    rids = [wave_sch.submit(m, max_new=mn)
            for m, mn in zip(mels, max_news)]
    wres = wave_sch.run()
    wave = [wres[r].tokens for r in rids]

    # round-boundary admission on the contiguous slot pool
    eng_c = engine()
    spec_c = spec_of(eng_c)
    contig = _drive(spec_c.continuous(n_slots=n_slots, n_frames=n_frames),
                    mels, max_news, arrivals)

    # the paged arena, roomy: every slot can hold its full budget
    pages_per = -(-max_len // 4)
    geom = dict(page_size=4, n_pages=1 + n_slots * pages_per,
                cross_page_size=n_frames, n_cross_pages=1 + n_slots)
    eng_p = engine(telemetry)
    spec_p = spec_of(eng_p)
    paged = _drive(spec_p.paged(n_slots=n_slots, n_frames=n_frames, **geom),
                   mels, max_news, arrivals)

    # deliberately tight arena: ONE slot's worth of self pages (any
    # single request still fits), so two live rows MUST collide in the
    # pre-round capacity pass and preempt-and-replay mid-schedule
    tele_t = obs.Telemetry() if telemetry is not None else None
    eng_t = engine(tele_t)
    spec_t = spec_of(eng_t)
    sched_t = spec_t.paged(n_slots=n_slots, n_frames=n_frames,
                           page_size=4, n_pages=1 + pages_per,
                           cross_page_size=n_frames,
                           n_cross_pages=1 + n_slots)
    tight = _drive(sched_t, mels, max_news, arrivals)

    checks = {
        "wave_is_greedy": wave == greedy,
        "contig_parity": contig["tokens"] == greedy,
        "paged_parity": paged["tokens"] == greedy,
        "tight_parity": tight["tokens"] == greedy,
        "midflight_admission": (contig["midflight"] > 0
                                and paged["midflight"] > 0),
        "tight_preempted": sched_t.preemptions > 0,
        "zero_retrace": all(
            s.verifier._verify_traces == 1 and s.draft._step_traces == 1
            for s in (spec_c, spec_p, spec_t)),
    }
    report: Dict[str, object] = {}
    if quant == "q8_0":
        s = eng_p.offload.stats
        total = s.offloaded_flops + s.fallback_flops + s.residual_flops
        checks["by_role_sums"] = sum(s.by_role.values()) == total
        report["by_role"] = dict(s.by_role)
    if telemetry is not None:
        for tag, tl in (("paged", telemetry), ("tight", tele_t)):
            cons = tl.ledger_consistent()
            checks[f"tele_{tag}_ledger_exact"] = bool(cons["exact"])
            checks[f"tele_{tag}_spans_closed"] = tl.tracer.all_closed()
            checks[f"tele_{tag}_nesting"] = not tl.tracer.check_nesting()
    acc = spec_p.acceptance_rate()
    modes = {"contiguous": contig, "paged": paged, "tight": tight}
    return {"name": name, "k": K, "n_slots": n_slots, "geometry": geom,
            **{mode: {k: v for k, v in r.items() if k != "tokens"}
               for mode, r in modes.items()},
            "modes": list(modes),
            "acceptance": acc,
            "preemptions": sched_t.preemptions,
            "checks": checks, "ok": all(checks.values())}


def run(smoke: bool = False, trace_out: str = None,
        metrics_out: str = None) -> dict:
    tele = obs.Telemetry()                # rides the q8 paged engine
    variants = [
        _variant("dense", "none", lambda: None, smoke),
        _variant("q8_0+offload", "q8_0",
                 lambda: OffloadEngine(interpret=True, prefer_pallas=False),
                 smoke, telemetry=tele),
    ]

    rows = []
    for v in variants:
        for mode in v["modes"]:
            r = v[mode]
            rows.append([v["name"], mode, f"{r['tok_s']:.1f}",
                         str(r["rounds"]), str(r["midflight"]),
                         f"{v['acceptance']:.2f}"])
    print(f"paged + continuous speculative serving, reduced ladder, "
          f"k={K} ({'smoke' if smoke else 'full'})")
    print(fmt_table(rows, ["variant", "mode", "tok/s", "rounds",
                           "midflight admits", "accept"]))
    ok = True
    for v in variants:
        ok = ok and v["ok"]
        detail = " ".join(f"{k}={'ok' if val else 'FAIL'}"
                          for k, val in v["checks"].items())
        print(f"{v['name']}: {v['preemptions']} preemptions (tight) | "
              f"{detail} -> {'ok' if v['ok'] else 'FAIL'}")
    if trace_out:
        print("trace written:", tele.write_trace(trace_out))
    if metrics_out:
        print("metrics written:", tele.write_metrics(metrics_out))
    out = {"smoke": smoke, "variants": variants, "gate_ok": ok,
           "ledger_consistency": tele.ledger_consistent()}
    save("paged_speculative", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI gate")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the q8 paged engine's Perfetto trace")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write its Prometheus metrics exposition")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke, trace_out=args.trace_out,
              metrics_out=args.metrics_out)
    return 0 if out["gate_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
