"""Paper Fig 10 / §4.4: burst-length sensitivity (PDP/EDP) + the TPU
tile-granularity analog sweep.
Usage:
  PYTHONPATH=src python -m benchmarks.burst_sweep

No flags; prints the Fig 10 PDP/EDP table against the paper's numbers and
the block_k tile-analog sweep, and writes experiments/bench/burst_sweep.json.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config
from repro.core.bursts import (
    optimal_burst, paper_burst_sweep, tile_sweep_report)
from repro.core.coverage import enumerate_whisper

PAPER = {8: {"pdp": 44.7, "edp": 2159.3},
         16: {"pdp": 42.2, "edp": 1511.0},
         32: {"pdp": 58.6, "edp": 2032.0}}


def run() -> dict:
    pts = paper_burst_sweep(lanes=2)
    rows = [[p.burst, f"{p.t_main_s:.1f}", f"{p.power_w:.3f}",
             f"{p.pdp_j:.1f}", f"{PAPER[p.burst]['pdp']:.1f}",
             f"{p.edp_js:.0f}", f"{PAPER[p.burst]['edp']:.0f}"]
            for p in pts]
    print("Fig 10 reproduction — burst sweep (whisper-tiny FP16, 32KB LMM)")
    print(fmt_table(rows, ["burst", "T_MAIN(s)", "P_sys(W)",
                           "PDP(J) ours", "paper", "EDP(J*s) ours", "paper"]))
    best_pdp = optimal_burst(pts, "pdp").burst
    best_edp = optimal_burst(pts, "edp").burst
    print(f"PDP-optimal burst: {best_pdp} (paper: 16); "
          f"EDP-optimal: {best_edp} (paper: 16)")

    # TPU analog: lane-granularity sweep on the tiny workload
    ms = enumerate_whisper(get_config("whisper-tiny"))
    tile_rows = []
    for tp in tile_sweep_report(ms):
        tile_rows.append([tp.burst, f"{tp.residual_flop_frac:.3f}",
                          f"{tp.vmem_claim_bytes/2**20:.2f}MiB",
                          f"{tp.grid_overhead:.2f}", f"{tp.score:.3f}"])
    print("\nTPU tile-granularity analog (block_k sweep)")
    print(fmt_table(tile_rows, ["block_k", "residual_flops",
                                "vmem_claim", "overhead", "PDP-proxy"]))
    out = {
        "paper_sweep": [p.__dict__ for p in pts],
        "pdp_optimal": best_pdp, "edp_optimal": best_edp,
        "matches_paper": best_pdp == 16 and best_edp == 16,
        "tile_sweep": [t.__dict__ for t in tile_sweep_report(ms)],
    }
    save("burst_sweep", out)
    return out


if __name__ == "__main__":
    run()
