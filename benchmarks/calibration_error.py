"""Analytic-vs-measured calibration study + CI prediction-error gate
(DESIGN.md §14.3) — the measured grounding for Fig 7+10's tuning claims.

Every cost `tune_sweep.py` prints is, by default, an analytic roofline
*projection* priced with datasheet constants.  This benchmark replays the
tuner's chosen tiling for each whisper-tiny GEMM class (plus scaled
variants for fit conditioning) as a real jitted program per backend
(DESIGN.md §14.1), fits per-backend effective constants
(``tuning/calibrate.py``), and reports, per (kernel, M, N, K, dtype,
backend):

  * the measured trimmed-mean wall-clock,
  * the raw analytic projection (datasheet constants) and its scale error,
  * the calibrated prediction and its relative error, with a
    p10/p50/p90 percentile summary,
  * the Spearman rank correlation between the analytic ordering of the
    candidate set and the measured ordering — the property the tuner
    actually relies on, meaningful even where absolute errors are large.

Fitted coefficients persist as the versioned JSON store
(``experiments/bench/calibration_coeffs.json``, or ``--save-calibration``
to drop them next to a tuning cache where ``Autotuner`` auto-loads them).

Usage:
  PYTHONPATH=src python -m benchmarks.calibration_error
      [--smoke] [--refresh-baseline] [--backends xla_ref,pallas_tpu]
      [--reps N] [--warmup N] [--save-calibration PATH]

``--smoke`` is the CI gate (replay N=3 on ``xla_ref``): asserts the
median calibrated relative error stays under the stored baseline
threshold (``benchmarks/baselines/calibration_error.json``), the
analytic-vs-measured rank correlation does not regress below its floor,
and ``CalibratedCoefficients`` round-trips through the JSON store
exactly.  ``--refresh-baseline`` re-derives the baseline from the current
run with headroom and rewrites the stored file (review the diff!).
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import OUT_DIR, fmt_table, save
from repro.tuning import (
    Autotuner, CalibratedCoefficients, TileCandidate, analytic_cost,
    default_candidate, fit_backend, rank_correlation, replay_candidate)

BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baselines", "calibration_error.json")
COEFFS_PATH = os.path.join(OUT_DIR, "calibration_coeffs.json")

# The candidate set: whisper-tiny's dominant GEMM classes (paper Table 1)
# plus scaled variants so the three fit columns (flops, bytes, steps) are
# well conditioned.  (name, kernel, M, N, K, dtype).
SHAPES = [
    ("enc.attn.qkv", "q8_matmul", 1504, 1152, 384, "q8_0"),
    ("enc.ffn.up", "q8_matmul", 1504, 1536, 384, "q8_0"),
    ("enc.ffn.down", "q8_matmul", 1504, 384, 1536, "q8_0"),
    ("enc.half", "q8_matmul", 752, 768, 384, "q8_0"),
    ("enc.deep.k", "q8_matmul", 1504, 384, 3072, "q8_0"),
    ("dec.ffn.up.mv", "q8_matvec", 8, 1536, 384, "q8_0"),
    ("dec.ffn.down.mv", "q8_matvec", 8, 384, 1536, "q8_0"),
    ("dec.wide.mv", "q8_matvec", 8, 3072, 384, "q8_0"),
    ("enc.ffn.up.bf16", "bf16_matmul", 1504, 1536, 384, "bf16"),
    ("enc.ffn.down.bf16", "bf16_matmul", 1504, 384, 1536, "bf16"),
]
# The smoke gate replays the FULL shape set (total measured work is
# ~100 ms/rep) but at N=3: a smaller subset would hand the error median
# to the noisy microsecond-scale matvec rows; over all ten shapes it
# sits on the stable millisecond-scale GEMMs.


def _percentiles(xs):
    import numpy as np
    p10, p50, p90 = np.percentile(np.asarray(xs, dtype=float), [10, 50, 90])
    return {"p10": float(p10), "p50": float(p50), "p90": float(p90)}


def _tiling_for(tuner: Autotuner, kernel: str, m: int, n: int, k: int,
                dtype: str) -> TileCandidate:
    """The tiling the tuner would dispatch (analytic ranking), or the
    untuned default when nothing fits the budget."""
    rec = tuner.best_tiling(kernel, m, n, k, dtype)
    if rec is None:
        return default_candidate(kernel, m, n, k)
    return TileCandidate(kernel, rec.block_m, rec.block_n, rec.block_k,
                         rec.vmem_bytes)


def run_backend(backend: str, shapes, reps: int, warmup: int) -> dict:
    """Replay every shape on one (requested) backend, fit coefficients,
    and score predictions.  Returns the per-backend report block."""
    tuner = Autotuner(mode="analytic")
    samples, rows = [], []
    for name, kern, m, n, k, dtype in shapes:
        cand = _tiling_for(tuner, kern, m, n, k, dtype)
        smp = replay_candidate(cand, m, n, k, dtype, backend=backend,
                               reps=reps, warmup=warmup)
        arep = analytic_cost(cand, m, n, k)
        samples.append(smp)
        rows.append({"name": name, "kernel": kern, "m": m, "n": n, "k": k,
                     "dtype": dtype, "backend": smp.backend,
                     "tiling": [cand.block_m, cand.block_n, cand.block_k],
                     "measured_s": smp.time_s, "analytic_s": arep.cost_s})
    actual = samples[0].backend       # post force/pin resolution
    coeffs = fit_backend(samples, backend=actual)
    for smp, row in zip(samples, rows):
        pred = coeffs.predict(smp.flops, smp.bytes_hbm, smp.steps)
        row["calibrated_s"] = pred
        row["rel_err"] = abs(pred - row["measured_s"]) / row["measured_s"]
        row["analytic_scale"] = row["analytic_s"] / row["measured_s"]
    corr = rank_correlation([r["analytic_s"] for r in rows],
                            [r["measured_s"] for r in rows])
    return {"backend_requested": backend, "backend": actual,
            "coefficients": {"eff_flops": coeffs.eff_flops,
                             "eff_bw": coeffs.eff_bw,
                             "overhead_s": coeffs.overhead_s,
                             "n_samples": coeffs.n_samples},
            "rows": rows, "rank_corr": corr,
            "rel_err": _percentiles([r["rel_err"] for r in rows]),
            "_coeffs_obj": coeffs}


def _print_backend(rep: dict) -> None:
    rows = [[r["name"], r["kernel"], f'{r["m"]}x{r["n"]}x{r["k"]}',
             f'{r["measured_s"]*1e6:.1f}', f'{r["calibrated_s"]*1e6:.1f}',
             f'{r["rel_err"]*100:.1f}%', f'{r["analytic_scale"]:.2g}x']
            for r in rep["rows"]]
    print(f'\nbackend={rep["backend"]} (requested {rep["backend_requested"]})'
          f' — measured vs calibrated prediction')
    print(fmt_table(rows, ["class", "kernel", "MxNxK", "measured us",
                           "calibrated us", "rel err", "analytic/measured"]))
    pe = rep["rel_err"]
    print(f'calibrated rel err p10/p50/p90 = {pe["p10"]:.3f}/'
          f'{pe["p50"]:.3f}/{pe["p90"]:.3f}; analytic-vs-measured '
          f'rank corr = {rep["rank_corr"]:.3f}')


def _load_baseline() -> dict:
    with open(BASELINE_PATH) as f:
        return json.load(f)


def _refresh_baseline(rep: dict) -> dict:
    """Re-derive the stored gate thresholds from this run with headroom:
    3x the observed median error (+0.08 absolute) and 0.3 rank-corr slack
    (floored at 0.5) — loose enough for shared-runner noise, tight enough
    that a model or fit regression (errors past 1.0, correlation toward
    0) still trips it."""
    base = {"schema": 1, "backend": rep["backend"],
            "median_rel_err_max": round(3.0 * rep["rel_err"]["p50"]
                                        + 0.08, 4),
            "rank_corr_min": round(max(0.5, rep["rank_corr"] - 0.3), 4)}
    os.makedirs(os.path.dirname(BASELINE_PATH), exist_ok=True)
    with open(BASELINE_PATH + ".tmp", "w") as f:
        json.dump(base, f, indent=1)
    os.replace(BASELINE_PATH + ".tmp", BASELINE_PATH)
    print(f"baseline refreshed -> {BASELINE_PATH}: {base}")
    return base


def run(backends=("xla_ref",), reps: int = 5, warmup: int = 2,
        smoke: bool = False, refresh_baseline: bool = False,
        save_calibration: str | None = None) -> dict:
    shapes = SHAPES
    if smoke:
        backends, reps, warmup = ("xla_ref",), 3, 2

    cal = CalibratedCoefficients()
    reports = []
    for b in backends:
        rep = run_backend(b, shapes, reps, warmup)
        _print_backend(rep)
        cal.put(rep.pop("_coeffs_obj"))
        reports.append(rep)

    cal.save(COEFFS_PATH)
    print(f"\ncalibrated coefficients -> {COEFFS_PATH} "
          f"({len(cal)} backend(s))")
    if save_calibration:
        cal.save(save_calibration)
        print(f"calibration also saved -> {save_calibration}")

    # the JSON store must be lossless: a calibration that changes on
    # rewrite would silently drift tuner rankings between runs
    roundtrip = CalibratedCoefficients.load(COEFFS_PATH)
    store_exact = roundtrip.to_dict() == cal.to_dict()

    out = {"smoke": smoke, "reps": reps, "warmup": warmup,
           "backends": reports, "store_roundtrip_exact": store_exact,
           "coeffs_path": COEFFS_PATH}

    if smoke or refresh_baseline:
        gate = next((r for r in reports if r["backend"] == "xla_ref"),
                    reports[0])
        if refresh_baseline:
            base = _refresh_baseline(gate)
        else:
            base = _load_baseline()
        med, corr = gate["rel_err"]["p50"], gate["rank_corr"]
        ok_err = med <= base["median_rel_err_max"]
        ok_corr = corr >= base["rank_corr_min"]
        print(f'\nsmoke gate [{gate["backend"]}]: median rel err '
              f'{med:.3f} <= {base["median_rel_err_max"]} '
              f'{"PASS" if ok_err else "FAIL"}; rank corr {corr:.3f} >= '
              f'{base["rank_corr_min"]} {"PASS" if ok_corr else "FAIL"}; '
              f'store roundtrip exact '
              f'{"PASS" if store_exact else "FAIL"}')
        out["gate"] = {"baseline": base, "median_rel_err": med,
                       "rank_corr": corr,
                       "passed": ok_err and ok_corr and store_exact}
        save("calibration_error", out)
        assert store_exact, "coefficients JSON store round-trip not exact"
        assert ok_err, (f"median calibrated rel err {med:.3f} exceeds "
                        f"baseline {base['median_rel_err_max']} — the cost "
                        "model's prediction error regressed")
        assert ok_corr, (f"analytic-vs-measured rank corr {corr:.3f} below "
                         f"baseline {base['rank_corr_min']} — the analytic "
                         "ordering no longer matches measurements")
        return out

    save("calibration_error", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: xla_ref, N=3, assert against baseline")
    ap.add_argument("--refresh-baseline", action="store_true",
                    help="rewrite the stored baseline from this run")
    ap.add_argument("--backends", default="xla_ref",
                    help="comma-separated registry backend names")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--save-calibration", default=None,
                    help="also write coefficients here (e.g. next to a "
                         "tuning cache for Autotuner auto-load)")
    args = ap.parse_args(argv)
    run(backends=tuple(b for b in args.backends.split(",") if b),
        reps=args.reps, warmup=args.warmup, smoke=args.smoke,
        refresh_baseline=args.refresh_baseline,
        save_calibration=args.save_calibration)


if __name__ == "__main__":
    main()
