"""Paper Table 4/5: multi-utterance latency + transcript-agreement check.

The paper decodes 21 LibriSpeech utterances on CPU vs IMAX and reports a
0.00-0.13 % transcript delta. Our analog: N synthetic utterances of varying
length through the FULL whisper-tiny config, greedy-decoded twice — dense
bf16 XLA path (the "CPU" reference) vs Q8_0 + offload dispatcher (the
"IMAX" path) — reporting per-utterance latency and token agreement.
Usage:
  PYTHONPATH=src python -m benchmarks.multi_utterance \
      [--n-utts N] [--max-new M] [--smoke]

``--smoke`` runs the reduced whisper-tiny smoke config with short
utterances (CI-speed); the default decodes the FULL config twice per
utterance and is wall-clock heavy. ``run(n_utts=5, max_new=8)`` stays
parameterized for callers (benchmarks.run uses the defaults). Writes
experiments/bench/multi_utterance.json.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config, get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.serve.engine import ServeEngine


def run(n_utts: int = 5, max_new: int = 8, smoke: bool = False) -> dict:
    cfg = (get_smoke_config if smoke else get_config)("whisper-tiny")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)
    rng = np.random.default_rng(0)
    lengths = rng.integers(8, 24, n_utts) if smoke \
        else rng.integers(64, 256, n_utts)

    dense = ServeEngine(cfg, params, max_len=max_new + 8, quant="none",
                        eos_id=-1)
    q8 = ServeEngine(cfg, params, max_len=max_new + 8, quant="q8_0",
                     offload=OffloadEngine(prefer_pallas=False), eos_id=-1)

    rows, per_utt = [], []
    for i, L in enumerate(lengths):
        mel = rng.standard_normal((1, int(L), cfg.n_mels)).astype(np.float32)
        rd = dense.transcribe(mel, max_new=max_new)[0]
        rq = q8.transcribe(mel, max_new=max_new)[0]
        delta = float(np.mean([a != b for a, b in
                               zip(rd.tokens, rq.tokens)]))
        speed = rd.total_s / max(rq.total_s, 1e-9)
        rows.append([i, int(L), f"{rd.total_s:.2f}", f"{rq.total_s:.2f}",
                     f"{speed:.2f}x", f"{delta*100:.1f}%"])
        per_utt.append({"frames": int(L), "dense_s": rd.total_s,
                        "q8_s": rq.total_s, "delta": delta})
    mean_delta = float(np.mean([u["delta"] for u in per_utt]))
    print("Table 5 analog — per-utterance latency + transcript delta")
    print(fmt_table(rows, ["id", "frames", "dense(s)", "q8+offload(s)",
                           "speed", "delta"]))
    print(f"mean token delta: {mean_delta*100:.2f}% (paper: 0.13%)")
    out = {"utterances": per_utt, "mean_delta": mean_delta,
           "paper_mean_delta": 0.0013, "smoke": smoke,
           "offload_rate": q8.offload.stats.offload_rate()}
    save("multi_utterance", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-utts", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke config + short utterances")
    args = ap.parse_args(argv)
    run(n_utts=args.n_utts, max_new=args.max_new, smoke=args.smoke)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
