"""Per-backend execution matrix (DESIGN.md §12): the tiny-shape suite once
per registered backend — tok/s + PDP per backend, the cross-backend
restatement of the paper's Fig 9 cross-platform PDP table.

One "token" is one pass of a decode-batch activation through the
whisper-tiny Q8_0 projection set (attn/ffn.up/ffn.down — the dot-product
hot spots the paper offloads). Each registered backend is forced via
``REGISTRY.force`` and runs the identical jitted program, and the burst
divides every suite K (zero residual), so each row measures exactly the
backend it is labeled with: pallas_tpu (native on TPU, interpret-mode —
deliberately slow — on this CPU container), xla_ref (the always-available
reference), and host_residual pinned whole-problem (the paper's CPU-only
comparison row). PDP uses the TDP-normalized methodology of §4.1
(time x platform W), so off-TPU the numbers are proxies that rank, not
absolute joules.

Usage:
  PYTHONPATH=src python -m benchmarks.backend_matrix [--smoke]

``--smoke`` shrinks shapes/iters for the CI gate; the gate itself is
numerical: every backend's output must stay allclose to the ref.py oracle.
Writes experiments/bench/backend_matrix.json.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, save, timeit_median
from repro.backends import (
    MAIN, REGISTRY, KernelRequest, backend_platform, executor)
from repro.core import energy
from repro.core.qformats import quantize_q8_0
from repro.kernels import ref
from repro.tuning import kernel_for

# whisper-tiny decode projections: (name, N, K) per token row
SHAPES = [("attn.qkv", 384, 384), ("ffn.up", 1536, 384),
          ("ffn.down", 384, 1536)]
# divides every suite K, so k_res == 0 for all rows: no host_residual
# share contaminates the per-backend comparison (the paper's zero-residual
# claim for whisper's principal kernels, DESIGN.md §5)
BURST = 128
BATCH = 8                       # decode-batch rows = tokens per step


def _suite(smoke: bool):
    shapes = SHAPES[:1] if smoke else SHAPES
    key = jax.random.PRNGKey(0)
    xs, wqs = [], []
    for i, (_, n, k) in enumerate(shapes):
        kx, kw = jax.random.split(jax.random.fold_in(key, i))
        xs.append(jax.random.normal(kx, (BATCH, k), jnp.float32))
        wqs.append(quantize_q8_0(jax.random.normal(kw, (n, k)) * 0.1))
    return shapes, xs, wqs


def run(smoke: bool = False) -> dict:
    shapes, xs, wqs = _suite(smoke)
    iters = 2 if smoke else 3
    rows, results = [], {}
    ok = True
    for name in REGISTRY.names():
        backend = REGISTRY.get(name)
        req = KernelRequest(kernel=kernel_for(BATCH, True), m=BATCH,
                            n=shapes[0][1], k=shapes[0][2], dtype="q8_0",
                            segment=MAIN)
        if not backend.supports(req):
            rows.append([name, "-", "-", "unsupported"])
            continue
        hints = backend.cost_hints(req)

        def step_fn(xs, wqs=tuple(wqs), name=name):
            # dispatch resolves at trace time; the compiled step is pure
            return [executor.matmul(x, wq, burst=BURST, backend=name)
                    for x, wq in zip(xs, wqs)]

        jstep = jax.jit(step_fn)

        def step(xs=tuple(xs), jstep=jstep):
            return jstep(xs)

        # force (not just pin) this row's backend: a force() context
        # outranks an ambient REPRO_BACKEND, so rows stay correctly
        # labeled even when the env var is set (DESIGN.md §12.2). Tracing
        # happens inside the context; the timed replays are compiled.
        with REGISTRY.force(name):
            outs = step()
            close = all(
                np.allclose(np.asarray(o),
                            np.asarray(ref.q8_matmul_ref(x, wq)),
                            rtol=2e-4, atol=2e-4)
                for o, x, wq in zip(outs, xs, wqs))
            ok = ok and close
            t_step = timeit_median(step, iters=iters, warmup=1)
        tok_s = BATCH / max(t_step, 1e-12)
        pdp_mj_tok = energy.pdp(t_step, energy.TPU_V5E_W) / BATCH * 1e3
        results[name] = {"t_step_s": t_step, "tok_s": tok_s,
                         "pdp_mj_per_tok": pdp_mj_tok,
                         "allclose_ref": bool(close), "hints": hints}
        rows.append([name, f"{tok_s:.1f}", f"{pdp_mj_tok:.3f}",
                     "ok" if close else "MISMATCH"])

    print(f"backend matrix — {len(shapes)} shape(s) x B={BATCH}, "
          f"burst {BURST}, Q8_0 (pallas_tpu interprets off-TPU)")
    print(fmt_table(rows, ["backend", "tok/s", "PDP mJ/tok", "vs ref"]))
    out = {"smoke": smoke, "batch": BATCH, "burst": BURST,
           "shapes": [{"name": s[0], "n": s[1], "k": s[2]} for s in shapes],
           "platform": backend_platform(), "backends": results,
           "all_match_ref": ok}
    save("backend_matrix", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one shape, fewer iters — the CI parity gate")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    # CI gate: every backend must agree with the ref.py oracle
    return 0 if out["all_match_ref"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
