"""Paper Fig 7: synthesized power vs LMM size (FP16 and Q8_0 paths), and the
PDP-optimality argument for the 32 KB operating point.
Usage:
  PYTHONPATH=src python -m benchmarks.lmm_power

No flags; prints the Fig 7 power-vs-LMM table with coverage context and
writes experiments/bench/lmm_power.json.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config
from repro.core import energy
from repro.core.coverage import LMM_SIZES_KB, coverage, enumerate_whisper


def run() -> dict:
    rows = []
    for kb in LMM_SIZES_KB:
        rows.append([f"{kb}KB", f"{energy.lmm_power(kb, 'fp16'):.3f}",
                     f"{energy.lmm_power(kb, 'q8_0'):.3f}"])
    print("Fig 7 — per-lane power vs LMM size")
    print(fmt_table(rows, ["LMM", "FP16 (W)", "Q8_0 (W)"]))
    d = energy.lmm_power(32) - energy.lmm_power(16)
    print(f"16->32KB delta: {d*1000:.0f} mW (paper: 10 mW)")

    # PDP trade-off: coverage gain vs power growth per size (tiny workload)
    ms = enumerate_whisper(get_config("whisper-tiny"))
    trade = []
    for kb in LMM_SIZES_KB:
        cov = coverage(ms, kb)
        p = energy.lmm_power(kb)
        trade.append([f"{kb}KB", f"{cov*100:.1f}%", f"{p:.3f}",
                      f"{cov/p:.3f}"])
    print("\nCoverage-per-watt (the 32 KB operating-point argument)")
    print(fmt_table(trade, ["LMM", "coverage", "P_lane(W)", "cov/W"]))
    best = max(trade, key=lambda r: float(r[3]))
    print(f"best coverage-per-watt: {best[0]} (paper operating point: 32KB)")
    out = {"power_rows": rows, "tradeoff": trade, "best": best[0],
           "delta_16_32_mw": d * 1000}
    save("lmm_power", out)
    return out


if __name__ == "__main__":
    run()
