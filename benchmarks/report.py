"""Benchmark reporting: the EXPERIMENTS.md §Dry-run / §Roofline tables from
the JSON records under experiments/dryrun/, plus the paper-figure index of
every benchmark script (DESIGN.md §8).

Usage:
  PYTHONPATH=src python -m benchmarks.report [--mesh pod_16x16] [--variant V]
  PYTHONPATH=src python -m benchmarks.report --index

Flags:
  --mesh M     dry-run mesh directory to tabulate (default pod_16x16).
  --variant V  record variant filter (default "").
  --index      print the benchmark-script <-> paper-figure index with the
               output status of each script's experiments/bench/*.json.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN_DIR = os.path.join(ROOT, "experiments", "dryrun")
BENCH_DIR = os.path.join(ROOT, "experiments", "bench")

# One row per benchmark module: (module, paper figure/table, what it shows).
# Kept in DESIGN.md §8 order; tests assert every benchmarks/*.py script with
# a run() entry point appears here.
BENCHMARK_INDEX = [
    ("profile_shares", "Fig 4 / §1",
     "dot-product runtime share + Amdahl bound"),
    ("q8_reconstruction", "§4.2", "Q8_0 reconstruction error vs paper"),
    ("coverage_cdf", "Table 2 + 6", "LMM coverage CDFs"),
    ("lmm_power", "Fig 7", "power vs LMM size; 32KB PDP argument"),
    ("burst_sweep", "Fig 10 / §4.4", "burst PDP/EDP sweep + tile analog"),
    ("tune_sweep", "Fig 7+10", "(vmem_budget x block_k) autotuning grid"),
    ("calibration_error", "DESIGN.md §14",
     "analytic-vs-measured replay calibration + CI error gate"),
    ("lmm_latency", "Fig 11 / §5.1", "LMM size -> projected E2E latency"),
    ("exec_breakdown", "Fig 12", "EXEC/LOAD/CONF decomposition"),
    ("pdp_cross_platform", "Fig 9", "TDP-normalized cross-platform PDP"),
    ("decode_throughput", "§5.1 E2E / DESIGN.md §10",
     "engine-on vs engine-off decode tokens/s (jit-purity gate)"),
    ("backend_matrix", "Fig 9 / DESIGN.md §12",
     "tiny-shape tok/s + PDP per execution backend"),
    ("multi_utterance", "Table 4/5",
     "multi-utterance latency + transcript agreement"),
    ("continuous_batching", "§5.1 E2E / DESIGN.md §11",
     "continuous vs static batching under Poisson arrivals"),
    ("sharded_serving", "§5.1 E2E / DESIGN.md §13",
     "mesh-sharded vs single-device serve (token parity + by_device)"),
    ("paged_serving", "§5.1 E2E / DESIGN.md §15",
     "paged vs contiguous KV serving (parity + requests-per-GB)"),
    ("telemetry_overhead", "DESIGN.md §16",
     "telemetry on/off lockstep drain (≤3% step overhead + §16.2 exactness)"),
    ("speculative", "§5.1 E2E / DESIGN.md §17",
     "tiny-draft speculative decode vs plain greedy (token parity + >1.5x)"),
    ("paged_speculative", "§5.1 E2E / DESIGN.md §17.4",
     "speculative rounds over continuous/paged serving (parity under "
     "admission + preemption)"),
]


def index_table() -> str:
    rows = ["| script | reproduces | shows | output |",
            "|---|---|---|---|"]
    for mod, fig, what in BENCHMARK_INDEX:
        out = os.path.join(BENCH_DIR, f"{mod}.json")
        status = "ok" if os.path.exists(out) else "not run"
        rows.append(f"| benchmarks/{mod}.py | {fig} | {what} | {status} |")
    return "\n".join(rows)

ARCH_ORDER = ["llava-next-mistral-7b", "jamba-v0.1-52b", "mamba2-780m",
              "phi3-mini-3.8b", "qwen1.5-110b", "internlm2-20b",
              "qwen2.5-14b", "whisper-tiny", "arctic-480b", "olmoe-1b-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, variant: str = "") -> Dict[tuple, dict]:
    out = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("variant", "") != variant:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def _g(b):
    return f"{b/2**30:.2f}"


def roofline_table(mesh: str, variant: str = "") -> str:
    rows = [
        "| arch | shape | status | compute(s) | memory(s) | collective(s) | "
        "bound | step_s | useful | roofline | arg(GiB) | temp(GiB) |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    cells = load(mesh, variant)
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape))
            if r is None:
                rows.append(f"| {arch} | {shape} | MISSING | | | | | | | | |")
                continue
            if r["status"] == "skip":
                rows.append(f"| {arch} | {shape} | skip (sub-quadratic "
                            f"attn required) | | | | | | | | |")
                continue
            if r["status"] == "error":
                rows.append(f"| {arch} | {shape} | ERROR | | | | | | | | |")
                continue
            rf, m = r["roofline"], r["memory"]
            rows.append(
                f"| {arch} | {shape} | ok | {rf['compute_s']:.4f} | "
                f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
                f"{rf['bottleneck']} | {rf['step_s']:.4f} | "
                f"{rf['useful_flop_ratio']:.2f} | "
                f"{rf['roofline_fraction']:.4f} | "
                f"{_g(m['argument_bytes'])} | {_g(m['temp_bytes'])} |")
    return "\n".join(rows)


def summary(mesh: str, variant: str = "") -> dict:
    cells = load(mesh, variant)
    n_ok = sum(1 for r in cells.values() if r["status"] == "ok")
    n_skip = sum(1 for r in cells.values() if r["status"] == "skip")
    n_err = sum(1 for r in cells.values() if r["status"] == "error")
    worst = sorted(
        ((r["roofline"]["roofline_fraction"], k)
         for k, r in cells.items() if r["status"] == "ok"))
    coll_bound = [(k, r["roofline"]["collective_s"])
                  for k, r in cells.items()
                  if r["status"] == "ok"
                  and r["roofline"]["bottleneck"] == "collective"]
    return {"ok": n_ok, "skip": n_skip, "error": n_err,
            "worst_roofline": worst[:5],
            "collective_bound": sorted(coll_bound, key=lambda x: -x[1])[:5]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--variant", default="")
    ap.add_argument("--index", action="store_true",
                    help="print the benchmark <-> paper-figure index")
    args = ap.parse_args(argv)
    if args.index:
        print(index_table())
        return
    print(roofline_table(args.mesh, args.variant))
    print()
    print(json.dumps(summary(args.mesh, args.variant), indent=1))


if __name__ == "__main__":
    main()
