"""Paper Fig 12: execution-time breakdown of the offloaded kernel.

IMAX decomposes kernel time into EXEC (PE compute), LOAD/DRAIN (DRAM<->LMM
DMA) and CONF/... (configuration). The TPU analog per kernel class, from the
invocation enumerator + hardware model:

  EXEC  = FLOPs / MXU rate        LOAD = operand+result bytes / HBM bw
  CONF  = per-invocation launch overhead (fixed cost x invocations)

The paper's claim under test: after dense packing + double buffering the
offloaded kernel is COMPUTE-bound (EXEC 60.9 % FP16 / 74.7 % Q8_0).

Rates model the *paper's* platform (this figure characterizes IMAX, not the
TPU): 2 lanes at 840 MHz with 22 (FP16, 2-way SIMD FMA) / 46 (Q8_0, packed
int8 MAC with dequant overhead) active PEs; DMA at LPDDR4-class effective
bandwidth. The dequant factor and DMA bandwidth are fitted (the paper does
not publish them); the validation target is the regime (compute-bound) and
the direction (Q8_0 EXEC share > FP16), not the exact percentages.
Usage:
  PYTHONPATH=src python -m benchmarks.exec_breakdown

No flags; prints the per-kernel-class EXEC/LOAD/CONF decomposition and
writes experiments/bench/exec_breakdown.json.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config
from repro.core.coverage import enumerate_whisper

CLK = 840e6
RATE = {"fp16": 2 * 22 * 2 * 2 * CLK,           # lanes x PEs x SIMD x FMA
        "q8_0": 2 * 46 * 2 * 2 * CLK / 1.8}     # /1.8: inline dequant cost
DMA_BW = 6.4e9             # LPDDR4-class effective bytes/s
LAUNCH_S = 10e-6           # per-invocation CONF/REGV/RANGE/REFILL


def run() -> dict:
    cfg = get_config("whisper-tiny")
    ms = enumerate_whisper(cfg)
    out = {}
    rows = []
    for path, wbytes in (("fp16", 2), ("q8_0", 1.0625)):  # 34B per 32 block
        exec_s = sum(m.flops for m in ms) / RATE[path]
        load_s = sum((m.m * m.k * 2 + m.k * m.n * wbytes + m.m * m.n * 4)
                     * m.count for m in ms) / DMA_BW
        conf_s = sum(m.count for m in ms) * LAUNCH_S
        tot = exec_s + load_s + conf_s
        rows.append([path, f"{exec_s/tot*100:.1f}%", f"{load_s/tot*100:.1f}%",
                     f"{conf_s/tot*100:.1f}%",
                     {"fp16": "60.9%", "q8_0": "74.7%"}[path]])
        out[path] = {"exec_s": exec_s, "load_s": load_s, "conf_s": conf_s,
                     "exec_share": exec_s / tot}
    print("Fig 12 analog — offloaded-kernel time breakdown")
    print(fmt_table(rows, ["path", "EXEC", "LOAD/DRAIN", "CONF",
                           "paper EXEC"]))
    # the paper's structural claim: Q8_0 raises the EXEC share (less DMA)
    out["q8_raises_exec_share"] = (out["q8_0"]["exec_share"]
                                   > out["fp16"]["exec_share"])
    print(f"Q8_0 EXEC share > FP16 EXEC share: {out['q8_raises_exec_share']}"
          f" (matches the paper's direction)")
    save("exec_breakdown", out)
    return out


if __name__ == "__main__":
    run()
