"""Paged vs contiguous KV serving under a shared-prefix Poisson workload
(DESIGN.md §15; the memory-capacity analog of the paper's §5.1 sustained
multi-utterance evaluation).

The contiguous slot pool commits ``n_slots x (max_len + n_frames)`` KV up
front, so concurrency is capped by committed bytes even when utterances
repeat a hot audio preamble and budgets stay far below ``max_len``. The
paged pool (serve/paging.py) sizes ONE page arena to the workload,
deduplicates identical utterances' cross-KV by content hash, and
oversubscribes logical slots against physical pages with
preempt-and-recompute — so the same memory admits more concurrent
requests.

Both schedulers replay the SAME deterministic arrival trace (Poisson
gaps in decode-step units — the virtual clock advances one unit per
batch step, so the release schedule is machine-independent), for dense
bf16 AND q8_0+offload. Gates, asserted every run (CI via ``--smoke``):

  - token-exact parity: every request's paged token stream equals its
    contiguous stream (greedy decode rows are independent, so this holds
    through sharing, oversubscription, and preemption)
  - zero step retraces: ONE ``step_fn`` trace per engine across the
    whole schedule (replays ride the batch-1 ``_decode_jit``, which by
    design never touches the step trace counter)
  - >=2x admitted-requests-per-GB: peak concurrent admissions per
    committed KV byte, paged vs contiguous
  - preemption correctness: a deliberately tight arena (forcing
    preempt-and-recompute) still reproduces the contiguous token streams

Committed-KV bytes and peak utilization are reported next to tok/s and
p50/p95/p99 for every mode (DESIGN.md §15.4); the percentiles come from
the shared ``obs.metrics`` histogram in exact (track_values) mode.

Telemetry (DESIGN.md §16) rides the q8_0+offload variant's paged AND
tight-arena engines, adding gates: every lifecycle span closes through
prefix hits, CoW splits, preemptions and replays; span nesting holds;
and the sum of ledger-span FLOP deltas equals the ledger total EXACTLY
(§16.2). ``--trace-out``/``--metrics-out`` export the paged engine's
trace (Perfetto trace_event JSON, validated by tools/check_trace.py in
CI) and metrics exposition.

Usage:
  PYTHONPATH=src python -m benchmarks.paged_serving [--smoke]
      [--trace-out PATH] [--metrics-out PATH]

Writes experiments/bench/paged_serving.json.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import fmt_table, save
from repro import obs
from repro.configs.registry import get_config, get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.obs.metrics import LATENCY_BUCKETS_S, Histogram
from repro.serve.engine import ServeEngine


def _latency_summary(xs: List[float]) -> Dict[str, float]:
    """p50/p95/p99 (step units) through the ONE shared percentile
    implementation (repro.obs.metrics, DESIGN.md §16.3), exact mode."""
    h = Histogram("latency_steps", LATENCY_BUCKETS_S, track_values=True)
    for x in xs:
        h.observe(x)
    return {"p50_steps": h.percentile(50), "p95_steps": h.percentile(95),
            "p99_steps": h.percentile(99)}


def _drive(sched, mels: List[np.ndarray], max_news: List[int],
           arrivals: np.ndarray) -> Dict[str, object]:
    """Replay the arrival trace on a virtual step clock (one unit per
    batch decode step — deterministic across machines and modes), driving
    admit/decode manually so results stay in ``finished`` for the
    attribution check. Returns per-request token streams in submit order,
    step-unit latencies, and real wall-clock throughput."""
    t, i, n = 0, 0, len(mels)
    rid2idx: Dict[int, int] = {}
    done_at: Dict[int, int] = {}
    wall0 = time.perf_counter()
    while i < n or sched.n_queued or sched.n_active:
        while i < n and arrivals[i] <= t:
            rid2idx[sched.submit(mels[i], max_new=max_news[i])] = i
            i += 1
        sched.admit()
        if sched.n_active:
            for ev in sched.decode_step():
                if ev.done:
                    done_at[rid2idx[ev.rid]] = t + 1
            t += 1
        elif i < n:
            t = int(arrivals[i])          # idle: jump to the next arrival
    wall = time.perf_counter() - wall0
    att = sched.attribution()
    per_req = sum(att["per_request_pdp_j"].values())
    assert abs(per_req - att["batch_pdp_j"]) <= \
        1e-6 * max(1.0, att["batch_pdp_j"]), \
        "per-request PDP attribution must sum to the batch total (§11.3)"
    got = sched.finished
    rids = sorted(rid2idx, key=rid2idx.get)
    steps = sum(got[r].steps for r in rids)
    lat = [done_at[k] - float(arrivals[k]) for k in sorted(done_at)]
    return {"tokens": [got[r].tokens for r in rids],
            "steps": steps, "wall_s": wall,
            "tok_s": steps / max(wall, 1e-9),
            **_latency_summary(lat),
            "kv_committed_bytes": sched.kv_committed_bytes,
            "kv_used_peak_bytes": sched.kv_used_peak,
            "kv_utilization": sched.kv_utilization_peak,
            "active_peak": sched.active_peak,
            "step_traces": sched.step_traces}


def _workload(cfg, smoke: bool, rng: np.random.Generator):
    """Shared-prefix trace: ``n_distinct`` hot utterances (think repeated
    audio preambles) drawn with reuse across ``n_req`` requests, Poisson
    arrival gaps at ~3x service rate so the queue backs up and peak
    concurrency probes the admission limit."""
    n_req, n_frames = (16, 16) if smoke else (24, 32)
    lo, hi = (4, 12) if smoke else (6, 16)
    n_distinct = 2 if smoke else 3
    distinct = [rng.standard_normal((1, n_frames, cfg.n_mels)
                                    ).astype(np.float32)
                for _ in range(n_distinct)]
    mels = [distinct[int(rng.integers(n_distinct))] for _ in range(n_req)]
    max_news = [int(rng.integers(lo, hi + 1)) for _ in range(n_req)]
    # step-unit Poisson gaps: mean service is mean(max_new) steps for
    # n_slots-at-once service; 3x load backs the queue up deterministically
    mean_gap = float(np.mean(max_news)) / (3 * 4)
    arrivals = np.floor(np.cumsum(rng.exponential(mean_gap, n_req)))
    return mels, max_news, arrivals, n_frames, hi


def _variant(name: str, cfg, params, quant: str, make_offload,
             smoke: bool, mesh=None, telemetry=None) -> Dict[str, object]:
    rng = np.random.default_rng(0)        # same trace for every variant
    mels, max_news, arrivals, n_frames, hi = _workload(cfg, smoke, rng)
    n_slots = 4
    max_len = hi + 8
    page_size = 4
    # paged geometry: 3x logical-slot oversubscription, self arena sized
    # to the MEAN budget (tail requests page-fault into preemption — the
    # admission-control point), cross arena sized to the distinct
    # utterance count + 1 (prefix sharing dedups the rest)
    n_slots_p = 3 * n_slots
    pages_per = -(-(int(np.mean(max_news)) + 1) // page_size)
    geom = dict(page_size=page_size, n_pages=1 + n_slots_p * pages_per,
                cross_page_size=n_frames,
                n_cross_pages=1 + len({id(m) for m in mels}))

    def engine(tele=None):
        return ServeEngine(cfg, params, max_len=max_len, quant=quant,
                           offload=make_offload(), eos_id=-1,
                           telemetry=tele)

    eng_c = engine()
    contig = _drive(eng_c.scheduler(n_slots=n_slots, n_frames=n_frames),
                    mels, max_news, arrivals)
    eng_p = engine(telemetry)
    sched_p = eng_p.paged_scheduler(n_slots=n_slots_p, n_frames=n_frames,
                                    **geom)
    paged = _drive(sched_p, mels, max_news, arrivals)

    # deliberately tight arena: fewer pages than the actives want, so
    # decode MUST preempt-and-recompute — and stay token-exact. Its own
    # telemetry proves the preempt/replay path keeps the §16.2 invariants
    tele_t = obs.Telemetry() if telemetry is not None else None
    eng_t = engine(tele_t)
    tight_pages = 2 + 2 * pages_per       # ~2 full slots' worth of pages
    sched_t = eng_t.paged_scheduler(n_slots=n_slots, n_frames=n_frames,
                                    page_size=page_size,
                                    n_pages=tight_pages,
                                    cross_page_size=n_frames,
                                    n_cross_pages=geom["n_cross_pages"])
    tight = _drive(sched_t, mels, max_news, arrivals)

    # admitted-requests-per-GB: peak concurrent admissions per committed
    # KV byte (the GB scaling cancels in the gated ratio)
    rpb_c = contig["active_peak"] / contig["kv_committed_bytes"]
    rpb_p = paged["active_peak"] / paged["kv_committed_bytes"]
    checks = {
        "parity": paged["tokens"] == contig["tokens"],
        "tight_parity": tight["tokens"] == contig["tokens"],
        "tight_preempted": sched_t.preemptions > 0,
        "shared_hits": sched_p.shared_hits > 0,
        "zero_retrace": (contig["step_traces"] == 1
                         and paged["step_traces"] == 1
                         and tight["step_traces"] == 1),
        "mem_2x": rpb_p >= 2 * rpb_c,
    }
    if telemetry is not None:
        # §16.2 invariants over the instrumented paged + tight engines:
        # exact ledger attribution, closed lifecycles, clean nesting —
        # through prefix hits, CoW splits, preemptions, and replays
        for tag, tl in (("paged", telemetry), ("tight", tele_t)):
            cons = tl.ledger_consistent()
            checks[f"tele_{tag}_ledger_exact"] = bool(cons["exact"])
            checks[f"tele_{tag}_spans_closed"] = tl.tracer.all_closed()
            checks[f"tele_{tag}_nesting"] = not tl.tracer.check_nesting()
    modes = {"contiguous": contig, "paged": paged, "tight": tight}
    if mesh is not None:
        # the multidev leg: the SAME paged geometry with the arenas'
        # page axes and the tables' slot axes sharded over "data"
        # (DESIGN.md §15.3) must stay token-exact and trace-stable
        eng_s = ServeEngine(cfg, params, max_len=max_len, quant=quant,
                            offload=make_offload(), eos_id=-1, mesh=mesh)
        sched_s = eng_s.paged_scheduler(n_slots=n_slots_p,
                                        n_frames=n_frames, **geom)
        sharded = _drive(sched_s, mels, max_news, arrivals)
        checks["sharded_parity"] = sharded["tokens"] == contig["tokens"]
        checks["sharded_zero_retrace"] = sharded["step_traces"] == 1
        modes["sharded"] = sharded
    return {"name": name, "n_slots": n_slots, "n_slots_paged": n_slots_p,
            "n_frames": n_frames, "geometry": geom,
            **{mode: {k: v for k, v in r.items() if k != "tokens"}
               for mode, r in modes.items()},
            "modes": list(modes),
            "preemptions": sched_t.preemptions,
            "shared_hits": sched_p.shared_hits,
            "req_per_gb_ratio": rpb_p / max(rpb_c, 1e-30),
            "checks": checks, "ok": all(checks.values())}


def run(smoke: bool = False, trace_out: str = None,
        metrics_out: str = None) -> dict:
    cfg = get_smoke_config("whisper-tiny") if smoke \
        else get_config("whisper-tiny")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)
    mesh = None
    if len(jax.devices()) >= 2:           # the multidev CI leg
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh()
    tele = obs.Telemetry()                # rides the q8 paged engine
    variants = [
        _variant("dense", cfg, params, "none", lambda: None, smoke,
                 mesh=mesh),
        _variant("q8_0+offload", cfg, params, "q8_0",
                 lambda: OffloadEngine(interpret=True, prefer_pallas=False),
                 smoke, mesh=mesh, telemetry=tele),
    ]

    rows = []
    for v in variants:
        for mode in v["modes"]:
            r = v[mode]
            rows.append([v["name"], mode, f"{r['tok_s']:.1f}",
                         f"{r['p95_steps']:.0f}", f"{r['p99_steps']:.0f}",
                         f"{r['kv_committed_bytes'] / 1024:.0f}",
                         f"{r['kv_utilization']:.2f}",
                         str(r["active_peak"])])
    print("whisper-tiny paged vs contiguous KV serving, shared-prefix "
          f"Poisson trace ({'smoke' if smoke else 'full'} config)")
    print(fmt_table(rows, ["variant", "mode", "tok/s", "p95(steps)",
                           "p99(steps)", "KV committed(KiB)", "KV util",
                           "peak active"]))
    ok = True
    for v in variants:
        ok = ok and v["ok"]
        detail = " ".join(f"{k}={'ok' if val else 'FAIL'}"
                          for k, val in v["checks"].items())
        print(f"{v['name']}: {v['req_per_gb_ratio']:.2f}x requests/GB, "
              f"{v['shared_hits']} prefix hits, {v['preemptions']} "
              f"preemptions (tight) | {detail} "
              f"-> {'ok' if v['ok'] else 'FAIL'}")
    if trace_out:
        print("trace written:", tele.write_trace(trace_out))
    if metrics_out:
        print("metrics written:", tele.write_metrics(metrics_out))
    out = {"smoke": smoke, "variants": variants, "gate_ok": ok,
           "ledger_consistency": tele.ledger_consistent()}
    save("paged_serving", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI gate")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the q8 paged engine's Perfetto trace")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write its Prometheus metrics exposition")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke, trace_out=args.trace_out,
              metrics_out=args.metrics_out)
    return 0 if out["gate_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
