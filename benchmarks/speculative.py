"""Speculative decoding across the Whisper ladder (DESIGN.md §17; the
§5.1 E2E serving path spending tiny-model FLOPs to amortize base/small
steps — the ladder the paper's scaling study runs, §4.3).

A whisper-tiny-shaped draft proposes k tokens per round; the base/small
verifier scores the k+1 window in ONE jitted forward and greedy
acceptance keeps the stream token-exact with the verifier alone. The
gates, asserted every run (CI via ``--smoke`` on the default AND the
``REPRO_BACKEND=xla_ref`` matrix legs):

  - token-exact parity: for whisper-base AND whisper-small verifiers,
    dense f32 and q8_0+offload, the speculative token streams equal the
    verifier's own plain greedy ``transcribe`` exactly
  - speedup: speculative decode sustains > 1.5x the plain-greedy tok/s
    on both verifier rungs (draft acceptance via the echo workload below)
  - zero retraces: across the whole timed run the verify window, the
    draft step, and the plain-greedy step each compile exactly once
  - exact attribution: draft + verify ledger FLOPs (``by_role``) sum to
    the ledger's flop totals, and the per-round ledger spans claim every
    committed FLOP (the §16.2 integer invariant, checked by
    ``telemetry.ledger_consistent``)

Workload: the ladder is exercised at reduced scale (the real rungs'
relative step costs preserved — tiny ≪ base < small — with vocab shrunk
so the readout does not flatten the rung gap) with an *echo*
parameterization — decoder-block
output projections scaled by ``alpha`` so, with tied embeddings, every
rung's argmax approximately echoes its input token. Draft and verifier
then agree on most positions despite independent random init, giving the
high-acceptance regime the speedup gate needs; the parity gate is what
guards correctness and holds at ANY acceptance (the test suite drives
the near-zero-acceptance regime with raw random init).

Usage:
  PYTHONPATH=src python -m benchmarks.speculative [--smoke]

Writes experiments/bench/speculative.json.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List

# reduced ladder preserving the real rungs' *relative* step costs
# (tiny ≪ base < small; per-step FLOP ratios ~1:36:128), vocab 512
# (%16==0) so decode stays block-dominated and the draft/verifier gap
# survives the readout. The draft must be cheap not just in FLOPs but in
# *dispatch count* — verifier steps have to dominate wall-clock for the
# speculative trade to show, same as on real hardware where base/small
# steps are weight-streaming-bound (paper §4.3 coverage collapse).
_LADDER = {
    "tiny": dict(num_layers=1, num_encoder_layers=1, d_model=128,
                 num_heads=2, d_ff=512),
    "base": dict(num_layers=4, num_encoder_layers=4, d_model=384,
                 num_heads=6, d_ff=1536),
    "small": dict(num_layers=8, num_encoder_layers=8, d_model=512,
                  num_heads=8, d_ff=2048),
}


def _ladder_cfg(rung: str):
    from repro.configs.whisper_base import CONFIG

    s = _LADDER[rung]
    return dataclasses.replace(
        CONFIG, name=f"whisper-{rung}-ladder", vocab_size=512, vocab_pad=0,
        encoder_ctx=64, head_dim=64, num_kv_heads=s["num_heads"],
        dtype="float32", param_dtype="float32", remat="none",
        scan_layers=False, **s)


def _echo_params(params, alpha: float):
    """Scale every decoder-block output projection (self/cross attention
    ``o``, FFN ``down``) by ``alpha``: at small alpha the blocks approach
    identity, logits approach ``unembed(LN(embed(tok) + pos))``, and with
    tied embeddings each rung echoes its input token — the controllable
    high-acceptance workload (module docstring)."""
    import jax

    def scale(leaf_path):
        sub = params["dec_blocks"]
        for k in leaf_path:
            sub = sub[k]
        return jax.tree_util.tree_map(lambda a: a * alpha, sub)

    out = dict(params)
    blocks = dict(params["dec_blocks"])
    for arm, proj in (("self_attn", "o"), ("cross_attn", "o"),
                      ("ffn", "down")):
        blocks[arm] = dict(blocks[arm])
        blocks[arm][proj] = scale((arm, proj))
    out["dec_blocks"] = blocks
    return out


def _timed_greedy(engine, mel, max_new: int) -> Dict[str, object]:
    engine.transcribe(mel, max_new=max_new)            # compile warmup
    t0 = engine._step_traces
    res = engine.transcribe(mel, max_new=max_new)
    toks = sum(r.steps for r in res)
    wall = sum(r.decode_s for r in res)
    return {"tokens": [r.tokens for r in res], "toks": toks,
            "wall_s": wall, "tok_s": toks / max(wall, 1e-9),
            "retraces": engine._step_traces - t0}


def _timed_spec(spec, mel, max_new: int) -> Dict[str, object]:
    spec.transcribe(mel, max_new=max_new)              # compile warmup
    v0 = spec.verifier._verify_traces
    d0 = spec.draft._step_traces
    r0, dr0, a0 = spec.rounds, spec.drafted, spec.accepted
    res = spec.transcribe(mel, max_new=max_new)
    toks = sum(r.steps for r in res)
    wall = sum(r.decode_s for r in res)
    return {"tokens": [r.tokens for r in res], "toks": toks,
            "wall_s": wall, "tok_s": toks / max(wall, 1e-9),
            "rounds": spec.rounds - r0,
            "acceptance": (spec.accepted - a0) / max(spec.drafted - dr0, 1),
            "verify_retraces": spec.verifier._verify_traces - v0,
            "draft_retraces": spec.draft._step_traces - d0}


def _variant(rung: str, quant: str, tiny_cfg, tiny_params, mel,
             max_new: int, k: int, alpha: float) -> Dict[str, object]:
    import jax

    from repro import obs
    from repro.core.offload import OffloadEngine
    from repro.models import model as model_lib
    from repro.serve.engine import ServeEngine

    cfg = _ladder_cfg(rung)
    params = _echo_params(
        model_lib.init_params(jax.random.PRNGKey(1), cfg), alpha)
    off = (OffloadEngine(interpret=True) if quant == "q8_0" else None)
    tele = obs.Telemetry()
    v = ServeEngine(cfg, params, max_len=max_new + k + 1, quant=quant,
                    offload=off, eos_id=-1, telemetry=tele)
    greedy = _timed_greedy(v, mel, max_new)
    spec_engine = v.speculative(tiny_cfg, tiny_params, k=k)
    spec = _timed_spec(spec_engine, mel, max_new)

    checks = {
        "parity": greedy["tokens"] == spec["tokens"],
        "speedup": spec["tok_s"] > 1.5 * greedy["tok_s"],
        "zero_retrace": (greedy["retraces"] == 0
                         and spec["verify_retraces"] == 0
                         and spec["draft_retraces"] == 0),
    }
    report: Dict[str, object] = {}
    if off is not None:
        s = off.stats
        total = s.offloaded_flops + s.fallback_flops + s.residual_flops
        checks["by_role_sums"] = sum(s.by_role.values()) == total
        ledger = tele.ledger_consistent()
        checks["spans_exact"] = bool(ledger["exact"])
        report["by_role"] = dict(s.by_role)
        report["ledger"] = ledger
    return {"rung": rung, "quant": quant, "k": k,
            "greedy": {kk: vv for kk, vv in greedy.items()
                       if kk != "tokens"},
            "spec": {kk: vv for kk, vv in spec.items() if kk != "tokens"},
            "speedup_x": spec["tok_s"] / max(greedy["tok_s"], 1e-9),
            "checks": checks, "ok": all(checks.values()), **report}


def run(smoke: bool = False) -> dict:
    import jax
    import numpy as np

    from benchmarks.common import fmt_table, save
    from repro.models import model as model_lib

    b, max_new, k = (2, 24, 6) if smoke else (4, 48, 6)
    alpha = 0.02
    tiny_cfg = _ladder_cfg("tiny")
    tiny_params = _echo_params(
        model_lib.init_params(jax.random.PRNGKey(0), tiny_cfg), alpha)
    frames = 32
    mel = np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                       (b, frames, tiny_cfg.n_mels)),
                     np.float32)

    variants: List[Dict[str, object]] = []
    for rung in ("base", "small"):
        for quant in ("none", "q8_0"):
            variants.append(_variant(rung, quant, tiny_cfg, tiny_params,
                                     mel, max_new, k, alpha))

    rows = []
    for v in variants:
        rows.append([v["rung"], v["quant"],
                     f"{v['greedy']['tok_s']:.1f}",
                     f"{v['spec']['tok_s']:.1f}",
                     f"{v['speedup_x']:.2f}x",
                     f"{v['spec']['acceptance']:.2f}",
                     str(v["spec"]["rounds"]),
                     "0" if v["checks"]["zero_retrace"] else "RETRACED"])
    print(f"speculative decoding, reduced ladder, tiny draft, k={k} "
          f"({'smoke' if smoke else 'full'})")
    print(fmt_table(rows, ["verifier", "quant", "greedy tok/s",
                           "spec tok/s", "speedup", "accept", "rounds",
                           "retraces"]))
    ok = True
    for v in variants:
        ok = ok and v["ok"]
        detail = " ".join(f"{kk}={'ok' if val else 'FAIL'}"
                          for kk, val in v["checks"].items())
        print(f"{v['rung']}/{v['quant']}: {detail} -> "
              f"{'ok' if v['ok'] else 'FAIL'}")
    out = {"smoke": smoke, "k": k, "alpha": alpha, "batch": b,
           "max_new": max_new, "variants": variants, "gate_ok": ok}
    save("speculative", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for the CI gate")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    return 0 if out["gate_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
