"""Paper §4.2: Q8_0 reconstruction error over the Whisper-tiny weight set.

Paper figures (65 2-D tensors, 36.4M scalars of the released FP16 model):
  MAE 1.39e-4 | RMSE 2.09e-4 | max|err| 3.41e-3 | rel-L2 8.31e-3

We quantize every 2-D GEMM weight of our whisper-tiny (randomly initialized
at trained-weight scale) with the same GGML block format and report the same
four metrics — the match validates the format implementation, with the
residual gap attributable to weight-distribution differences (init vs
trained).
Usage:
  PYTHONPATH=src python -m benchmarks.q8_reconstruction

No flags; prints MAE/RMSE/max|err|/rel-L2 against the paper's published
figures and writes experiments/bench/q8_reconstruction.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config
from repro.core.qformats import QBLOCK, quantize_q8_0, reconstruction_error
from repro.models import model as model_lib

PAPER = {"mae": 1.39e-4, "rmse": 2.09e-4, "max_abs": 3.41e-3,
         "rel_l2": 8.31e-3}


def run() -> dict:
    cfg = get_config("whisper-tiny")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)

    tensors = []
    def collect(path, leaf):
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2
                and leaf.shape[-1] % QBLOCK == 0
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            tensors.append(np.asarray(leaf, np.float32).reshape(-1, leaf.shape[-1]))
        return leaf
    jax.tree_util.tree_map_with_path(collect, params)

    n_values = sum(t.size for t in tensors)
    errs = []
    sq = 0.0
    ab = 0.0
    mx = 0.0
    num = 0.0
    den = 0.0
    for t in tensors:
        w = jnp.asarray(t)
        e = reconstruction_error(w, quantize_q8_0(w))
        errs.append(e)
        sq += e["rmse"] ** 2 * t.size
        ab += e["mae"] * t.size
        mx = max(mx, e["max_abs"])
        num += (e["rel_l2"] * 1.0) ** 2 * t.size  # approx aggregate
        den += t.size
    agg = {
        "n_tensors": len(tensors),
        "n_values": int(n_values),
        "mae": ab / n_values,
        "rmse": float(np.sqrt(sq / n_values)),
        "max_abs": mx,
        "rel_l2": float(np.sqrt(num / den)),
    }
    ratios = {k: agg[k] / PAPER[k] for k in PAPER}
    rows = [[k, f"{agg[k]:.3e}", f"{PAPER[k]:.3e}", f"{ratios[k]:.2f}x"]
            for k in PAPER]
    print("Q8_0 reconstruction error (paper §4.2)")
    print(fmt_table(rows, ["metric", "ours", "paper", "ratio"]))
    ok = all(0.1 < r < 10 for r in ratios.values())
    out = {"ours": agg, "paper": PAPER, "ratios": ratios,
           "same_order_of_magnitude": ok}
    save("q8_reconstruction", out)
    return out


if __name__ == "__main__":
    run()
