"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--fast]``.

One module per paper table/figure (DESIGN.md §8 index). Results print as
tables and persist under experiments/bench/*.json; EXPERIMENTS.md cites
them. ``--fast`` skips the two wall-clock-heavy whisper-full runs."""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    backend_matrix, burst_sweep, calibration_error, continuous_batching,
    coverage_cdf, decode_throughput, exec_breakdown, lmm_latency, lmm_power,
    multi_utterance, paged_serving, paged_speculative, pdp_cross_platform,
    profile_shares,
    q8_reconstruction, sharded_serving, speculative, telemetry_overhead,
    tune_sweep)

SUITES = [
    ("q8_reconstruction (§4.2)", q8_reconstruction.run, False),
    ("coverage_cdf (Table 2/6)", coverage_cdf.run, False),
    ("burst_sweep (Fig 10)", burst_sweep.run, False),
    ("tune_sweep (Fig 7+10 co-design grid)", tune_sweep.run, False),
    ("calibration_error (DESIGN.md §14 replay calibration)",
     calibration_error.run, False),
    ("lmm_power (Fig 7)", lmm_power.run, False),
    ("lmm_latency (Fig 11)", lmm_latency.run, False),
    ("pdp_cross_platform (Fig 9)", pdp_cross_platform.run, False),
    ("exec_breakdown (Fig 12)", exec_breakdown.run, False),
    ("decode_throughput (§5.1 E2E / DESIGN.md §10)", decode_throughput.run,
     False),
    ("backend_matrix (Fig 9 / DESIGN.md §12)", backend_matrix.run, False),
    ("profile_shares (Fig 4)", profile_shares.run, True),
    ("multi_utterance (Table 4/5)", multi_utterance.run, True),
    ("continuous_batching (§5.1 / DESIGN.md §11)", continuous_batching.run,
     True),
    ("sharded_serving (§5.1 / DESIGN.md §13)", sharded_serving.run, True),
    ("paged_serving (§5.1 / DESIGN.md §15)", paged_serving.run, True),
    ("telemetry_overhead (DESIGN.md §16)", telemetry_overhead.run, True),
    ("speculative (§5.1 / DESIGN.md §17)", speculative.run, True),
    ("paged_speculative (§5.1 / DESIGN.md §17.4)", paged_speculative.run,
     True),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip wall-clock-heavy whisper-full benches")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark name")
    args = ap.parse_args(argv)

    failures = []
    for name, fn, heavy in SUITES:
        if args.fast and heavy:
            print(f"\n=== {name} === SKIPPED (--fast)")
            continue
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn()
            print(f"[{time.time()-t0:.1f}s] ok")
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} benchmark(s) FAILED:", failures)
        return 1
    print("\nall benchmarks passed; JSON in experiments/bench/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
