"""Paper Table 2 + Table 6: LMM kernel-coverage CDFs.

Table 2 (tiny, baseline padded vs optimized dense) and Table 6 (coverage vs
LMM size for tiny/base/small) from our invocation enumerator + documented
footprint model (core/coverage.py).
Usage:
  PYTHONPATH=src python -m benchmarks.coverage_cdf

No flags; prints Table 2 (baseline vs optimized, tiny) and Table 6
(tiny/base/small vs LMM size) and writes
experiments/bench/coverage_cdf.json.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, save
from repro.configs.registry import get_config
from repro.core.coverage import LMM_SIZES_KB, coverage_cdf, enumerate_whisper

PAPER_T2_OPT = {8: 64.96, 16: 66.35, 32: 93.80, 64: 93.80, 128: 100.0,
                256: 100.0}
PAPER_T6 = {
    "whisper-tiny": {16: 66.35, 32: 93.80, 64: 93.80, 128: 100.0, 256: 100.0},
    "whisper-base": {16: 66.55, 32: 66.54, 64: 94.17, 128: 97.08, 256: 99.89},
    "whisper-small": {16: 66.53, 32: 66.52, 64: 94.36, 128: 96.89,
                      256: 99.89},
}


def run() -> dict:
    out = {}
    rows_t2 = []
    tiny = enumerate_whisper(get_config("whisper-tiny"))
    for size, base, opt in coverage_cdf(tiny):
        rows_t2.append([f"{size}KB", f"{base*100:.2f}%", f"{opt*100:.2f}%",
                        f"{PAPER_T2_OPT[size]:.2f}%"])
    print("Table 2 analog — whisper-tiny coverage (baseline vs optimized)")
    print(fmt_table(rows_t2, ["LMM", "baseline(padded)", "optimized(ours)",
                              "optimized(paper)"]))
    out["table2"] = rows_t2

    print("\nTable 6 analog — coverage vs LMM size across model scales")
    rows_t6 = []
    for arch in ("whisper-tiny", "whisper-base", "whisper-small"):
        ms = enumerate_whisper(get_config(arch))
        cdf = {s: o for s, _, o in coverage_cdf(ms)}
        paper = PAPER_T6[arch]
        rows_t6.append([arch] + [f"{cdf[s]*100:.1f}/{paper[s]:.1f}"
                                 for s in (16, 32, 64, 128, 256)])
    print(fmt_table(rows_t6, ["model (ours/paper %)", "16KB", "32KB", "64KB",
                              "128KB", "256KB"]))
    out["table6"] = rows_t6

    # headline claims
    tiny_32 = dict((s, o) for s, _, o in coverage_cdf(tiny))[32]
    base_32 = dict((s, o) for s, _, o in
                   coverage_cdf(enumerate_whisper(get_config("whisper-base"))))[32]
    out["claims"] = {
        "tiny_32kb_high": tiny_32 > 0.8,
        "base_drops_at_32kb": base_32 < tiny_32,
    }
    save("coverage_cdf", out)
    return out


if __name__ == "__main__":
    run()
